//! End-to-end training driver — the full three-layer stack on a real
//! workload:
//!
//!   L1 Pallas kernels -> L2 JAX transformer -> AOT HLO artifacts ->
//!   L3 rust coordinator: heterogeneous plan (DP optimizer), uneven
//!   batch split, microbatch gradient accumulation, uneven
//!   ReduceScatter, sharded Adam, uneven AllGather — REAL numerics via
//!   PJRT, python nowhere on the path.
//!
//! Trains a decoder-only transformer on a synthetic Markov corpus and
//! logs the loss curve. Presets (this image is a single 2.7 GHz core —
//! see DESIGN.md §Substitutions for the paper-scale mapping):
//!
//! * `--preset small`  (default): the test artifacts (~3.7M params),
//!   300 steps, a couple of minutes.
//! * `--preset medium`: ~42M params (`make artifacts-e2e`), 150 steps.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example train_e2e
//! cargo run --release --offline --example train_e2e -- --preset medium \
//!     --steps 150
//! ```

use std::path::PathBuf;

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::runtime::Manifest;
use cephalo::trainer::adam::AdamConfig;
use cephalo::trainer::{TrainConfig, Trainer, WorkerSpec};

struct Preset {
    dir: &'static str,
    steps: usize,
    batch: usize,
    lr: f32,
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let preset = match get("--preset").as_deref() {
        Some("medium") => Preset {
            dir: "artifacts_e2e",
            steps: 150,
            batch: 8,
            lr: 1.5e-3,
        },
        _ => Preset { dir: "artifacts", steps: 300, batch: 16, lr: 2e-3 },
    };
    let steps = get("--steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(preset.steps);
    let batch = get("--batch")
        .and_then(|s| s.parse().ok())
        .unwrap_or(preset.batch);
    let dir = PathBuf::from(get("--artifacts").unwrap_or_else(|| {
        preset.dir.to_string()
    }));
    if !dir.join("manifest.json").exists() {
        anyhow::bail!(
            "no artifacts at {} — run `make artifacts` (or artifacts-e2e)",
            dir.display()
        );
    }

    // 1) Plan the heterogeneous division on the paper's Cluster A.
    let cluster = Cluster::cluster_a();
    let names: Vec<String> =
        cluster.gpus().iter().map(|g| g.spec.name.clone()).collect();
    let workload = Workload::prepare(cluster, "BERT-Large", 42)
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    let (assignment, _) = workload
        .optimize(batch)
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    let workers: Vec<WorkerSpec> =
        Trainer::workers_from_assignment(&assignment, &names);
    println!("heterogeneous plan over simulated Cluster A:");
    for w in &workers {
        println!(
            "  {:<8} batch {:>3}   state share {:>5.1}%",
            w.name,
            w.batch,
            w.state_ratio * 100.0
        );
    }

    // 2) Train with real numerics.
    let cfg = TrainConfig {
        steps,
        seed: 42,
        adam: AdamConfig { lr: preset.lr, ..Default::default() },
        corpus_branch: 4,
        log_every: 10,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&dir, workers, cfg)?;
    let m = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!(e))?
        .model;
    println!(
        "\nmodel: {} params (d={} L={} V={} seq={}), pallas={}",
        m.num_params, m.d_model, m.n_layers, m.vocab, m.seq_len,
        m.use_pallas
    );
    println!(
        "corpus entropy {:.3} nats (loss floor), ln(V) = {:.3} (init loss)\n",
        trainer.corpus_entropy(),
        (m.vocab as f64).ln()
    );

    let t0 = std::time::Instant::now();
    let history = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    // 3) Report.
    let first = history.first().unwrap().mean_loss;
    let last = history.last().unwrap().mean_loss;
    let floor = trainer.corpus_entropy();
    println!("\n===== e2e result =====");
    println!("steps            : {}", history.len());
    println!("global batch     : {batch}");
    println!("wall time        : {wall:.1}s ({:.2}s/step)",
             wall / history.len() as f64);
    println!("loss             : {first:.4} -> {last:.4} (floor {floor:.3})");
    println!(
        "progress to floor: {:.0}%",
        (first - last) / (first - floor) * 100.0
    );
    let csv_path = "e2e_loss_curve.csv";
    let mut csv = String::from("step,loss,wall_seconds\n");
    for s in &history {
        csv.push_str(&format!("{},{},{}\n", s.step, s.mean_loss,
                              s.wall_seconds));
    }
    std::fs::write(csv_path, csv)?;
    println!("loss curve       : {csv_path}");
    anyhow::ensure!(last < first - 0.3, "loss did not descend enough");
    Ok(())
}
