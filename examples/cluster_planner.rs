//! Cluster planner: sweep Table-2 models and batch sizes over a chosen
//! cluster, comparing Cephalo against every registered strategy — a
//! practitioner's "what can my mixed-GPU fleet actually train, and how
//! fast?" tool. All solves for a model run as one parallel
//! `plan::sweep` over the planner registry.
//!
//! ```sh
//! cargo run --release --offline --example cluster_planner -- [a|b]
//! ```

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::plan::{sweep, PlannerRegistry};
use cephalo::util::tablefmt::{fmt_throughput, Table};

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "a".to_string());
    let cluster = Cluster::preset(&arg).unwrap_or_else(|| {
        eprintln!("unknown cluster '{arg}', using A");
        Cluster::cluster_a()
    });
    let models = if cluster.num_gpus() > 8 {
        vec![("ViT-e", 512), ("GPT 6.7B", 512), ("Llama 7B", 512)]
    } else {
        vec![
            ("BERT-Large", 128),
            ("ViT-G", 128),
            ("GPT 2.7B", 128),
            ("Llama 3B", 128),
        ]
    };

    let registry = PlannerRegistry::with_defaults();
    let planners: Vec<_> = ["cephalo", "megatron", "flashflex"]
        .iter()
        .map(|n| registry.get(n).expect("default registry entry"))
        .collect();

    let mut table = Table::new(
        &format!("Training plans for cluster {}", cluster.name),
        &["model", "batch", "system", "samples/s", "plan"],
    );
    for (model, batch) in models {
        let w = match Workload::prepare(cluster.clone(), model, 42) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        for cell in sweep(&w.ctx(0), &planners, &[batch], None) {
            match cell.result {
                Ok(out) => table.add_row(vec![
                    model.into(),
                    batch.to_string(),
                    out.planner,
                    fmt_throughput(out.throughput),
                    out.config,
                ]),
                Err(e) => table.add_row(vec![
                    model.into(),
                    batch.to_string(),
                    cell.planner,
                    if e.is_oom() { "OOM".into() } else { "-".into() },
                    e.to_string(),
                ]),
            }
        }
    }
    println!("{}", table.render());
}
