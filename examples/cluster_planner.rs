//! Cluster planner: sweep Table-2 models and batch sizes over a chosen
//! cluster, comparing Cephalo against every baseline — a practitioner's
//! "what can my mixed-GPU fleet actually train, and how fast?" tool.
//!
//! ```sh
//! cargo run --release --offline --example cluster_planner -- [a|b]
//! ```

use cephalo::baselines::{self, BaselinePlanner};
use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::util::tablefmt::{fmt_throughput, Table};

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "a".to_string());
    let cluster = Cluster::preset(&arg).unwrap_or_else(|| {
        eprintln!("unknown cluster '{arg}', using A");
        Cluster::cluster_a()
    });
    let models = if cluster.num_gpus() > 8 {
        vec![("ViT-e", 512), ("GPT 6.7B", 512), ("Llama 7B", 512)]
    } else {
        vec![
            ("BERT-Large", 128),
            ("ViT-G", 128),
            ("GPT 2.7B", 128),
            ("Llama 3B", 128),
        ]
    };

    let mut table = Table::new(
        &format!("Training plans for cluster {}", cluster.name),
        &["model", "batch", "system", "samples/s", "plan"],
    );
    for (model, batch) in models {
        let w = match Workload::prepare(cluster.clone(), model, 42) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        match w.cephalo_throughput(batch) {
            Ok((asg, stats)) => {
                let bs: Vec<usize> =
                    asg.per_gpu.iter().map(|g| g.batch()).collect();
                table.add_row(vec![
                    model.into(),
                    batch.to_string(),
                    "Cephalo".into(),
                    fmt_throughput(stats.throughput),
                    format!("b={bs:?}"),
                ]);
            }
            Err(e) => table.add_row(vec![
                model.into(),
                batch.to_string(),
                "Cephalo".into(),
                "OOM".into(),
                e.to_string(),
            ]),
        }
        let planners: Vec<Box<dyn BaselinePlanner>> = vec![
            Box::new(baselines::megatron::MegatronHet),
            Box::new(baselines::flashflex::FlashFlex),
        ];
        for p in planners {
            match p.plan(&w.ctx(batch)) {
                Ok(out) => table.add_row(vec![
                    model.into(),
                    batch.to_string(),
                    out.system,
                    fmt_throughput(out.throughput),
                    out.config,
                ]),
                Err(_) => table.add_row(vec![
                    model.into(),
                    batch.to_string(),
                    p.name().into(),
                    "OOM".into(),
                    String::new(),
                ]),
            }
        }
    }
    println!("{}", table.render());
}
