//! Quickstart: the full Cephalo pipeline in ~40 lines.
//!
//! 1. describe a heterogeneous cluster (the paper's Cluster A),
//! 2. profile the workload (synthetic oracle standing in for real GPUs),
//! 3. let the optimizer decouple compute (b_i) from memory (r_i),
//! 4. simulate a training iteration and report throughput.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;

fn main() {
    let cluster = Cluster::cluster_a();
    println!(
        "cluster {}: {} GPUs, {:.0} aggregate TFLOPs, {:.0} GB memory",
        cluster.name,
        cluster.num_gpus(),
        cluster.total_tflops(),
        cluster.total_mem_bytes() / 1e9
    );

    let workload = Workload::prepare(cluster, "BERT-Large", 42)
        .expect("profiling failed");

    let batch = 128;
    let (assignment, stats) = workload
        .cephalo_throughput(batch)
        .expect("planning failed");

    println!("\nper-GPU plan (batch {batch}):");
    println!("{:<6} {:>8} {:>8} {:>8} {:>9}", "gpu", "b_i", "m_i", "l_i",
             "state r_i");
    for (g, slot) in assignment.per_gpu.iter().zip(workload.cluster.gpus())
    {
        println!(
            "{:<6} {:>8} {:>8} {:>8} {:>9.3}",
            slot.spec.name,
            g.batch(),
            g.microbatch,
            g.num_micro,
            g.state_ratio
        );
    }
    println!(
        "\nsimulated iteration: {:.3} s  ->  {:.2} samples/s \
         ({} AllGathers/iter)",
        stats.latency, stats.throughput, stats.ag_count
    );
    println!(
        "predicted by the optimizer's Eqs. 2/3 model: {:.3} s",
        assignment.iter_latency
    );
}
