// Bisect the per-call leak in the PJRT exec path.
use cephalo::runtime::XlaEngine;
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}
fn main() {
    let dir = cephalo::runtime::default_artifacts_dir();
    let engine = XlaEngine::load(&dir, &["grad_step"]).unwrap();
    let params = engine.init_params(1);
    let seq = engine.manifest().model.seq_len;
    let tokens = vec![1i32; seq];
    let targets = vec![2i32; seq];

    // Phase 1: literal creation only (vec1 + reshape), no execute.
    let r0 = rss_mb();
    for _ in 0..20 {
        for p in &params {
            let l = xla::Literal::vec1(p).reshape(&[p.len() as i64]).unwrap();
            std::hint::black_box(&l);
        }
    }
    let r1 = rss_mb();
    println!("literal-only: {:.0} -> {:.0} MB (delta {:.1}/iter)", r0, r1, (r1-r0)/20.0);

    // Phase 2: full grad_step over device-resident params (execute_b).
    engine.set_params(&params).unwrap();
    let r2 = rss_mb();
    for _ in 0..20 {
        let out = engine.grad_step(&tokens, &targets, 1).unwrap();
        std::hint::black_box(&out);
    }
    let r3 = rss_mb();
    println!("grad_step:    {:.0} -> {:.0} MB (delta {:.1}/iter)", r2, r3, (r3-r2)/20.0);
    // Phase 3: set_params churn (per-step upload path).
    let r4 = rss_mb();
    for _ in 0..20 {
        engine.set_params(&params).unwrap();
    }
    let r5 = rss_mb();
    println!("set_params:   {:.0} -> {:.0} MB (delta {:.1}/iter)", r4, r5, (r5-r4)/20.0);
}
