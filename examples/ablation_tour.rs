//! Ablation tour: reproduce the paper's two component studies at
//! example scale —
//!
//! * §4.4 / Fig. 7: FSDP vs Cephalo-CB (compute balancing only) vs
//!   Cephalo-MB (memory balancing only) vs full Cephalo, and
//! * §4.5 / Fig. 8: the gradient-accumulation optimization ladder
//!   (FSDP-GA -> LGA -> +CO -> +S -> +O).
//!
//! ```sh
//! cargo run --release --offline --example ablation_tour
//! ```

use cephalo::cluster::Cluster;
use cephalo::coordinator::Workload;
use cephalo::model::find_model;
use cephalo::optimizer::ablations;
use cephalo::perfmodel::{CollectiveModel, SyntheticOracle};
use cephalo::sim::cephalo::simulate_assignment;
use cephalo::sim::GaVariant;
use cephalo::util::tablefmt::{fmt_throughput, Table};

fn main() {
    // ---- Fig. 7-style ablation on Cluster A / GPT 2.7B ----
    let batch = 128;
    let w = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
        .expect("profile");
    let mut t = Table::new(
        "Compute vs memory balancing (GPT 2.7B, Cluster A, batch 128)",
        &["variant", "samples/s", "note"],
    );
    // Every variant is evaluated on the SAME simulator.
    let variants: Vec<(&str, Result<_, _>, &str)> = vec![
        ("FSDP", ablations::fsdp_even(&w.profile, batch),
         "even everything"),
        ("Cephalo-CB", ablations::compute_balanced_only(&w.profile, batch),
         "compute only"),
        ("Cephalo-MB", ablations::memory_balanced_only(&w.profile, batch),
         "memory only, m=1"),
        ("Cephalo", w.optimize(batch).map(|(a, _)| a), "joint"),
    ];
    for (name, plan, note) in variants {
        match plan {
            Ok(a) => {
                let s = w.simulate(&a, GaVariant::LGA_CO_S_O);
                t.add_row(vec![name.into(), fmt_throughput(s.throughput),
                               note.into()]);
            }
            Err(e) => {
                t.add_row(vec![name.into(), "OOM".into(), e.to_string()]);
            }
        }
    }
    println!("{}", t.render());

    // ---- Fig. 8-style GA ladder on 16xV100 / GPT 6.7B ----
    // 2x p3.16xlarge: 25 Gbps NICs bound the DP ring.
    let cluster = Cluster::homogeneous("V100", 16, 8, 25.0);
    let model = find_model("GPT 6.7B").unwrap();
    let oracle = SyntheticOracle::new(&cluster, &model, 42);
    let coll = CollectiveModel::from_cluster(&cluster);
    // Paper setup: batch 256 = 16 GPUs x 16 microbatches of size 1.
    let asg = cephalo::optimizer::Assignment {
        per_gpu: (0..16)
            .map(|_| cephalo::optimizer::GpuAssign {
                microbatch: 1,
                num_micro: 16,
                state_ratio: 1.0 / 16.0,
            })
            .collect(),
        layer_latency: 0.0,
        iter_latency: 0.0,
    };
    let ladder = [
        ("FSDP-GA", GaVariant::FSDP_GA),
        ("LGA", GaVariant::LGA),
        ("LGA+CO", GaVariant::LGA_CO),
        ("LGA+CO+S", GaVariant::LGA_CO_S),
        ("LGA+CO+S+O", GaVariant::LGA_CO_S_O),
    ];
    let mut t2 = Table::new(
        "Gradient accumulation ladder (GPT 6.7B, 16xV100, batch 256)",
        &["variant", "samples/s", "speedup vs FSDP-GA", "peak mem GB"],
    );
    let base = simulate_assignment(&model, &oracle, &coll, &asg,
                                   GaVariant::FSDP_GA);
    for (name, v) in ladder {
        let s = simulate_assignment(&model, &oracle, &coll, &asg, v);
        let peak = s
            .per_gpu_mem
            .iter()
            .fold(0.0f64, |a, &b| a.max(b));
        t2.add_row(vec![
            name.into(),
            fmt_throughput(s.throughput),
            format!("{:.2}x", base.latency / s.latency),
            format!("{:.1}", peak / 1e9),
        ]);
    }
    println!("{}", t2.render());
}
