"""L1 correctness: Pallas kernels vs pure-jnp reference oracle.

Hypothesis sweeps shapes/dtypes; every comparison is assert_allclose
against ref.py — the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import flash_attention as attn_mod
from compile.kernels import fused_ffn as ffn_mod
from compile.kernels import fused_layernorm as ln_mod
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# attention


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(1, 3),
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([16, 64, 128, 256]),
    head_dim=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(batch, heads, seq, head_dim, seed):
    q = rand(seed, (batch, heads, seq, head_dim))
    k = rand(seed + 1, (batch, heads, seq, head_dim))
    v = rand(seed + 2, (batch, heads, seq, head_dim))
    out = attn_mod.flash_attention(q, k, v)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (64, 32),
                                             (128, 128)])
def test_attention_block_shape_invariance(block_q, block_k):
    """Output must not depend on the tiling schedule."""
    q = rand(7, (2, 2, 128, 16))
    k = rand(8, (2, 2, 128, 16))
    v = rand(9, (2, 2, 128, 16))
    out = attn_mod.flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)


def test_attention_causality():
    """Perturbing future keys/values must not change earlier outputs."""
    q = rand(1, (1, 1, 64, 16))
    k = rand(2, (1, 1, 64, 16))
    v = rand(3, (1, 1, 64, 16))
    base = attn_mod.flash_attention(q, k, v)
    k2 = k.at[:, :, 32:, :].add(100.0)
    v2 = v.at[:, :, 32:, :].add(100.0)
    pert = attn_mod.flash_attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, :32], pert[:, :, :32],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[:, :, 32:], pert[:, :, 32:])


def test_attention_rejects_indivisible_seq():
    q = rand(1, (1, 1, 48, 16))
    with pytest.raises(ValueError):
        attn_mod.flash_attention(q, q, q, block_q=32, block_k=32)


def test_attention_grad_matches_ref_grad():
    q = rand(11, (1, 2, 64, 16))
    k = rand(12, (1, 2, 64, 16))
    v = rand(13, (1, 2, 64, 16))
    g = rand(14, (1, 2, 64, 16))

    def via_kernel(q, k, v):
        return jnp.sum(kernels.attention(q, k, v) * g)

    def via_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v) * g)

    gk = jax.grad(via_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(via_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_attention_softmax_normalization():
    """With v = ones, output must be exactly ones (softmax sums to 1)."""
    q = rand(21, (1, 2, 128, 32))
    k = rand(22, (1, 2, 128, 32))
    v = jnp.ones((1, 2, 128, 32), jnp.float32)
    out = attn_mod.flash_attention(q, k, v)
    np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ffn


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([8, 32, 128, 256]),
    d=st.sampled_from([16, 64, 128]),
    mult=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(rows, d, mult, seed):
    x = rand(seed, (rows, d))
    w1 = rand(seed + 1, (d, d * mult), scale=0.1)
    b1 = rand(seed + 2, (d * mult,), scale=0.1)
    w2 = rand(seed + 3, (d * mult, d), scale=0.1)
    b2 = rand(seed + 4, (d,), scale=0.1)
    out = ffn_mod.fused_ffn(x, w1, b1, w2, b2)
    expect = ref.ffn(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("block_rows", [8, 32, 64, 256])
def test_ffn_block_shape_invariance(block_rows):
    x = rand(5, (256, 32))
    w1 = rand(6, (32, 128), scale=0.1)
    b1 = jnp.zeros(128)
    w2 = rand(7, (128, 32), scale=0.1)
    b2 = jnp.zeros(32)
    out = ffn_mod.fused_ffn(x, w1, b1, w2, b2, block_rows=block_rows)
    expect = ref.ffn(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)


def test_ffn_grad_matches_ref_grad():
    x = rand(31, (64, 32))
    w1 = rand(32, (32, 128), scale=0.1)
    b1 = rand(33, (128,), scale=0.1)
    w2 = rand(34, (128, 32), scale=0.1)
    b2 = rand(35, (32,), scale=0.1)

    def via_kernel(*a):
        return jnp.sum(kernels.ffn(*a) ** 2)

    def via_ref(*a):
        return jnp.sum(ref.ffn(*a) ** 2)

    gk = jax.grad(via_kernel, argnums=tuple(range(5)))(x, w1, b1, w2, b2)
    gr = jax.grad(via_ref, argnums=tuple(range(5)))(x, w1, b1, w2, b2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# layernorm


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([8, 64, 128, 512]),
    d=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, d, seed):
    x = rand(seed, (rows, d), scale=3.0)
    scale = 1.0 + rand(seed + 1, (d,), scale=0.2)
    bias = rand(seed + 2, (d,), scale=0.2)
    out = ln_mod.layernorm_fwd(x, scale, bias)
    expect = ref.layernorm(x, scale, bias)
    np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)


def test_layernorm_output_stats():
    """With unit scale / zero bias, rows are standardized."""
    x = rand(41, (128, 256), scale=5.0)
    out = ln_mod.layernorm_fwd(x, jnp.ones(256), jnp.zeros(256))
    np.testing.assert_allclose(np.mean(out, axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(out, axis=-1), 1.0, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([8, 128, 256]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_pallas_bwd_matches_autodiff(rows, d, seed):
    """The hand-written Pallas backward kernel vs jax autodiff of ref."""
    x = rand(seed, (rows, d), scale=2.0)
    scale = 1.0 + rand(seed + 1, (d,), scale=0.2)
    bias = rand(seed + 2, (d,), scale=0.2)
    g = rand(seed + 3, (rows, d))

    dx, dscale, dbias = ln_mod.layernorm_bwd(x, scale, g)

    def f(x, scale, bias):
        return jnp.sum(ref.layernorm(x, scale, bias) * g)

    ex, escale, ebias = jax.grad(f, argnums=(0, 1, 2))(x, scale, bias)
    np.testing.assert_allclose(dx, ex, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(dscale, escale, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(dbias, ebias, rtol=3e-4, atol=3e-4)


def test_layernorm_bwd_multiblock_accumulation():
    """dscale/dbias accumulate correctly across row-block grid steps."""
    x = rand(51, (256, 32), scale=2.0)
    scale = 1.0 + rand(52, (32,), scale=0.2)
    g = rand(53, (256, 32))
    one_block = ln_mod.layernorm_bwd(x, scale, g, block_rows=256)
    many_block = ln_mod.layernorm_bwd(x, scale, g, block_rows=32)
    for a, b in zip(one_block, many_block):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# VMEM footprint estimators (§Perf inputs) — sanity


def test_attention_vmem_budget():
    fp = attn_mod.vmem_footprint_bytes(64, 64, 512, 64)
    assert fp < 16 * 1024 * 1024


def test_ffn_vmem_budget():
    fp = ffn_mod.vmem_footprint_bytes(128, 1024, 4096, 4)
    # full weight panels for d=1024 are large; must still fit in 16 MiB? No:
    # they exceed VMEM — the estimator must report that honestly.
    assert fp > 16 * 1024 * 1024
    small = ffn_mod.vmem_footprint_bytes(128, 256, 1024, 4)
    assert small < 16 * 1024 * 1024
