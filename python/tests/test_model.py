"""L2 correctness: model shapes, loss semantics, gradient conventions.

The gradient-accumulation and uneven-batch equivalences proved here are
the numerical foundation for the Rust coordinator's Eq.-1 weighting and
layered gradient accumulation: because grad_step returns SUM-loss
gradients, concatenation == addition of shards.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=128, d_model=64, n_layers=2, n_heads=4, seq_len=32)
CFG_REF = M.ModelConfig(vocab=128, d_model=64, n_layers=2, n_heads=4,
                        seq_len=32, use_pallas=False)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def make_batch(seed, b, cfg=CFG):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, cfg.seq_len), 0, cfg.vocab, jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


def test_param_count_matches_formula(params):
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == CFG.num_params()


def test_param_order_covers_all(params):
    assert set(M.PARAM_ORDER) == set(params.keys())
    rt = M.list_to_params(M.params_to_list(params))
    for n in M.PARAM_ORDER:
        assert rt[n] is params[n]


def test_forward_shape(params):
    tokens, _ = make_batch(1, 3)
    logits = M.forward(params, tokens, CFG)
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_near_uniform_at_init(params):
    """At init the model is near-uniform: mean loss ~ ln(vocab)."""
    tokens, targets = make_batch(2, 4)
    ls, cnt = M.loss_sum(params, tokens, targets, CFG)
    mean = float(ls) / float(cnt)
    assert abs(mean - np.log(CFG.vocab)) < 0.2
    assert int(cnt) == 4 * CFG.seq_len


def test_pallas_and_ref_paths_agree(params):
    tokens, targets = make_batch(3, 2)
    lp = M.loss_sum(params, tokens, targets, CFG)[0]
    lr = M.loss_sum(params, tokens, targets, CFG_REF)[0]
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-4)


def test_grad_step_returns_all_params(params):
    tokens, targets = make_batch(4, 2)
    grads, ls, cnt = M.grad_step(params, tokens, targets, CFG)
    assert len(grads) == len(M.PARAM_ORDER)
    shapes = M.param_shapes(CFG)
    for name, g in zip(M.PARAM_ORDER, grads):
        assert g.shape == shapes[name], name
        assert bool(jnp.all(jnp.isfinite(g))), name


def test_gradient_accumulation_equivalence(params):
    """Sum of microbatch gradients == full-batch gradient (sum loss)."""
    tokens, targets = make_batch(5, 4)
    g_full, ls_full, _ = M.grad_step(params, tokens, targets, CFG)
    g_acc = None
    ls_acc = 0.0
    for i in range(4):
        g, ls, _ = M.grad_step(params, tokens[i:i + 1], targets[i:i + 1], CFG)
        ls_acc += float(ls)
        g_acc = g if g_acc is None else [a + b for a, b in zip(g_acc, g)]
    np.testing.assert_allclose(ls_acc, float(ls_full), rtol=1e-4)
    for name, a, b in zip(M.PARAM_ORDER, g_acc, g_full):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4,
                                   err_msg=name)


def test_uneven_split_equivalence(params):
    """Eq. 1: shards of sizes (1, 3) summed == full batch of 4."""
    tokens, targets = make_batch(6, 4)
    g_full, _, _ = M.grad_step(params, tokens, targets, CFG)
    g1, _, _ = M.grad_step(params, tokens[:1], targets[:1], CFG)
    g3, _, _ = M.grad_step(params, tokens[1:], targets[1:], CFG)
    for name, a, b, c in zip(M.PARAM_ORDER, g1, g3, g_full):
        np.testing.assert_allclose(a + b, c, rtol=2e-3, atol=2e-4,
                                   err_msg=name)


def test_grad_descends_loss(params):
    tokens, targets = make_batch(7, 2)
    grads, ls0, cnt = M.grad_step(params, tokens, targets, CFG)
    lr = 0.05
    plist = M.params_to_list(params)
    new = [p - lr * g / float(cnt) for p, g in zip(plist, grads)]
    ls1, _ = M.loss_sum(M.list_to_params(new), tokens, targets, CFG)
    assert float(ls1) < float(ls0)


def test_layer_forward_residual_structure(params):
    """Zeroed attention+ffn weights reduce the layer to identity."""
    x = jax.random.normal(jax.random.PRNGKey(8), (2, CFG.seq_len, CFG.d_model))
    d, dff = CFG.d_model, CFG.d_ff
    zeros = [
        jnp.ones(d), jnp.zeros(d),                      # ln1
        jnp.zeros((d, d)), jnp.zeros((d, d)),           # wq wk
        jnp.zeros((d, d)), jnp.zeros((d, d)),           # wv wo
        jnp.ones(d), jnp.zeros(d),                      # ln2
        jnp.zeros((d, dff)), jnp.zeros(dff),
        jnp.zeros((dff, d)), jnp.zeros(d),
    ]
    y = M.layer_forward(x, tuple(zeros), CFG)
    np.testing.assert_allclose(y, x, atol=1e-5)


def test_make_grad_step_fn_flat_signature(params):
    fn = M.make_grad_step_fn(CFG)
    tokens, targets = make_batch(9, 1)
    out = fn(*M.params_to_list(params), tokens, targets)
    assert len(out) == len(M.PARAM_ORDER) + 2
    grads, ls, cnt = M.grad_step(params, tokens, targets, CFG)
    np.testing.assert_allclose(float(out[-2]), float(ls), rtol=1e-5)
    np.testing.assert_allclose(float(out[-1]), float(cnt))


def test_make_layer_fwd_fn(params):
    fn = M.make_layer_fwd_fn(CFG)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, CFG.seq_len, CFG.d_model))
    layer0 = [params[n][0] for n in M.LAYER_PARAM_NAMES]
    (y,) = fn(x, *layer0)
    assert y.shape == x.shape
    expect = M.layer_forward(x, tuple(layer0), CFG)
    np.testing.assert_allclose(y, expect, rtol=1e-5, atol=1e-5)
