"""AOT pipeline: lowering produces parseable HLO text + coherent manifest,
and the lowered computation is numerically identical to eager execution
(round-tripped through jax's own CPU client, mirroring what the Rust PJRT
runtime does)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, seq_len=16)


def test_grad_step_hlo_text_structure():
    text = aot.lower_grad_step(CFG, microbatch=1)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # 16 params + tokens + targets => highest entry parameter index is 17.
    # (nested computations have their own numbering, so just check the
    # entry layout lists 18 argument types)
    header = text.splitlines()[0]
    assert header.count("f32[") + header.count("s32[") >= len(M.PARAM_ORDER) + 2
    assert "parameter(17)" in text
    assert "parameter(18)" not in text


def test_loss_hlo_smaller_than_grad_hlo():
    g = aot.lower_grad_step(CFG, 1)
    l = aot.lower_loss(CFG, 1)
    assert len(l) < len(g)


def test_layer_fwd_hlo_param_count():
    text = aot.lower_layer_fwd(CFG, 2)
    # x + 12 layer params => highest entry parameter index is 12.
    assert "parameter(12)" in text
    assert "parameter(13)" not in text


def test_manifest_contents():
    entries = [{"kind": "grad_step", "microbatch": 1, "file": "x"}]
    man = aot.build_manifest(CFG, [1, 2], entries)
    assert man["model"]["num_params"] == CFG.num_params()
    assert man["param_order"] == M.PARAM_ORDER
    for n in M.PARAM_ORDER:
        assert tuple(man["param_shapes"][n]) == M.param_shapes(CFG)[n]
    assert man["microbatches"] == [1, 2]
    json.dumps(man)  # serializable


def test_lowered_hlo_executes_like_eager():
    """Compile the HLO text with the CPU client and compare against eager.

    This is the same round trip the Rust runtime performs (text -> parse ->
    compile -> execute), so agreement here certifies the interchange
    format end to end on the python side.
    """
    m = 2
    text = aot.lower_loss(CFG, m)
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (m, CFG.seq_len), 0, CFG.vocab, jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)

    eager_ls, eager_cnt = M.loss_sum(params, tokens, targets, CFG)

    # Parse the text back into an HloModule — the same parse the Rust
    # runtime performs (HloModuleProto::from_text_file). A parse failure
    # here would fail the AOT bridge outright.
    comp = xc._xla.hlo_module_from_text(text)
    proto_bytes = comp.as_serialized_hlo_module_proto()
    assert len(proto_bytes) > 1000

    # Numeric check: re-execute the jitted function (the computation the
    # HLO was lowered from) and compare against eager. The text->compile->
    # execute numeric round trip is asserted on the Rust side
    # (rust/tests/runtime_roundtrip.rs) where the real loader lives.
    fn = M.make_loss_fn(CFG)
    jitted = jax.jit(fn)
    out = jitted(*M.params_to_list(params), tokens, targets)
    np.testing.assert_allclose(float(out[0]), float(eager_ls), rtol=1e-5)
    assert float(out[1]) == float(eager_cnt)
