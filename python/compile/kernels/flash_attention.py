"""Tiled causal flash-attention Pallas kernel (interpret mode).

Hardware adaptation (paper -> TPU, see DESIGN.md §Hardware-Adaptation):
the CUDA flash-attention schedule keeps K/V tiles in shared memory and
iterates thread-blocks over query tiles; here the `BlockSpec` grid plays
the thread-block role — one program instance per (batch*head, q-block),
with the K/V tiles staged through VMEM and the online-softmax running
statistics carried through a `fori_loop`, which is exactly the HBM->VMEM
schedule a real Mosaic lowering would pipeline.

`interpret=True` is mandatory on this CPU-only image: a real TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
Correctness is asserted against `ref.attention` by the pytest suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes. 64 is a multiple of the 8-sublane f32 tile and keeps
# the per-program VMEM footprint small; see EXPERIMENTS.md §Perf for the
# footprint arithmetic.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq: int,
                      scale: float):
    """One program instance: one (batch*head, q-block) pair.

    q_ref: [1, block_q, hd]; k_ref/v_ref: [1, seq, hd] (whole K/V row for
    this batch*head); o_ref: [1, block_q, hd].
    """
    block_q = q_ref.shape[1]
    head_dim = q_ref.shape[2]
    qb = pl.program_id(1)

    q = q_ref[0, :, :] * scale  # [block_q, hd]
    # Global row index of each query in this block.
    q_pos = qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )

    num_kv = pl.cdiv(seq, block_k)

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        k = pl.load(k_ref, (0, pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(j * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(k_pos <= q_pos, s, ref.NEG_INF)

        m_cur = jnp.max(s, axis=-1)  # [bq]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # rescale factor for old stats
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), ref.NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kv, body, (m0, l0, acc0))

    # Causal mask guarantees every row attends to >= 1 key, so l > 0.
    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """Causal flash attention. q,k,v: [batch, heads, seq, head_dim]."""
    batch, heads, seq, head_dim = q.shape
    bq = min(block_q, seq)
    bk = min(block_k, seq)
    if seq % bq != 0 or seq % bk != 0:
        raise ValueError(f"seq={seq} must be divisible by blocks ({bq},{bk})")
    bh = batch * heads
    qf = q.reshape(bh, seq, head_dim)
    kf = k.reshape(bh, seq, head_dim)
    vf = v.reshape(bh, seq, head_dim)

    grid = (bh, seq // bq)
    kernel = functools.partial(
        _attention_kernel,
        block_k=bk,
        seq=seq,
        scale=1.0 / (head_dim ** 0.5),
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, head_dim), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, head_dim), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(batch, heads, seq, head_dim)


def vmem_footprint_bytes(block_q: int, block_k: int, seq: int, head_dim: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated per-program VMEM residency for the §Perf analysis.

    Counts the q block, one k/v tile pair, the f32 accumulator and the
    [bq, bk] score/probability tile. The full-seq K/V rows are *streamed*
    through the tile (pl.dslice loads), so only one tile of each is
    resident at a time in a pipelined Mosaic lowering.
    """
    q_blk = block_q * head_dim * dtype_bytes
    kv_tiles = 2 * block_k * head_dim * dtype_bytes
    acc = block_q * head_dim * 4
    stats = 2 * block_q * 4
    scores = block_q * block_k * 4
    out = block_q * head_dim * dtype_bytes
    return q_blk + kv_tiles + acc + stats + scores + out
