"""Fused LayerNorm Pallas kernels — forward *and* backward (interpret mode).

LayerNorm is the one kernel whose backward we also hand-write as a Pallas
kernel (closed-form VJP), demonstrating the full fwd+bwd kernel path; the
attention/FFN backwards use recompute-from-reference VJPs (see
kernels/__init__.py), matching the paper's activation-checkpointing
strategy of recomputing intra-layer activations in the backward pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128
EPS = 1e-5


def _ln_fwd_kernel(x_ref, scale_ref, bias_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mean) * inv
    o_ref[...] = (xhat * scale_ref[...][None, :] +
                  bias_ref[...][None, :]).astype(o_ref.dtype)


def _ln_bwd_kernel(x_ref, scale_ref, g_ref, dx_ref, dscale_ref, dbias_ref):
    """Closed-form LayerNorm VJP.

    dx = inv/d * (d*gs - sum(gs) - xhat * sum(gs*xhat)) with gs = g*scale.
    dscale/dbias accumulate across the row-block grid: every program writes
    the same output block (index_map -> 0), initialising on the first step.
    """
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)
    d = x.shape[-1]

    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mean) * inv

    gs = g * scale[None, :]
    s1 = jnp.sum(gs, axis=-1, keepdims=True)
    s2 = jnp.sum(gs * xhat, axis=-1, keepdims=True)
    dx = (inv / d) * (d * gs - s1 - xhat * s2)
    dx_ref[...] = dx.astype(dx_ref.dtype)

    part_dscale = jnp.sum(g * xhat, axis=0)
    part_dbias = jnp.sum(g, axis=0)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dscale_ref[...] = jnp.zeros_like(dscale_ref)
        dbias_ref[...] = jnp.zeros_like(dbias_ref)

    dscale_ref[...] += part_dscale.astype(dscale_ref.dtype)
    dbias_ref[...] += part_dbias.astype(dbias_ref.dtype)


def layernorm_fwd(x, scale, bias, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """x: [rows, d] -> [rows, d]."""
    rows, d = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"rows={rows} not divisible by block_rows={br}")
    return pl.pallas_call(
        _ln_fwd_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale, bias)


def layernorm_bwd(x, scale, g, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """Returns (dx, dscale, dbias) for y = layernorm(x, scale, bias)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"rows={rows} not divisible by block_rows={br}")
    return pl.pallas_call(
        _ln_bwd_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((d,), scale.dtype),
            jax.ShapeDtypeStruct((d,), scale.dtype),
        ],
        interpret=interpret,
    )(x, scale, g)
