"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float tolerance)
pure-`jax.numpy` counterpart here. pytest + hypothesis sweep shapes and
dtypes asserting `assert_allclose(kernel(...), ref(...))`; the backward
passes of the wrapped ops are defined as the VJPs of these references
(activation-recompute style), so gradient correctness follows from forward
agreement.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def layernorm(x, scale, bias, eps=1e-5):
    """LayerNorm over the last axis. x: [..., d], scale/bias: [d]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean) * inv * scale + bias


def attention(q, k, v):
    """Causal multi-head attention.

    q, k, v: [batch, heads, seq, head_dim] -> [batch, heads, seq, head_dim].
    Scores are scaled by 1/sqrt(head_dim); the mask is causal
    (position i attends to j <= i).
    """
    head_dim = q.shape[-1]
    seq = q.shape[-2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(head_dim, q.dtype)
    )
    qi = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 1)
    mask = ki <= qi
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def ffn(x, w1, b1, w2, b2):
    """Fused feed-forward: gelu(x @ w1 + b1) @ w2 + b2.

    x: [rows, d], w1: [d, d_ff], b1: [d_ff], w2: [d_ff, d], b2: [d].
    """
    h = jax.nn.gelu(x @ w1 + b1)
    return h @ w2 + b2
