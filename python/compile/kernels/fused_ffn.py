"""Fused feed-forward (GELU MLP) Pallas kernel (interpret mode).

Fuses `gelu(x @ w1 + b1) @ w2 + b2` into a single kernel so the [rows,
d_ff] intermediate never round-trips HBM — the paper's activation-memory
pressure motivates exactly this fusion. The grid tiles the row dimension;
each program instance keeps its row tile, the two weight panels and the
hidden tile in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = h + b1_ref[...][None, :]
    h = jax.nn.gelu(h)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o = o + b2_ref[...][None, :]
    o_ref[...] = o.astype(o_ref.dtype)


def fused_ffn(x, w1, b1, w2, b2, *, block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = True):
    """x: [rows, d] -> [rows, d]; w1: [d, d_ff], w2: [d_ff, d]."""
    rows, d = x.shape
    d_ff = w1.shape[1]
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"rows={rows} must be divisible by block_rows={br}")
    grid = (rows // br,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def vmem_footprint_bytes(block_rows: int, d: int, d_ff: int,
                         dtype_bytes: int = 4) -> int:
    """Per-program VMEM residency estimate for §Perf."""
    x_blk = block_rows * d * dtype_bytes
    weights = (d * d_ff + d_ff * d) * dtype_bytes + (d_ff + d) * dtype_bytes
    hidden = block_rows * d_ff * 4
    out = block_rows * d * dtype_bytes
    return x_blk + weights + hidden + out
