"""L1 — Pallas kernels for the transformer compute hot spots.

Exports differentiable ops backed by the Pallas kernels:

* ``attention(q, k, v)``   — tiled causal flash attention
  (``flash_attention.flash_attention``); backward = recompute-from-reference VJP
  (activation-checkpointing style, matching the paper's recompute-in-
  backward strategy).
* ``ffn(x, w1, b1, w2, b2)`` — fused GELU MLP (``ffn.fused_ffn``); backward
  likewise recomputes via the reference VJP.
* ``layernorm(x, scale, bias)`` — fused LayerNorm with a *hand-written
  Pallas backward kernel* (closed-form VJP).

Each op is wrapped in ``jax.custom_vjp`` so the L2 model differentiates
through the kernels cleanly, and the whole graph still lowers to plain HLO
under ``interpret=True``.
"""

import jax

from . import flash_attention as _attention_mod
from . import fused_ffn as _ffn_mod
from . import fused_layernorm as _layernorm_mod
from . import ref

# ---------------------------------------------------------------------------
# attention


@jax.custom_vjp
def attention(q, k, v):
    """Causal multi-head attention via the flash-attention Pallas kernel."""
    return _attention_mod.flash_attention(q, k, v)


def _attention_fwd(q, k, v):
    return _attention_mod.flash_attention(q, k, v), (q, k, v)


def _attention_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(ref.attention, q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)


# ---------------------------------------------------------------------------
# fused FFN


@jax.custom_vjp
def ffn(x, w1, b1, w2, b2):
    """Fused gelu(x@w1+b1)@w2+b2 via the Pallas FFN kernel."""
    return _ffn_mod.fused_ffn(x, w1, b1, w2, b2)


def _ffn_fwd(x, w1, b1, w2, b2):
    return _ffn_mod.fused_ffn(x, w1, b1, w2, b2), (x, w1, b1, w2, b2)


def _ffn_bwd(res, g):
    x, w1, b1, w2, b2 = res
    _, vjp = jax.vjp(ref.ffn, x, w1, b1, w2, b2)
    return vjp(g)


ffn.defvjp(_ffn_fwd, _ffn_bwd)


# ---------------------------------------------------------------------------
# layernorm (Pallas forward AND backward)


@jax.custom_vjp
def layernorm(x, scale, bias):
    """LayerNorm over the last axis via the Pallas kernel. x: [rows, d]."""
    return _layernorm_mod.layernorm_fwd(x, scale, bias)


def _layernorm_fwd(x, scale, bias):
    return _layernorm_mod.layernorm_fwd(x, scale, bias), (x, scale)


def _layernorm_bwd(res, g):
    x, scale = res
    dx, dscale, dbias = _layernorm_mod.layernorm_bwd(x, scale, g)
    return dx, dscale, dbias


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
