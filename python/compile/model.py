"""L2 — decoder-only transformer (forward/backward) in JAX.

The model is the paper's training workload: a sequence of identical
transformer layers (pre-LN attention + FFN blocks), embedding in, LM head
out, causal cross-entropy loss. Layer parameters are *stacked* along a
leading layer axis and the layer loop is a ``jax.lax.scan``, which keeps
the lowered HLO compact and maps directly onto the paper's "transformer
layers are identical" assumption (each scan step == one FSDP unit).

The hot spots call the L1 Pallas kernels (``kernels.attention``,
``kernels.ffn``, ``kernels.layernorm``); everything lowers to plain HLO
via interpret mode, executed from Rust through PJRT.

Gradient conventions (chosen for the Rust coordinator):
* ``grad_step`` returns gradients of the **sum** of token losses (not the
  mean). Summed gradients make layered gradient accumulation and Eq. 1's
  uneven-batch weighting exact: the leader just adds shard contributions
  and scales once by 1/(global token count).
* Losses are returned as (loss_sum, token_count) so the leader can report
  the exact global mean loss.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static transformer hyperparameters (fixed at AOT time)."""

    vocab: int = 1024
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    seq_len: int = 128
    ff_mult: int = 4
    use_pallas: bool = True

    @property
    def d_ff(self) -> int:
        return self.d_model * self.ff_mult

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def num_params(self) -> int:
        d, dff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        per_layer = 4 * d * d + d * dff + dff + dff * d + d + 4 * d
        return V * d + L * per_layer + 2 * d + d * V


# Parameter order is the ABI between python and rust: aot.py writes it to
# artifacts/manifest.json and rust/src/runtime/artifacts.rs re-reads it.
PARAM_ORDER: List[str] = [
    "embed",      # [V, d]
    "ln1_scale",  # [L, d]
    "ln1_bias",   # [L, d]
    "wq",         # [L, d, d]
    "wk",         # [L, d, d]
    "wv",         # [L, d, d]
    "wo",         # [L, d, d]
    "ln2_scale",  # [L, d]
    "ln2_bias",   # [L, d]
    "w1",         # [L, d, d_ff]
    "b1",         # [L, d_ff]
    "w2",         # [L, d_ff, d]
    "b2",         # [L, d]
    "lnf_scale",  # [d]
    "lnf_bias",   # [d]
    "wout",       # [d, V]
]


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, dff, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    return {
        "embed": (V, d),
        "ln1_scale": (L, d),
        "ln1_bias": (L, d),
        "wq": (L, d, d),
        "wk": (L, d, d),
        "wv": (L, d, d),
        "wo": (L, d, d),
        "ln2_scale": (L, d),
        "ln2_bias": (L, d),
        "w1": (L, d, dff),
        "b1": (L, dff),
        "w2": (L, dff, d),
        "b2": (L, d),
        "lnf_scale": (d,),
        "lnf_bias": (d,),
        "wout": (d, V),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """GPT-2-style init: normal(0, 0.02) weights, ones/zeros for LN/bias."""
    shapes = param_shapes(cfg)
    params = {}
    for i, name in enumerate(PARAM_ORDER):
        sub = jax.random.fold_in(key, i)
        shape = shapes[name]
        if "scale" in name:
            params[name] = jnp.ones(shape, jnp.float32)
        elif "bias" in name or name in ("b1", "b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return params


def params_to_list(params: Dict[str, jax.Array]) -> List[jax.Array]:
    return [params[name] for name in PARAM_ORDER]


def list_to_params(flat) -> Dict[str, jax.Array]:
    return dict(zip(PARAM_ORDER, flat))


# ---------------------------------------------------------------------------
# Forward pass


def _ln(x2d, scale, bias, use_pallas):
    if use_pallas:
        return kernels.layernorm(x2d, scale, bias)
    return kref.layernorm(x2d, scale, bias)


def _attn(q, k, v, use_pallas):
    if use_pallas:
        return kernels.attention(q, k, v)
    return kref.attention(q, k, v)


def _ffn(x2d, w1, b1, w2, b2, use_pallas):
    if use_pallas:
        return kernels.ffn(x2d, w1, b1, w2, b2)
    return kref.ffn(x2d, w1, b1, w2, b2)


LAYER_PARAM_NAMES = (
    "ln1_scale", "ln1_bias", "wq", "wk", "wv", "wo",
    "ln2_scale", "ln2_bias", "w1", "b1", "w2", "b2",
)


def layer_forward(x, layer_params, cfg: ModelConfig):
    """One transformer layer. x: [b, s, d] -> [b, s, d].

    Pre-LN: x + attn(ln1(x)); then x + ffn(ln2(x)). This function is both
    the scan body and the unit profiled for the Fig.-5 latency model.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    hd = cfg.head_dim
    up = cfg.use_pallas
    (ln1_s, ln1_b, wq, wk, wv, wo, ln2_s, ln2_b, w1, b1, w2, b2) = layer_params

    x2d = x.reshape(b * s, d)
    a_in = _ln(x2d, ln1_s, ln1_b, up)
    q = (a_in @ wq).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (a_in @ wk).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (a_in @ wv).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = _attn(q, k, v, up)
    att = att.transpose(0, 2, 1, 3).reshape(b * s, d)
    x2d = x2d + att @ wo

    f_in = _ln(x2d, ln2_s, ln2_b, up)
    x2d = x2d + _ffn(f_in, w1, b1, w2, b2, up)
    return x2d.reshape(b, s, d)


def forward(params: Dict[str, jax.Array], tokens, cfg: ModelConfig):
    """tokens: [b, s] int32 -> logits [b, s, V]."""
    x = params["embed"][tokens]  # [b, s, d]

    stacked = tuple(params[n] for n in LAYER_PARAM_NAMES)

    def body(x, layer_params):
        return layer_forward(x, layer_params, cfg), None

    x, _ = jax.lax.scan(body, x, stacked)

    b, s, d = x.shape
    x2d = _ln(x.reshape(b * s, d), params["lnf_scale"], params["lnf_bias"],
              cfg.use_pallas)
    logits = x2d @ params["wout"]
    return logits.reshape(b, s, cfg.vocab)


def loss_sum(params, tokens, targets, cfg: ModelConfig):
    """Cross-entropy summed over all tokens. Returns (loss_sum, count)."""
    logits = forward(params, tokens, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)  # [b, s]
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    losses = logz - tgt_logit
    return jnp.sum(losses), jnp.asarray(losses.size, jnp.float32)


def grad_step(params, tokens, targets, cfg: ModelConfig):
    """Sum-loss gradients for one microbatch.

    Returns (grads in PARAM_ORDER, loss_sum, token_count).
    """

    def f(plist):
        ls, cnt = loss_sum(list_to_params(plist), tokens, targets, cfg)
        return ls, cnt

    (ls, cnt), grads = jax.value_and_grad(f, has_aux=True)(
        params_to_list(params)
    )
    return grads, ls, cnt


def make_grad_step_fn(cfg: ModelConfig):
    """The AOT entry point: flat-arg function for jax.jit().lower().

    Signature: (p_0, ..., p_15, tokens, targets) ->
               (g_0, ..., g_15, loss_sum, token_count).
    """

    def fn(*args):
        plist = list(args[: len(PARAM_ORDER)])
        tokens, targets = args[len(PARAM_ORDER)], args[len(PARAM_ORDER) + 1]
        grads, ls, cnt = grad_step(list_to_params(plist), tokens, targets, cfg)
        return tuple(grads) + (ls, cnt)

    return fn


def make_loss_fn(cfg: ModelConfig):
    """Flat-arg forward-only loss (for eval and profiling)."""

    def fn(*args):
        plist = list(args[: len(PARAM_ORDER)])
        tokens, targets = args[len(PARAM_ORDER)], args[len(PARAM_ORDER) + 1]
        ls, cnt = loss_sum(list_to_params(plist), tokens, targets, cfg)
        return (ls, cnt)

    return fn


def make_layer_fwd_fn(cfg: ModelConfig):
    """Single-layer forward (x, 12 layer params) -> y — the Fig.-5
    profiling unit loaded by rust's profiler."""

    def fn(x, *layer_params):
        return (layer_forward(x, tuple(layer_params), cfg),)

    return fn


def layer_param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Shapes of one (unstacked) layer's params, in layer_forward order."""
    d, dff = cfg.d_model, cfg.d_ff
    return [
        ("ln1_scale", (d,)),
        ("ln1_bias", (d,)),
        ("wq", (d, d)),
        ("wk", (d, d)),
        ("wv", (d, d)),
        ("wo", (d, d)),
        ("ln2_scale", (d,)),
        ("ln2_bias", (d,)),
        ("w1", (d, dff)),
        ("b1", (dff,)),
        ("w2", (dff, d)),
        ("b2", (d,)),
    ]
