"""AOT lowering: JAX -> HLO *text* artifacts for the Rust runtime.

Emits HLO text (NOT ``lowered.compile().serialize()``): jax >= 0.5 writes
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (under ``artifacts/``):
* ``grad_step_m{m}.hlo.txt``  — per-microbatch sum-loss gradient step, one
  per configured microbatch size (one compiled executable per variant, as
  the runtime contract requires).
* ``loss_m{m}.hlo.txt``       — forward-only loss for eval.
* ``layer_fwd_m{m}.hlo.txt``  — single transformer layer forward, the
  profiling unit for the Fig.-5 compute-latency model.
* ``manifest.json``           — model config, parameter order/shapes, the
  list of emitted entry points. The ABI consumed by
  ``rust/src/runtime/artifacts.rs``.

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    PARAM_ORDER,
    layer_param_shapes,
    make_grad_step_fn,
    make_layer_fwd_fn,
    make_loss_fn,
    param_shapes,
)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True,
    so the Rust side unwraps with to_tuple())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Sequence[int], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_grad_step(cfg: ModelConfig, microbatch: int) -> str:
    fn = make_grad_step_fn(cfg)
    shapes = param_shapes(cfg)
    args = [_spec(shapes[n]) for n in PARAM_ORDER]
    args.append(_spec((microbatch, cfg.seq_len), jnp.int32))  # tokens
    args.append(_spec((microbatch, cfg.seq_len), jnp.int32))  # targets
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_loss(cfg: ModelConfig, microbatch: int) -> str:
    fn = make_loss_fn(cfg)
    shapes = param_shapes(cfg)
    args = [_spec(shapes[n]) for n in PARAM_ORDER]
    args.append(_spec((microbatch, cfg.seq_len), jnp.int32))
    args.append(_spec((microbatch, cfg.seq_len), jnp.int32))
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_layer_fwd(cfg: ModelConfig, microbatch: int) -> str:
    fn = make_layer_fwd_fn(cfg)
    args = [_spec((microbatch, cfg.seq_len, cfg.d_model))]
    args += [_spec(s) for _, s in layer_param_shapes(cfg)]
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_manifest(cfg: ModelConfig, microbatches: List[int],
                   entries: List[dict]) -> dict:
    shapes = param_shapes(cfg)
    return {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "seq_len": cfg.seq_len,
            "d_ff": cfg.d_ff,
            "use_pallas": cfg.use_pallas,
            "num_params": cfg.num_params(),
        },
        "param_order": PARAM_ORDER,
        "param_shapes": {n: list(shapes[n]) for n in PARAM_ORDER},
        "layer_param_shapes": [
            {"name": n, "shape": list(s)} for n, s in layer_param_shapes(cfg)
        ],
        "microbatches": microbatches,
        "entries": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", default="1,2,4",
                    help="comma-separated microbatch sizes to AOT-compile")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead of the "
                         "Pallas kernels (same numerics; faster CPU exec)")
    args = ap.parse_args()

    cfg = ModelConfig(
        vocab=args.vocab,
        d_model=args.d_model,
        n_layers=args.layers,
        n_heads=args.heads,
        seq_len=args.seq_len,
        use_pallas=not args.no_pallas,
    )
    microbatches = sorted({int(x) for x in args.microbatches.split(",")})
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for m in microbatches:
        for kind, lower in (
            ("grad_step", lower_grad_step),
            ("loss", lower_loss),
            ("layer_fwd", lower_layer_fwd),
        ):
            name = f"{kind}_m{m}.hlo.txt"
            path = os.path.join(args.out_dir, name)
            text = lower(cfg, m)
            with open(path, "w") as f:
                f.write(text)
            entries.append({"kind": kind, "microbatch": m, "file": name})
            print(f"wrote {path} ({len(text)} chars)")

    manifest = build_manifest(cfg, microbatches, entries)
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}: {cfg.num_params()} params, "
          f"microbatches={microbatches}, pallas={cfg.use_pallas}")


if __name__ == "__main__":
    main()
