//! Real numeric training engine (the paper's Trainer, §3.3).
//!
//! N worker threads stand in for the cluster's GPUs. Each worker owns a
//! batch share `b_i` (compute division) and a training-state shard
//! `r_i` (memory division) — the decoupling that *is* Cephalo. Per step:
//!
//! 1. the leader samples a global batch and splits it `b_i`-wise;
//! 2. every worker runs its microbatches through the AOT-compiled JAX
//!    grad step (PJRT), accumulating SUM-loss gradients — numerically
//!    identical to layered gradient accumulation (addition commutes);
//! 3. gradients are combined with a real uneven ReduceScatter
//!    (`collectives::ring_reduce_scatter` over the `r_i` shard layout)
//!    and scaled once by 1/(global token count) — Eq. 1 exactly;
//! 4. each worker applies sharded Adam to its own state shard;
//! 5. an uneven AllGather rebuilds the full parameter vector.
//!
//! Python never runs here: the grad step is the HLO artifact produced at
//! build time.

pub mod adam;
pub mod checkpoint;
pub mod data;

#[cfg(feature = "xla")]
use std::path::Path;
#[cfg(feature = "xla")]
use std::sync::Arc;

#[cfg(feature = "xla")]
use crate::util::error::{anyhow, Result};

// Hot path uses the direct collectives (single-pass, no per-ring-step
// copies); the segmented-ring implementations are property-tested
// equivalent (collectives::tests) and exercised by the Fig.-12 bench.
#[cfg(feature = "xla")]
use crate::collectives::{direct_allgather, direct_reduce_scatter};
#[cfg(feature = "xla")]
use crate::optimizer::Assignment;
use crate::runtime::Manifest;
#[cfg(feature = "xla")]
use crate::runtime::ExecService;
#[cfg(feature = "xla")]
use crate::sharding::ShardLayout;
use adam::AdamConfig;
#[cfg(feature = "xla")]
use adam::AdamShard;
#[cfg(feature = "xla")]
use data::Corpus;

/// One worker's static role.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Per-step batch share b_i (rows).
    pub batch: usize,
    /// Training-state ratio r_i.
    pub state_ratio: f64,
    /// Label for logs (GPU name in the simulated cluster).
    pub name: String,
}

/// Training-loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub seed: u64,
    pub adam: AdamConfig,
    /// Markov-corpus branching factor (lower = easier).
    pub corpus_branch: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 100,
            seed: 42,
            adam: AdamConfig::default(),
            corpus_branch: 4,
            log_every: 10,
        }
    }
}

/// Per-step outcome.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    pub mean_loss: f64,
    pub tokens: f64,
    pub wall_seconds: f64,
}

#[cfg(feature = "xla")]
pub struct Trainer {
    service: ExecService,
    workers: Vec<WorkerSpec>,
    cfg: TrainConfig,
    /// Leader's full parameter copy, one flat vec per tensor.
    params: Vec<Vec<f32>>,
    /// Tensor sizes (manifest order) for flatten/unflatten.
    sizes: Vec<usize>,
    /// Shard layout over the flat parameter vector (by r_i).
    layout: ShardLayout,
    shards: Vec<AdamShard>,
    corpus: Corpus,
    pub history: Vec<StepStats>,
}

#[cfg(feature = "xla")]
impl Trainer {
    /// Build from explicit worker specs.
    pub fn new(
        artifacts_dir: &Path,
        workers: Vec<WorkerSpec>,
        cfg: TrainConfig,
    ) -> Result<Trainer> {
        if workers.is_empty() {
            return Err(anyhow!("need at least one worker"));
        }
        let service = ExecService::start(artifacts_dir, &["grad_step",
                                                          "loss"])?;
        let manifest = service.manifest().clone();
        let sizes = manifest.param_sizes();
        let flat_len: usize = sizes.iter().sum();
        let ratios: Vec<f64> =
            workers.iter().map(|w| w.state_ratio.max(0.0)).collect();
        let layout = ShardLayout::by_ratios(flat_len, &ratios);
        let shards = (0..workers.len())
            .map(|r| AdamShard::new(layout.size(r), cfg.adam))
            .collect();
        let corpus =
            Corpus::new(manifest.model.vocab, cfg.corpus_branch, cfg.seed);
        // Parameter init on the engine side (shared PRNG).
        let params = {
            // init through a temporary engine call path: the service owns
            // the engine; replicate init here using manifest shapes.
            init_params(&manifest, cfg.seed)
        };
        Ok(Trainer {
            service,
            workers,
            cfg,
            params,
            sizes,
            layout,
            shards,
            corpus,
            history: Vec::new(),
        })
    }

    /// Build worker specs from a Cephalo `Assignment` and cluster GPU
    /// names.
    pub fn workers_from_assignment(
        asg: &Assignment,
        names: &[String],
    ) -> Vec<WorkerSpec> {
        asg.per_gpu
            .iter()
            .enumerate()
            .map(|(i, g)| WorkerSpec {
                batch: g.batch(),
                state_ratio: g.state_ratio,
                name: names
                    .get(i)
                    .cloned()
                    .unwrap_or_else(|| format!("gpu{i}")),
            })
            .collect()
    }

    pub fn manifest(&self) -> &Manifest {
        self.service.manifest()
    }

    pub fn global_batch(&self) -> usize {
        self.workers.iter().map(|w| w.batch).sum()
    }

    pub fn corpus_entropy(&self) -> f64 {
        self.corpus.entropy()
    }

    /// Run one training step; returns the global mean loss.
    pub fn step(&mut self, step_idx: usize) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        let manifest = self.service.manifest().clone();
        let seq = manifest.model.seq_len;
        let b = self.global_batch();
        let (tokens, targets) = self.corpus.sample_batch(b, seq);
        let sizes: Vec<usize> =
            self.workers.iter().map(|w| w.batch).collect();
        let parts = data::split_batch(&tokens, &targets, seq, &sizes);

        // Upload the step's parameters to the device once; workers then
        // run microbatches against the device-resident copy.
        let snapshot = Arc::new(self.params.clone());
        let handle = self.service.handle();
        handle.set_params(Arc::clone(&snapshot))?;

        // Workers: microbatch loops, local gradient accumulation.
        let flat_len: usize = self.sizes.iter().sum();
        let mut worker_grads: Vec<Vec<f32>> = Vec::new();
        let mut loss_sum = 0f64;
        let mut token_count = 0f64;
        let results: Vec<Result<(Vec<f32>, f64, f64)>> =
            std::thread::scope(|scope| {
                let mut joins = Vec::new();
                for (w, (wtokens, wtargets)) in
                    self.workers.iter().zip(parts.into_iter())
                {
                    let handle = handle.clone();
                    let manifest = manifest.clone();
                    let sizes = self.sizes.clone();
                    let batch = w.batch;
                    joins.push(scope.spawn(move || {
                        worker_grad_pass(
                            &handle, &manifest, &sizes, &wtokens,
                            &wtargets, batch, flat_len,
                        )
                    }));
                }
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
        for r in results {
            let (g, ls, cnt) = r?;
            worker_grads.push(g);
            loss_sum += ls;
            token_count += cnt;
        }

        // Uneven ReduceScatter of gradients onto the state shards, then
        // the Eq.-1 scale by 1/(global token count).
        let mut grad_shards =
            direct_reduce_scatter(&worker_grads, &self.layout);
        let inv = 1.0 / token_count as f32;
        for shard in grad_shards.iter_mut() {
            for g in shard.iter_mut() {
                *g *= inv;
            }
        }

        // Sharded Adam in parallel, on a flattened parameter copy.
        let mut flat = flatten(&self.params, flat_len);
        {
            let layout = &self.layout;
            let mut param_slices: Vec<&mut [f32]> = Vec::new();
            let mut rest: &mut [f32] = &mut flat;
            let mut consumed = 0usize;
            for r in 0..self.workers.len() {
                let range = layout.range(r);
                let (head, tail) = rest.split_at_mut(range.len());
                debug_assert_eq!(range.start, consumed);
                consumed += range.len();
                param_slices.push(head);
                rest = tail;
            }
            std::thread::scope(|scope| {
                for ((shard, grads), pslice) in self
                    .shards
                    .iter_mut()
                    .zip(&grad_shards)
                    .zip(param_slices.into_iter())
                {
                    scope.spawn(move || shard.update(pslice, grads));
                }
            });
        }

        // AllGather rebuilds the full parameter vector on all ranks
        // (leader keeps one canonical copy).
        let shard_views: Vec<Vec<f32>> = (0..self.workers.len())
            .map(|r| flat[self.layout.range(r)].to_vec())
            .collect();
        let gathered = direct_allgather(&shard_views, &self.layout);
        self.params = unflatten(&gathered, &self.sizes);

        let stats = StepStats {
            step: step_idx,
            mean_loss: loss_sum / token_count,
            tokens: token_count,
            wall_seconds: t0.elapsed().as_secs_f64(),
        };
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Run the configured number of steps, logging every `log_every`.
    pub fn run(&mut self) -> Result<Vec<StepStats>> {
        for s in 0..self.cfg.steps {
            let stats = self.step(s)?;
            if self.cfg.log_every > 0 && s % self.cfg.log_every == 0 {
                crate::info!(
                    "step {:>5}  loss {:.4}  ({:.2}s, {} tokens)",
                    s,
                    stats.mean_loss,
                    stats.wall_seconds,
                    stats.tokens
                );
            }
        }
        Ok(self.history.clone())
    }

    /// Evaluate mean loss on fresh batches (no update).
    pub fn eval_loss(&mut self, batches: usize) -> Result<f64> {
        let manifest = self.service.manifest().clone();
        let seq = manifest.model.seq_len;
        let m = *manifest.microbatches.iter().max().unwrap();
        let snapshot = Arc::new(self.params.clone());
        let handle = self.service.handle();
        handle.set_params(snapshot)?;
        let mut total = 0f64;
        let mut count = 0f64;
        for _ in 0..batches {
            let (tokens, targets) = self.corpus.sample_batch(m, seq);
            let (ls, cnt) = handle.loss(tokens, targets, m)?;
            total += ls as f64;
            count += cnt as f64;
        }
        Ok(total / count)
    }

    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Per-worker training-state bytes (the 16 B/param split by r_i) —
    /// for memory reports.
    pub fn state_bytes_per_worker(&self) -> Vec<usize> {
        (0..self.workers.len())
            .map(|r| self.layout.size(r) * 16)
            .collect()
    }

    /// Assemble a leader-view checkpoint (full params + gathered Adam
    /// moments over the flat parameter space).
    pub fn checkpoint(&self) -> checkpoint::Checkpoint {
        let flat_len: usize = self.sizes.iter().sum();
        let mut adam_m = vec![0f32; flat_len];
        let mut adam_v = vec![0f32; flat_len];
        let mut step = 0u64;
        for (r, shard) in self.shards.iter().enumerate() {
            let range = self.layout.range(r);
            adam_m[range.clone()].copy_from_slice(&shard.m);
            adam_v[range].copy_from_slice(&shard.v);
            step = step.max(shard.step);
        }
        checkpoint::Checkpoint {
            step,
            params: self.params.clone(),
            adam_m,
            adam_v,
        }
    }

    /// Restore params + optimizer state from a checkpoint. The shard
    /// layout may differ from the one the checkpoint was written under —
    /// exactly the elastic-replan resume path
    /// (`coordinator::elastic`).
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        ck.validate()?;
        let sizes: Vec<usize> = ck.params.iter().map(Vec::len).collect();
        if sizes != self.sizes {
            return Err(anyhow!(
                "checkpoint tensor sizes do not match the artifacts"
            ));
        }
        self.params = ck.params.clone();
        for (r, shard) in self.shards.iter_mut().enumerate() {
            let range = self.layout.range(r);
            shard.m.copy_from_slice(&ck.adam_m[range.clone()]);
            shard.v.copy_from_slice(&ck.adam_v[range]);
            shard.step = ck.step;
        }
        Ok(())
    }
}

/// One worker's full pass: decompose the batch into available
/// microbatch sizes, run grad steps, sum gradients into a flat vector.
#[cfg(feature = "xla")]
#[allow(clippy::too_many_arguments)]
fn worker_grad_pass(
    handle: &crate::runtime::ExecHandle,
    manifest: &Manifest,
    sizes: &[usize],
    tokens: &[i32],
    targets: &[i32],
    batch: usize,
    flat_len: usize,
) -> Result<(Vec<f32>, f64, f64)> {
    let seq = manifest.model.seq_len;
    let mut flat_grad = vec![0f32; flat_len];
    let mut loss_sum = 0f64;
    let mut token_count = 0f64;
    let mut row = 0usize;
    for m in manifest.decompose_batch(batch) {
        let lo = row * seq;
        let hi = (row + m) * seq;
        let out = handle.grad_step(
            tokens[lo..hi].to_vec(),
            targets[lo..hi].to_vec(),
            m,
        )?;
        // Accumulate (sum-loss gradients add exactly).
        let mut off = 0usize;
        for (g, &sz) in out.grads.iter().zip(sizes) {
            debug_assert_eq!(g.len(), sz);
            for (acc, v) in flat_grad[off..off + sz].iter_mut().zip(g) {
                *acc += v;
            }
            off += sz;
        }
        loss_sum += out.loss_sum as f64;
        token_count += out.token_count as f64;
        row += m;
    }
    debug_assert_eq!(row, batch);
    Ok((flat_grad, loss_sum, token_count))
}

/// Leader-side parameter init matching `XlaEngine::init_params`.
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = crate::util::prng::Rng::new(seed);
    manifest
        .param_order
        .iter()
        .zip(&manifest.param_shapes)
        .map(|(name, shape)| {
            let nelem: usize = shape.iter().product();
            if name.contains("scale") {
                vec![1.0; nelem]
            } else if name.contains("bias") || name == "b1" || name == "b2"
            {
                vec![0.0; nelem]
            } else {
                let mut v = vec![0f32; nelem];
                rng.fill_normal(&mut v, 0.02);
                v
            }
        })
        .collect()
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn flatten(tensors: &[Vec<f32>], flat_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(flat_len);
    for t in tensors {
        out.extend_from_slice(t);
    }
    out
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn unflatten(flat: &[f32], sizes: &[usize]) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0usize;
    for &sz in sizes {
        out.push(flat[off..off + sz].to_vec());
        off += sz;
    }
    debug_assert_eq!(off, flat.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn flatten_roundtrip() {
        let tensors = vec![vec![1.0f32, 2.0], vec![3.0], vec![4.0, 5.0, 6.0]];
        let sizes = vec![2usize, 1, 3];
        let flat = flatten(&tensors, 6);
        assert_eq!(flat, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(unflatten(&flat, &sizes), tensors);
    }

    #[test]
    fn init_params_shapes() {
        let manifest = Manifest::parse(
            Path::new("/tmp"),
            r#"{
                "model": {"vocab": 8, "d_model": 4, "n_layers": 1,
                          "n_heads": 1, "seq_len": 4, "d_ff": 16,
                          "use_pallas": true, "num_params": 100},
                "param_order": ["embed", "ln1_scale", "b1"],
                "param_shapes": {"embed": [8, 4], "ln1_scale": [1, 4],
                                  "b1": [1, 16]},
                "microbatches": [1],
                "entries": []
            }"#,
        )
        .unwrap();
        let p = init_params(&manifest, 1);
        assert_eq!(p[0].len(), 32);
        assert!(p[1].iter().all(|&x| x == 1.0)); // scale -> ones
        assert!(p[2].iter().all(|&x| x == 0.0)); // b1 -> zeros
        assert!(p[0].iter().any(|&x| x != 0.0)); // embed -> random
        // Deterministic.
        assert_eq!(init_params(&manifest, 1)[0], p[0]);
    }
}
