//! Real numeric training engine (the paper's Trainer, §3.3), generic
//! over the execution backend.
//!
//! N workers stand in for the cluster's GPUs. Each worker owns a batch
//! share `b_i` (compute division) and a training-state shard `r_i`
//! (memory division) — the decoupling that *is* Cephalo. Per step:
//!
//! 1. the leader samples a global batch and splits it `b_i`-wise;
//! 2. the [`crate::exec::StepExecutor`] backend runs every worker's
//!    share and returns per-worker SUM-loss gradients — numerically
//!    identical to layered gradient accumulation (addition commutes);
//! 3. gradients are combined with a real uneven ReduceScatter over the
//!    `r_i` shard layout, routed through the pluggable
//!    [`comm::CollectiveEngine`] — in-process rings by default, a
//!    [`crate::transport::Transport`] fabric (channels or TCP sockets)
//!    via [`Trainer::with_comm`] — and scaled once by 1/(global token
//!    count), Eq. 1 exactly;
//! 4. each worker applies sharded Adam to its own state shard;
//! 5. an uneven `ring_allgather` rebuilds the full parameter vector.
//!
//! **Parameter residency** ([`TrainConfig::shard_params`]): by default
//! the trainer keeps the historical leader-resident full weight copy
//! (step 5 rebuilds it every step). With `shard_params = true` the
//! weights shard exactly like the Adam moments: each rank holds only
//! its `r_i` slice, the step MATERIALIZES the full weights with the
//! same ring AllGather (moved from the step's tail to its head) and
//! frees them when the step ends, and the optimizer updates the local
//! slice in place. Per-rank parameter bytes then scale with `r_i`
//! (DESIGN.md invariant 11); the full vector is only assembled on
//! explicit export ([`Trainer::gather_params`], checkpoints). Both
//! residencies produce BITWISE-identical trajectories: gathering the
//! shards at step start reproduces, bit for bit, the full vector the
//! leader-resident path carried over from the previous step's tail
//! AllGather.
//!
//! The pipeline itself (this file) is backend-agnostic and always
//! compiled: `cephalo train --backend native` drives it with the
//! dependency-free `exec::NativeExecutor`, and the elastic session
//! swaps worker memberships mid-run via [`Trainer::adopt`]. Only the
//! PJRT backend (`exec::PjrtExecutor`, reachable through
//! [`Trainer::new`]) stays behind the `xla` feature.

pub mod adam;
pub mod checkpoint;
pub mod comm;
pub mod data;

use crate::exec::StepExecutor;
use crate::optimizer::Assignment;
use crate::runtime::Manifest;
use crate::sharding::{ShardLayout, UnitLayout};
use crate::telemetry::{self, PhaseBreakdown};
use crate::util::error::{anyhow, Result};
use adam::{AdamConfig, AdamShard};
use comm::{CollectiveEngine, InProcessRing};
use data::Corpus;

/// One worker's static role.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Per-step batch share b_i (rows).
    pub batch: usize,
    /// Training-state ratio r_i.
    pub state_ratio: f64,
    /// Label for logs (GPU name in the simulated cluster).
    pub name: String,
}

/// Training-loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub seed: u64,
    pub adam: AdamConfig,
    /// Markov-corpus branching factor (lower = easier).
    pub corpus_branch: usize,
    pub log_every: usize,
    /// Fully-sharded parameters: drop the leader-resident weight copy
    /// and keep only per-rank `r_i` slices, gathering full weights
    /// transiently per step (see the module docs). Bitwise-identical
    /// to the default leader-resident mode.
    pub shard_params: bool,
    /// Number of FSDP units to cut the executor's shardable parameter
    /// prefix into (`<= 1` = whole-model gather). Only meaningful with
    /// `shard_params` on an executor that supports unit-pipelined
    /// execution: the step then materializes one unit at a time (plus
    /// the prefetched next unit and the resident tail) instead of the
    /// full weights, so transient parameter memory scales with the
    /// LARGEST UNIT, not the total parameter count. Bitwise-identical
    /// to whole-model gather (DESIGN.md invariant 13).
    pub fsdp_units: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 100,
            seed: 42,
            adam: AdamConfig::default(),
            corpus_branch: 4,
            log_every: 10,
            shard_params: false,
            fsdp_units: 1,
        }
    }
}

/// Per-step outcome.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    pub mean_loss: f64,
    pub tokens: f64,
    /// Step duration as reported by the executor's timing hook
    /// (`StepExecutor::step_seconds`): wall time for real backends,
    /// modeled time for simulation-backed ones. This is the number
    /// logs and per-event reports must quote.
    pub wall_seconds: f64,
    /// The actually measured wall time of the step, regardless of any
    /// attached timing model — kept separate so simulated steps/sec
    /// and executed steps/sec can never be conflated.
    pub measured_seconds: f64,
    /// Per-phase wall breakdown of `measured_seconds` (gather /
    /// compute / reduce-scatter / overlap-wait / optimizer). Measured
    /// UNCONDITIONALLY — on the wire this rides every STEP reply
    /// whether or not tracing is on, so telemetry can never change
    /// wire behavior (DESIGN.md invariant 14).
    pub phases: crate::telemetry::PhaseBreakdown,
}

/// Where the fp32 weights live between steps.
enum ParamStore {
    /// Historical default: the leader's full parameter copy, one flat
    /// vec per tensor (executor ABI shapes).
    Leader(Vec<Vec<f32>>),
    /// Fully sharded: rank r holds only `layout.range(r)` of the flat
    /// parameter vector; no full copy exists between steps.
    Sharded(Vec<Vec<f32>>),
}

pub struct Trainer {
    exec: Box<dyn StepExecutor>,
    /// The collective substrate for the hot path (gradient RS +
    /// parameter AG): in-process rings by default, a transport fabric
    /// via [`Trainer::with_comm`].
    comm: Box<dyn CollectiveEngine>,
    workers: Vec<WorkerSpec>,
    cfg: TrainConfig,
    /// The weights, leader-resident or fully sharded per
    /// [`TrainConfig::shard_params`].
    params: ParamStore,
    /// Tensor sizes (executor ABI order) for flatten/unflatten.
    sizes: Vec<usize>,
    /// Shard layout over the flat parameter vector (by r_i).
    layout: ShardLayout,
    /// FSDP unit plan over `layout`: a single whole unit unless
    /// `cfg.fsdp_units > 1` on a sharded trainer whose executor
    /// supports unit-pipelined execution.
    units: UnitLayout,
    shards: Vec<AdamShard>,
    corpus: Corpus,
    /// Persistent whole-model gather scratch (executor ABI shapes),
    /// reused across steps so the sharded hot path performs no
    /// per-step full-weight allocation (the head-of-step AllGather
    /// overwrites every element).
    gather: Vec<Vec<f32>>,
    /// High-water mark of transiently materialized parameter elements
    /// on any rank (see [`Trainer::peak_materialized_elems`]).
    peak_param_elems: usize,
    pub history: Vec<StepStats>,
}

impl Trainer {
    /// Build from an execution backend and explicit worker specs.
    pub fn from_executor(
        exec: Box<dyn StepExecutor>,
        workers: Vec<WorkerSpec>,
        cfg: TrainConfig,
    ) -> Result<Trainer> {
        if workers.is_empty() {
            return Err(anyhow!("need at least one worker"));
        }
        let sizes = exec.param_sizes().to_vec();
        let flat_len: usize = sizes.iter().sum();
        let ratios: Vec<f64> =
            workers.iter().map(|w| w.state_ratio.max(0.0)).collect();
        let layout = ShardLayout::by_ratios(flat_len, &ratios);
        let shards = (0..workers.len())
            .map(|r| AdamShard::new(layout.size(r), cfg.adam))
            .collect();
        let corpus = Corpus::new(exec.vocab(), cfg.corpus_branch, cfg.seed);
        let init = exec.init_params(cfg.seed);
        let params = if cfg.shard_params {
            // Slice the deterministic init into per-rank shards and
            // drop the full copy — from here on full weights exist only
            // transiently inside a step.
            let flat = flatten(&init, flat_len);
            ParamStore::Sharded(
                (0..workers.len())
                    .map(|r| flat[layout.range(r)].to_vec())
                    .collect(),
            )
        } else {
            ParamStore::Leader(init)
        };
        let units = Trainer::unit_plan(exec.as_ref(), &layout, &cfg);
        Ok(Trainer {
            exec,
            comm: Box::new(InProcessRing),
            workers,
            cfg,
            params,
            sizes,
            layout,
            units,
            shards,
            corpus,
            gather: Vec::new(),
            peak_param_elems: 0,
            history: Vec::new(),
        })
    }

    /// The FSDP unit plan for a layout: units engage only when the
    /// weights are sharded, more than one unit is requested, and the
    /// executor exposes a shardable prefix; everything else degrades
    /// to one whole-model unit (= the historical gather).
    fn unit_plan(
        exec: &dyn StepExecutor,
        layout: &ShardLayout,
        cfg: &TrainConfig,
    ) -> UnitLayout {
        if cfg.shard_params && cfg.fsdp_units > 1 {
            UnitLayout::for_prefix(
                layout,
                exec.unit_region(),
                exec.unit_alignment(),
                cfg.fsdp_units,
            )
        } else {
            UnitLayout::whole(layout)
        }
    }

    /// PJRT convenience constructor: load AOT artifacts from
    /// `artifacts_dir` (the historical entry point; the backend is just
    /// `exec::PjrtExecutor` behind the trait).
    #[cfg(feature = "xla")]
    pub fn new(
        artifacts_dir: &std::path::Path,
        workers: Vec<WorkerSpec>,
        cfg: TrainConfig,
    ) -> Result<Trainer> {
        let exec = crate::exec::PjrtExecutor::start(artifacts_dir)?;
        Trainer::from_executor(Box::new(exec), workers, cfg)
    }

    /// Build worker specs from a Cephalo `Assignment` and cluster GPU
    /// names.
    pub fn workers_from_assignment(
        asg: &Assignment,
        names: &[String],
    ) -> Vec<WorkerSpec> {
        asg.per_gpu
            .iter()
            .enumerate()
            .map(|(i, g)| WorkerSpec {
                batch: g.batch(),
                state_ratio: g.state_ratio,
                name: names
                    .get(i)
                    .cloned()
                    .unwrap_or_else(|| format!("gpu{i}")),
            })
            .collect()
    }

    pub fn global_batch(&self) -> usize {
        self.workers.iter().map(|w| w.batch).sum()
    }

    pub fn corpus_entropy(&self) -> f64 {
        self.corpus.entropy()
    }

    pub fn executor_name(&self) -> &'static str {
        self.exec.name()
    }

    /// Swap the collective substrate (must be installed before
    /// training; both engines are bitwise-equivalent, so mid-run swaps
    /// are safe too — just unusual).
    pub fn with_comm(mut self, comm: Box<dyn CollectiveEngine>) -> Trainer {
        self.comm = comm;
        self
    }

    /// Label of the collective engine in use ("inproc",
    /// "fabric:local", "fabric:tcp").
    pub fn comm_name(&self) -> &'static str {
        self.comm.name()
    }

    pub fn workers(&self) -> &[WorkerSpec] {
        &self.workers
    }

    /// The current shard layout over the flat parameter space.
    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// The FSDP unit plan in force (a single whole unit outside
    /// unit-pipelined mode).
    pub fn units(&self) -> &UnitLayout {
        &self.units
    }

    /// High-water mark of TRANSIENTLY materialized parameter elements
    /// on any rank across the steps run so far: the full flat length
    /// under whole-model gather, tail + two units (current +
    /// prefetched) under unit sharding, and 0 on a leader-resident
    /// trainer (its full copy is resident, not transient).
    pub fn peak_materialized_elems(&self) -> usize {
        self.peak_param_elems
    }

    /// The per-rank Adam shards (resident training state).
    pub fn shards(&self) -> &[AdamShard] {
        &self.shards
    }

    /// Run one training step; returns the global mean loss.
    pub fn step(&mut self, step_idx: usize) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        let seq = self.exec.seq_len();
        let b = self.global_batch();
        if b == 0 {
            return Err(anyhow!("global batch is zero"));
        }
        let (tokens, targets) = self.corpus.sample_batch(b, seq);
        let batches: Vec<usize> =
            self.workers.iter().map(|w| w.batch).collect();
        let parts = data::split_batch(&tokens, &targets, seq, &batches);

        // Unit-pipelined FSDP path: gather/compute/free one unit at a
        // time instead of materializing the full weights (engaged by
        // `fsdp_units > 1` on a sharded trainer; bitwise-identical —
        // DESIGN.md invariant 13).
        if self.units.num_units() > 1 {
            return self.step_units(step_idx, t0, &parts, &batches);
        }

        // Materialize the full weights: the resident leader copy, or —
        // fully sharded — a transient ring AllGather of the per-rank
        // slices into the persistent scratch (reused across steps; the
        // gather overwrites every element), bitwise the vector the
        // leader path carried over from the previous step's tail
        // AllGather.
        let mut phases = PhaseBreakdown::default();
        let use_gather = matches!(self.params, ParamStore::Sharded(_));
        if let ParamStore::Sharded(shards) = &self.params {
            let tg = std::time::Instant::now();
            let sp = telemetry::span(telemetry::CAT_GATHER, "param allgather");
            let flat = self.comm.allgather(shards, &self.layout)?;
            drop(sp);
            phases.gather_s += tg.elapsed().as_secs_f64();
            self.peak_param_elems = self.peak_param_elems.max(flat.len());
            unflatten_into(&flat, &self.sizes, &mut self.gather);
        }
        let full: &[Vec<f32>] = if use_gather {
            &self.gather
        } else {
            match &self.params {
                ParamStore::Leader(p) => p,
                ParamStore::Sharded(_) => unreachable!(),
            }
        };

        // Backend: per-worker batch shares -> per-worker summed grads.
        let tc = std::time::Instant::now();
        let out = self.exec.run_step(full, &parts)?;
        phases.compute_s += tc.elapsed().as_secs_f64();
        if out.worker_grads.len() != self.workers.len() {
            return Err(anyhow!(
                "backend returned {} gradient sets for {} workers",
                out.worker_grads.len(),
                self.workers.len()
            ));
        }
        if out.token_count <= 0.0 {
            return Err(anyhow!("backend reported zero tokens"));
        }

        // Uneven ReduceScatter of gradients onto the state shards
        // (through the collective engine — in-process rings or a real
        // transport fabric), then the Eq.-1 scale by 1/(global token
        // count).
        let tr = std::time::Instant::now();
        let sp =
            telemetry::span(telemetry::CAT_REDUCE_SCATTER, "grad rs");
        let mut grad_shards =
            self.comm.reduce_scatter(&out.worker_grads, &self.layout)?;
        drop(sp);
        phases.reduce_scatter_s += tr.elapsed().as_secs_f64();
        let inv = 1.0 / out.token_count as f32;
        for shard in grad_shards.iter_mut() {
            for g in shard.iter_mut() {
                *g *= inv;
            }
        }

        // Sharded Adam in parallel.
        match &mut self.params {
            ParamStore::Leader(params) => {
                // Historical path: update a flattened copy, then the
                // tail AllGather rebuilds the full parameter vector on
                // all ranks (leader keeps one canonical copy).
                let flat_len: usize = self.sizes.iter().sum();
                let mut flat = flatten(params, flat_len);
                let ta = std::time::Instant::now();
                let sp = telemetry::span(
                    telemetry::CAT_OPTIMIZER,
                    "sharded adam",
                );
                {
                    let layout = &self.layout;
                    let mut param_slices: Vec<&mut [f32]> = Vec::new();
                    let mut rest: &mut [f32] = &mut flat;
                    let mut consumed = 0usize;
                    for r in 0..self.workers.len() {
                        let range = layout.range(r);
                        let (head, tail) = rest.split_at_mut(range.len());
                        debug_assert_eq!(range.start, consumed);
                        consumed += range.len();
                        param_slices.push(head);
                        rest = tail;
                    }
                    std::thread::scope(|scope| {
                        for ((shard, grads), pslice) in self
                            .shards
                            .iter_mut()
                            .zip(&grad_shards)
                            .zip(param_slices.into_iter())
                        {
                            scope.spawn(move || shard.update(pslice, grads));
                        }
                    });
                }
                drop(sp);
                phases.optimizer_s += ta.elapsed().as_secs_f64();
                let shard_views: Vec<Vec<f32>> = (0..self.workers.len())
                    .map(|r| flat[self.layout.range(r)].to_vec())
                    .collect();
                let tg = std::time::Instant::now();
                let sp = telemetry::span(
                    telemetry::CAT_GATHER,
                    "tail allgather",
                );
                let rebuilt =
                    self.comm.allgather(&shard_views, &self.layout)?;
                drop(sp);
                phases.gather_s += tg.elapsed().as_secs_f64();
                *params = unflatten(&rebuilt, &self.sizes);
            }
            ParamStore::Sharded(shards) => {
                // Fully sharded: each rank updates its own resident
                // slice in place; no tail AllGather, no full copy — the
                // materialized weights drop at the end of this step.
                let ta = std::time::Instant::now();
                let sp = telemetry::span(
                    telemetry::CAT_OPTIMIZER,
                    "sharded adam",
                );
                std::thread::scope(|scope| {
                    for ((shard, grads), pshard) in self
                        .shards
                        .iter_mut()
                        .zip(&grad_shards)
                        .zip(shards.iter_mut())
                    {
                        scope.spawn(move || shard.update(pshard, grads));
                    }
                });
                drop(sp);
                phases.optimizer_s += ta.elapsed().as_secs_f64();
            }
        }

        let measured = t0.elapsed().as_secs_f64();
        let stats = StepStats {
            step: step_idx,
            mean_loss: out.loss_sum / out.token_count,
            tokens: out.token_count,
            wall_seconds: self.exec.step_seconds(&batches, measured),
            measured_seconds: measured,
            phases,
        };
        telemetry::drain();
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// The unit-pipelined step (ZeRO-style FSDP units): AllGather unit
    /// k+1 while unit k computes, free each unit right after its
    /// gradients are reduce-scattered, and keep the resident tail
    /// (the executor's non-unit suffix) materialized for the whole
    /// step. Per-unit gradient shards concatenate — in unit order —
    /// exactly to each rank's global `r_i` shard, and dyadic
    /// quantization makes every partial sum exactly associative, so
    /// the trajectory is BITWISE the whole-model-gather one; only the
    /// f64 loss accumulation order differs (last-bit loss jitter,
    /// never parameters).
    fn step_units(
        &mut self,
        step_idx: usize,
        t0: std::time::Instant,
        parts: &[(Vec<i32>, Vec<i32>)],
        batches: &[usize],
    ) -> Result<StepStats> {
        let n = self.workers.len();
        let flat_len: usize = self.sizes.iter().sum();
        let nu = self.units.num_units();
        let region = self.exec.unit_region().min(flat_len);
        let tail_is_unit = region < flat_len;
        let table_units = nu - usize::from(tail_is_unit);
        let token_count: f64 =
            parts.iter().map(|(t, _)| t.len()).sum::<usize>() as f64;
        if token_count <= 0.0 {
            return Err(anyhow!("backend reported zero tokens"));
        }

        let mut loss_sum = 0f64;
        let mut peak = 0usize;
        let mut phases = PhaseBreakdown::default();
        // One per-rank gradient shard list PER UNIT, in unit order.
        let mut unit_grad_shards: Vec<Vec<Vec<f32>>> =
            Vec::with_capacity(nu);
        {
            let pshards: &[Vec<f32>] = match &self.params {
                ParamStore::Leader(_) => {
                    return Err(anyhow!(
                        "unit-pipelined step requires sharded params"
                    ));
                }
                ParamStore::Sharded(s) => s,
            };
            let ul = &self.units;
            // The tail (e.g. the native surrogate's bias) stays
            // materialized across every unit; its per-unit partial
            // gradients sum exactly (dyadic grid).
            let tg = std::time::Instant::now();
            let sp =
                telemetry::span(telemetry::CAT_GATHER, "tail+unit0 ag");
            let tail: Vec<f32> = if tail_is_unit {
                self.comm.allgather_unit(
                    pshards,
                    &self.layout,
                    ul,
                    nu - 1,
                )?
            } else {
                Vec::new()
            };
            let mut tail_acc: Vec<Vec<f32>> =
                vec![vec![0f32; tail.len()]; n];
            let mut current = self.comm.allgather_unit(
                pshards,
                &self.layout,
                ul,
                0,
            )?;
            drop(sp);
            phases.gather_s += tg.elapsed().as_secs_f64();
            for k in 0..table_units {
                // Prefetch unit k+1 before computing unit k — the
                // in-process schedule mirrors the wire overlap
                // (transport::dist drives the gather rounds between
                // compute chunks), so the transient peak holds TWO
                // units plus the tail.
                let next = if k + 1 < table_units {
                    let tg = std::time::Instant::now();
                    let sp = telemetry::span(
                        telemetry::CAT_GATHER,
                        "prefetch unit ag",
                    );
                    let g = self.comm.allgather_unit(
                        pshards,
                        &self.layout,
                        ul,
                        k + 1,
                    )?;
                    drop(sp);
                    phases.gather_s += tg.elapsed().as_secs_f64();
                    Some(g)
                } else {
                    None
                };
                peak = peak.max(
                    tail.len()
                        + current.len()
                        + next.as_ref().map_or(0, Vec::len),
                );
                let tc = std::time::Instant::now();
                let out = self.exec.run_unit_step(
                    ul.unit_range(k),
                    &current,
                    &tail,
                    parts,
                )?;
                phases.compute_s += tc.elapsed().as_secs_f64();
                if out.worker_unit_grads.len() != n
                    || out.worker_tail_grads.len() != n
                {
                    return Err(anyhow!(
                        "backend returned {} unit gradient sets for {} \
                         workers",
                        out.worker_unit_grads.len(),
                        n
                    ));
                }
                loss_sum += out.loss_sum;
                for (acc, g) in tail_acc.iter_mut().zip(&out.worker_tail_grads)
                {
                    for (o, v) in acc.iter_mut().zip(g) {
                        *o += v;
                    }
                }
                // Unit k is done: free its weights, reduce-scatter its
                // gradients onto the owning ranks.
                drop(current);
                let tr = std::time::Instant::now();
                let sp = telemetry::span(
                    telemetry::CAT_REDUCE_SCATTER,
                    "unit rs",
                );
                unit_grad_shards.push(self.comm.reduce_scatter(
                    &out.worker_unit_grads,
                    ul.unit_layout(k),
                )?);
                drop(sp);
                phases.reduce_scatter_s += tr.elapsed().as_secs_f64();
                current = next.unwrap_or_default();
            }
            if tail_is_unit {
                let tr = std::time::Instant::now();
                let sp = telemetry::span(
                    telemetry::CAT_REDUCE_SCATTER,
                    "tail rs",
                );
                unit_grad_shards.push(self.comm.reduce_scatter(
                    &tail_acc,
                    ul.unit_layout(nu - 1),
                )?);
                drop(sp);
                phases.reduce_scatter_s += tr.elapsed().as_secs_f64();
            }
        }

        // Each rank's global gradient shard is its per-unit slices
        // concatenated in unit order (they tile layout.range(r)
        // exactly), then the Eq.-1 scale.
        let inv = 1.0 / token_count as f32;
        let grad_shards: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut g = Vec::with_capacity(self.layout.size(r));
                for per_unit in &unit_grad_shards {
                    g.extend_from_slice(&per_unit[r]);
                }
                for x in g.iter_mut() {
                    *x *= inv;
                }
                g
            })
            .collect();

        // Sharded Adam in place, exactly like the whole-gather path.
        let ta = std::time::Instant::now();
        let sp = telemetry::span(telemetry::CAT_OPTIMIZER, "sharded adam");
        if let ParamStore::Sharded(shards) = &mut self.params {
            std::thread::scope(|scope| {
                for ((shard, grads), pshard) in self
                    .shards
                    .iter_mut()
                    .zip(&grad_shards)
                    .zip(shards.iter_mut())
                {
                    scope.spawn(move || shard.update(pshard, grads));
                }
            });
        }
        drop(sp);
        phases.optimizer_s += ta.elapsed().as_secs_f64();
        self.peak_param_elems = self.peak_param_elems.max(peak);

        let measured = t0.elapsed().as_secs_f64();
        let stats = StepStats {
            step: step_idx,
            mean_loss: loss_sum / token_count,
            tokens: token_count,
            wall_seconds: self.exec.step_seconds(batches, measured),
            measured_seconds: measured,
            phases,
        };
        telemetry::drain();
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Run the configured number of steps, logging every `log_every`.
    pub fn run(&mut self) -> Result<Vec<StepStats>> {
        for s in 0..self.cfg.steps {
            let stats = self.step(s)?;
            if self.cfg.log_every > 0 && s % self.cfg.log_every == 0 {
                crate::info!(
                    "step {:>5}  loss {:.4}  ({:.2}s, {} tokens)",
                    s,
                    stats.mean_loss,
                    stats.wall_seconds,
                    stats.tokens
                );
            }
        }
        Ok(self.history.clone())
    }

    /// Evaluate mean loss on fresh batches (no update). Sharded mode
    /// materializes the weights once for the whole evaluation; leader
    /// mode borrows the resident copy (no clone).
    pub fn eval_loss(&mut self, batches: usize) -> Result<f64> {
        let gathered: Option<Vec<Vec<f32>>> = match &self.params {
            ParamStore::Leader(_) => None,
            ParamStore::Sharded(_) => Some(self.gather_params()),
        };
        let params: &[Vec<f32>] = match (&gathered, &self.params) {
            (Some(g), _) => g,
            (None, ParamStore::Leader(p)) => p,
            (None, ParamStore::Sharded(_)) => unreachable!(),
        };
        let seq = self.exec.seq_len();
        let rows = self.exec.eval_rows().max(1);
        let mut total = 0f64;
        let mut count = 0f64;
        for _ in 0..batches {
            let (tokens, targets) = self.corpus.sample_batch(rows, seq);
            let (ls, cnt) =
                self.exec.eval_loss(params, &tokens, &targets)?;
            total += ls;
            count += cnt;
        }
        if count == 0.0 {
            return Err(anyhow!("eval saw no tokens"));
        }
        Ok(total / count)
    }

    /// The leader-resident full parameters. Panics on a fully-sharded
    /// trainer — no resident copy exists by design; use
    /// [`Trainer::gather_params`] for an explicit export.
    pub fn params(&self) -> &[Vec<f32>] {
        match &self.params {
            ParamStore::Leader(p) => p,
            ParamStore::Sharded(_) => panic!(
                "fully-sharded trainer holds no resident full parameter \
                 copy; use gather_params() for an explicit export"
            ),
        }
    }

    /// Assemble the full parameter tensors — an EXPLICIT export, the
    /// only place a fully-sharded trainer reconstitutes the weights
    /// outside a step. Shard concatenation is bitwise the ring
    /// AllGather result, so both residencies export identical tensors.
    pub fn gather_params(&self) -> Vec<Vec<f32>> {
        match &self.params {
            ParamStore::Leader(p) => p.clone(),
            ParamStore::Sharded(shards) => {
                let mut flat =
                    Vec::with_capacity(self.sizes.iter().sum());
                for s in shards {
                    flat.extend_from_slice(s);
                }
                unflatten(&flat, &self.sizes)
            }
        }
    }

    /// The per-rank parameter slices (`Some` only in sharded mode).
    pub fn param_shards(&self) -> Option<&[Vec<f32>]> {
        match &self.params {
            ParamStore::Leader(_) => None,
            ParamStore::Sharded(shards) => Some(shards),
        }
    }

    /// True when the weights are fully sharded (no leader copy).
    pub fn is_sharded(&self) -> bool {
        matches!(self.params, ParamStore::Sharded(_))
    }

    /// Total parameter count (flat length), valid in both residencies.
    pub fn num_params(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Per-worker training-state bytes (the 16 B/param split by r_i) —
    /// for memory reports.
    pub fn state_bytes_per_worker(&self) -> Vec<usize> {
        (0..self.workers.len())
            .map(|r| self.layout.size(r) * 16)
            .collect()
    }

    /// Per-worker RESIDENT parameter bytes: proportional to `r_i` in
    /// sharded mode (4 B x shard elements), the full 4 B x total on
    /// every worker in leader mode — the measured counterpart of
    /// `memory::ParamResidency::param_bytes`.
    pub fn param_bytes_per_worker(&self) -> Vec<usize> {
        match &self.params {
            ParamStore::Leader(_) => {
                vec![self.num_params() * 4; self.workers.len()]
            }
            ParamStore::Sharded(shards) => {
                shards.iter().map(|s| s.len() * 4).collect()
            }
        }
    }

    /// Assemble a leader-view checkpoint (full params + gathered Adam
    /// moments over the flat parameter space). In sharded mode the
    /// parameter assembly is an explicit export (the checkpoint is the
    /// ONE artifact that is always layout-independent).
    pub fn checkpoint(&self) -> checkpoint::Checkpoint {
        let flat_len: usize = self.sizes.iter().sum();
        let mut adam_m = vec![0f32; flat_len];
        let mut adam_v = vec![0f32; flat_len];
        let mut step = 0u64;
        for (r, shard) in self.shards.iter().enumerate() {
            let range = self.layout.range(r);
            adam_m[range.clone()].copy_from_slice(&shard.m);
            adam_v[range].copy_from_slice(&shard.v);
            step = step.max(shard.step);
        }
        checkpoint::Checkpoint {
            step,
            params: self.gather_params(),
            adam_m,
            adam_v,
        }
    }

    /// Restore params + optimizer state from a checkpoint. The shard
    /// layout may differ from the one the checkpoint was written under —
    /// exactly the elastic-replan resume path
    /// (`coordinator::elastic`). A fully-sharded trainer re-slices the
    /// checkpoint's parameters into its own layout; no full copy is
    /// retained.
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        ck.validate()?;
        let sizes: Vec<usize> = ck.params.iter().map(Vec::len).collect();
        if sizes != self.sizes {
            return Err(anyhow!(
                "checkpoint tensor sizes do not match the executor"
            ));
        }
        match &mut self.params {
            ParamStore::Leader(p) => *p = ck.params.clone(),
            ParamStore::Sharded(shards) => {
                let flat_len: usize = sizes.iter().sum();
                let flat = flatten(&ck.params, flat_len);
                for (r, s) in shards.iter_mut().enumerate() {
                    *s = flat[self.layout.range(r)].to_vec();
                }
            }
        }
        for (r, shard) in self.shards.iter_mut().enumerate() {
            let range = self.layout.range(r);
            shard.m.copy_from_slice(&ck.adam_m[range.clone()]);
            shard.v.copy_from_slice(&ck.adam_v[range]);
            shard.step = ck.step;
        }
        Ok(())
    }

    /// Adopt a new worker membership after an elastic re-plan: install
    /// the layout derived from the new state ratios and the migrated
    /// Adam shards (built by `coordinator::elastic::apply_migration`).
    ///
    /// In leader-resident mode the full parameter copy carries over
    /// unchanged and `param_shards` must be `None`. In fully-sharded
    /// mode the weights migrate exactly like the moments: pass the
    /// re-sliced per-rank parameter shards (same `apply_migration`
    /// transfer list, applied to the flat weight vector). Training
    /// resumes on the next [`Trainer::step`].
    pub fn adopt(
        &mut self,
        workers: Vec<WorkerSpec>,
        shards: Vec<AdamShard>,
        param_shards: Option<Vec<Vec<f32>>>,
    ) -> Result<()> {
        if workers.is_empty() {
            return Err(anyhow!("need at least one worker"));
        }
        if shards.len() != workers.len() {
            return Err(anyhow!(
                "{} shards for {} workers",
                shards.len(),
                workers.len()
            ));
        }
        let flat_len: usize = self.sizes.iter().sum();
        let ratios: Vec<f64> =
            workers.iter().map(|w| w.state_ratio.max(0.0)).collect();
        let layout = ShardLayout::by_ratios(flat_len, &ratios);
        for (r, s) in shards.iter().enumerate() {
            if s.m.len() != layout.size(r) || s.v.len() != layout.size(r) {
                return Err(anyhow!(
                    "migrated shard {r} holds {} elems, layout wants {}",
                    s.m.len(),
                    layout.size(r)
                ));
            }
        }
        match (&self.params, &param_shards) {
            (ParamStore::Leader(_), Some(_)) => {
                return Err(anyhow!(
                    "leader-resident trainer adopts no parameter shards \
                     (the full copy carries over)"
                ));
            }
            (ParamStore::Sharded(_), None) => {
                return Err(anyhow!(
                    "fully-sharded trainer needs migrated parameter \
                     shards (there is no leader copy to fall back on)"
                ));
            }
            (ParamStore::Sharded(_), Some(ps)) => {
                if ps.len() != workers.len() {
                    return Err(anyhow!(
                        "{} parameter shards for {} workers",
                        ps.len(),
                        workers.len()
                    ));
                }
                for (r, s) in ps.iter().enumerate() {
                    if s.len() != layout.size(r) {
                        return Err(anyhow!(
                            "migrated parameter shard {r} holds {} \
                             elems, layout wants {}",
                            s.len(),
                            layout.size(r)
                        ));
                    }
                }
            }
            (ParamStore::Leader(_), None) => {}
        }
        if let Some(ps) = param_shards {
            self.params = ParamStore::Sharded(ps);
        }
        // The unit plan follows the layout (same region and unit
        // count, new rank boundaries), so unit-sharded training
        // resumes seamlessly after an elastic re-plan.
        self.units =
            Trainer::unit_plan(self.exec.as_ref(), &layout, &self.cfg);
        self.workers = workers;
        self.layout = layout;
        self.shards = shards;
        Ok(())
    }
}

/// Leader-side parameter init matching `XlaEngine::init_params`
/// (shared by the PJRT backend; ungated because it only needs the
/// manifest).
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = crate::util::prng::Rng::new(seed);
    manifest
        .param_order
        .iter()
        .zip(&manifest.param_shapes)
        .map(|(name, shape)| {
            let nelem: usize = shape.iter().product();
            if name.contains("scale") {
                vec![1.0; nelem]
            } else if name.contains("bias") || name == "b1" || name == "b2"
            {
                vec![0.0; nelem]
            } else {
                let mut v = vec![0f32; nelem];
                rng.fill_normal(&mut v, 0.02);
                v
            }
        })
        .collect()
}

pub(crate) fn flatten(tensors: &[Vec<f32>], flat_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(flat_len);
    for t in tensors {
        out.extend_from_slice(t);
    }
    out
}

pub(crate) fn unflatten(flat: &[f32], sizes: &[usize]) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0usize;
    for &sz in sizes {
        out.push(flat[off..off + sz].to_vec());
        off += sz;
    }
    debug_assert_eq!(off, flat.len());
    out
}

/// [`unflatten`] into a reusable ABI-shaped buffer: after the first
/// call the buffer keeps its capacity, so steady-state steps allocate
/// nothing for the materialized weights.
pub(crate) fn unflatten_into(
    flat: &[f32],
    sizes: &[usize],
    out: &mut Vec<Vec<f32>>,
) {
    out.resize(sizes.len(), Vec::new());
    let mut off = 0usize;
    for (t, &sz) in out.iter_mut().zip(sizes) {
        t.clear();
        t.extend_from_slice(&flat[off..off + sz]);
        off += sz;
    }
    debug_assert_eq!(off, flat.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{NativeExecutor, SurrogateSpec};
    use std::path::Path;

    fn native_trainer(
        workers: Vec<WorkerSpec>,
        cfg: TrainConfig,
    ) -> Trainer {
        let exec = NativeExecutor::new(SurrogateSpec::default());
        Trainer::from_executor(Box::new(exec), workers, cfg).unwrap()
    }

    fn w(batch: usize, ratio: f64, name: &str) -> WorkerSpec {
        WorkerSpec { batch, state_ratio: ratio, name: name.into() }
    }

    fn quiet(seed: u64) -> TrainConfig {
        TrainConfig { steps: 0, seed, log_every: 0, ..Default::default() }
    }

    #[test]
    fn flatten_roundtrip() {
        let tensors = vec![vec![1.0f32, 2.0], vec![3.0], vec![4.0, 5.0, 6.0]];
        let sizes = vec![2usize, 1, 3];
        let flat = flatten(&tensors, 6);
        assert_eq!(flat, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(unflatten(&flat, &sizes), tensors);
    }

    #[test]
    fn init_params_shapes() {
        let manifest = Manifest::parse(
            Path::new("/tmp"),
            r#"{
                "model": {"vocab": 8, "d_model": 4, "n_layers": 1,
                          "n_heads": 1, "seq_len": 4, "d_ff": 16,
                          "use_pallas": true, "num_params": 100},
                "param_order": ["embed", "ln1_scale", "b1"],
                "param_shapes": {"embed": [8, 4], "ln1_scale": [1, 4],
                                  "b1": [1, 16]},
                "microbatches": [1],
                "entries": []
            }"#,
        )
        .unwrap();
        let p = init_params(&manifest, 1);
        assert_eq!(p[0].len(), 32);
        assert!(p[1].iter().all(|&x| x == 1.0)); // scale -> ones
        assert!(p[2].iter().all(|&x| x == 0.0)); // b1 -> zeros
        assert!(p[0].iter().any(|&x| x != 0.0)); // embed -> random
        // Deterministic.
        assert_eq!(init_params(&manifest, 1)[0], p[0]);
    }

    #[test]
    fn native_training_descends_ungated() {
        // The acceptance headline at unit scale: the FULL pipeline
        // (split -> grads -> ring RS -> sharded Adam -> ring AG) runs
        // and learns in the default build, no artifacts, no xla.
        let workers = vec![w(5, 0.6, "big"), w(3, 0.4, "small")];
        let cfg = TrainConfig {
            steps: 60,
            seed: 3,
            log_every: 0,
            adam: AdamConfig { lr: 3e-2, ..Default::default() },
            corpus_branch: 2,
            ..Default::default()
        };
        let mut t = native_trainer(workers, cfg);
        let hist = t.run().unwrap();
        let first = hist.first().unwrap().mean_loss;
        let last = hist.last().unwrap().mean_loss;
        assert!(
            last < first * 0.9,
            "loss should descend: {first} -> {last}"
        );
        assert_eq!(t.executor_name(), "native");
        let bytes = t.state_bytes_per_worker();
        assert!(bytes[0] > bytes[1]);
    }

    #[test]
    fn uneven_split_matches_single_worker_bitwise() {
        // The exact-summation contract end to end: an uneven (3,1)
        // split with uneven (0.7, 0.3) sharding matches a single
        // worker doing all 4 rows BIT FOR BIT, step after step.
        let mut uneven = native_trainer(
            vec![w(3, 0.7, "fast"), w(1, 0.3, "slow")],
            quiet(5),
        );
        let mut single =
            native_trainer(vec![w(4, 1.0, "solo")], quiet(5));
        assert_eq!(uneven.params(), single.params());
        for s in 0..4 {
            uneven.step(s).unwrap();
            single.step(s).unwrap();
            assert_eq!(
                uneven.params(),
                single.params(),
                "params diverged at step {s}"
            );
        }
    }

    #[test]
    fn fabric_comm_engines_train_bitwise_identically() {
        // The tentpole's trainer rewiring: the SAME hot path over
        // in-process rings, a channel fabric, and a TCP-loopback
        // fabric — three collective substrates, one trajectory, bit
        // for bit.
        let workers = || vec![w(3, 0.7, "fast"), w(1, 0.3, "slow")];
        let mut inproc = native_trainer(workers(), quiet(5));
        let mut local = native_trainer(workers(), quiet(5))
            .with_comm(Box::new(comm::FabricRing::local(2).unwrap()));
        let mut tcp = native_trainer(workers(), quiet(5))
            .with_comm(Box::new(comm::FabricRing::tcp_loopback(2).unwrap()));
        assert_eq!(inproc.comm_name(), "inproc");
        assert_eq!(local.comm_name(), "fabric:local");
        assert_eq!(tcp.comm_name(), "fabric:tcp");
        for s in 0..3 {
            inproc.step(s).unwrap();
            local.step(s).unwrap();
            tcp.step(s).unwrap();
            assert_eq!(
                inproc.params(),
                local.params(),
                "channel fabric diverged at step {s}"
            );
            assert_eq!(
                inproc.params(),
                tcp.params(),
                "tcp fabric diverged at step {s}"
            );
        }
    }

    #[test]
    fn zero_batch_and_zero_ratio_workers_participate() {
        // A rank can hold state but no compute (b_i = 0) or compute but
        // no state (r_i = 0); both pass through the ring collectives.
        let mut t = native_trainer(
            vec![w(0, 0.5, "state-only"), w(4, 0.0, "compute-only"),
                 w(2, 0.5, "both")],
            quiet(8),
        );
        let mut reference =
            native_trainer(vec![w(6, 1.0, "solo")], quiet(8));
        for s in 0..3 {
            t.step(s).unwrap();
            reference.step(s).unwrap();
        }
        assert_eq!(t.params(), reference.params());
        assert_eq!(t.state_bytes_per_worker()[1], 0);
    }

    #[test]
    fn checkpoint_roundtrip_across_layout_change() {
        // Satellite: save under layout A, restore under layout B, and
        // the reassembled state is bitwise-equal; continued training
        // under either layout produces identical parameters.
        let mut a = native_trainer(
            vec![w(4, 0.6, "a0"), w(2, 0.3, "a1"), w(2, 0.1, "a2")],
            quiet(21),
        );
        for s in 0..3 {
            a.step(s).unwrap();
        }
        let ck = a.checkpoint();
        assert_eq!(ck.step, 3);
        let tmp = std::env::temp_dir().join("ceph_layout_change.ckpt");
        ck.save(&tmp).unwrap();
        let loaded = checkpoint::Checkpoint::load(&tmp).unwrap();
        assert_eq!(loaded, ck);

        // Restore under a DIFFERENT layout (2 ranks, different ratios,
        // same global batch so the data stream lines up).
        let mut b = native_trainer(
            vec![w(5, 0.45, "b0"), w(3, 0.55, "b1")],
            quiet(21),
        );
        b.restore(&loaded).unwrap();
        assert_eq!(b.params(), a.params(), "restored params differ");
        // Reassembling B's shards must reproduce the checkpoint bit for
        // bit even though the shard boundaries moved.
        let re = b.checkpoint();
        assert_eq!(re.adam_m, ck.adam_m);
        assert_eq!(re.adam_v, ck.adam_v);
        assert_eq!(re.step, ck.step);
        assert_eq!(re.params, ck.params);

        // Continued training: restore a fresh layout-A trainer too and
        // step both — trajectories must stay bitwise identical.
        let mut a2 = native_trainer(
            vec![w(4, 0.6, "a0"), w(2, 0.3, "a1"), w(2, 0.1, "a2")],
            quiet(21),
        );
        a2.restore(&loaded).unwrap();
        for s in 3..6 {
            a2.step(s).unwrap();
            b.step(s).unwrap();
            assert_eq!(a2.params(), b.params(), "diverged at step {s}");
        }
    }

    #[test]
    fn adopt_swaps_membership_and_validates() {
        let mut t = native_trainer(
            vec![w(2, 0.5, "x"), w(2, 0.5, "y")],
            quiet(2),
        );
        t.step(0).unwrap();
        let flat_len: usize = t.params().iter().map(Vec::len).sum();
        // Mismatched shard sizes are rejected.
        let bad = vec![AdamShard::new(1, AdamConfig::default())];
        assert!(t
            .adopt(vec![w(4, 1.0, "solo")], bad, None)
            .is_err());
        // A well-formed single-rank adoption passes and trains on.
        let ck = t.checkpoint();
        let solo = AdamShard {
            m: ck.adam_m.clone(),
            v: ck.adam_v.clone(),
            step: ck.step,
            cfg: AdamConfig::default(),
        };
        // A leader-resident trainer rejects parameter shards ...
        assert!(t
            .adopt(
                vec![w(4, 1.0, "solo")],
                vec![solo.clone()],
                Some(vec![vec![0.0; flat_len]]),
            )
            .is_err());
        t.adopt(vec![w(4, 1.0, "solo")], vec![solo], None).unwrap();
        assert_eq!(t.layout().sizes(), vec![flat_len]);
        assert_eq!(t.global_batch(), 4);
        t.step(1).unwrap();
    }

    #[test]
    fn eval_loss_runs_without_update() {
        let mut t = native_trainer(vec![w(2, 1.0, "solo")], quiet(4));
        let before = t.params().to_vec();
        let loss = t.eval_loss(2).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(t.params(), &before[..]);
    }

    fn quiet_sharded(seed: u64) -> TrainConfig {
        TrainConfig { shard_params: true, ..quiet(seed) }
    }

    #[test]
    fn fully_sharded_matches_leader_resident_bitwise() {
        // The tentpole invariant at unit scale: dropping the leader
        // copy changes WHERE the weights live, not one bit of the
        // trajectory — across every collective substrate.
        let workers = || {
            vec![
                w(3, 0.7, "fast"),
                w(1, 0.3, "slow"),
                w(2, 0.0, "stateless"),
            ]
        };
        let mut leader = native_trainer(workers(), quiet(9));
        let mut sharded = native_trainer(workers(), quiet_sharded(9));
        let mut sharded_tcp = native_trainer(workers(), quiet_sharded(9))
            .with_comm(Box::new(comm::FabricRing::tcp_loopback(3).unwrap()));
        assert!(!leader.is_sharded());
        assert!(sharded.is_sharded());
        assert_eq!(sharded.gather_params(), leader.gather_params());
        for s in 0..4 {
            leader.step(s).unwrap();
            sharded.step(s).unwrap();
            sharded_tcp.step(s).unwrap();
            assert_eq!(
                sharded.gather_params(),
                leader.gather_params(),
                "sharded diverged from leader at step {s}"
            );
            assert_eq!(
                sharded_tcp.gather_params(),
                leader.gather_params(),
                "sharded-over-tcp diverged at step {s}"
            );
        }
        // Per-rank parameter bytes scale with r_i in sharded mode (the
        // r_i = 0 rank holds ZERO weight bytes), but are the full copy
        // on every rank in leader mode.
        let sb = sharded.param_bytes_per_worker();
        let lb = leader.param_bytes_per_worker();
        let total = leader.num_params() * 4;
        assert_eq!(sb.iter().sum::<usize>(), total);
        assert!(sb[0] > sb[1], "bigger r_i must hold more weight bytes");
        assert_eq!(sb[2], 0, "r_i = 0 rank holds no weights");
        assert_eq!(lb, vec![total; 3]);
        // And the resident copy is genuinely gone.
        assert!(sharded.param_shards().is_some());
        assert!(leader.param_shards().is_none());
    }

    #[test]
    fn sharded_checkpoint_roundtrip_across_layout_change() {
        // Satellite: save from a fully-sharded trainer under layout A,
        // restore into a fully-sharded trainer under layout B (and a
        // leader-resident one), bitwise against the reference.
        let mut a = native_trainer(
            vec![w(4, 0.5, "a0"), w(2, 0.3, "a1"), w(2, 0.2, "a2")],
            quiet_sharded(23),
        );
        for s in 0..3 {
            a.step(s).unwrap();
        }
        let ck = a.checkpoint();
        assert_eq!(ck.step, 3);
        let tmp =
            std::env::temp_dir().join("ceph_sharded_layout_change.ckpt");
        ck.save(&tmp).unwrap();
        let loaded = checkpoint::Checkpoint::load(&tmp).unwrap();
        assert_eq!(loaded, ck);

        // Restore under a DIFFERENT sharded layout (2 ranks).
        let mut b = native_trainer(
            vec![w(5, 0.35, "b0"), w(3, 0.65, "b1")],
            quiet_sharded(23),
        );
        b.restore(&loaded).unwrap();
        assert_eq!(b.gather_params(), a.gather_params());
        // Re-exporting from B's shards reproduces the checkpoint bit
        // for bit even though every shard boundary moved.
        let re = b.checkpoint();
        assert_eq!(re, ck);

        // And a LEADER-resident restore of the same checkpoint stays on
        // the identical trajectory when both continue training.
        let mut l = native_trainer(vec![w(8, 1.0, "solo")], quiet(23));
        l.restore(&loaded).unwrap();
        for s in 3..6 {
            b.step(s).unwrap();
            l.step(s).unwrap();
            assert_eq!(
                b.gather_params(),
                l.gather_params(),
                "sharded restore diverged at step {s}"
            );
        }
    }

    #[test]
    fn sharded_adopt_migrates_weights_with_the_moments() {
        use crate::coordinator::elastic;
        // Shrink 2 -> 1 via the real migration plumbing: the transfer
        // list re-slices Adam m/v AND the weight vector; the adopted
        // trainer continues bitwise on a leader-resident reference.
        let mut t = native_trainer(
            vec![w(3, 0.6, "x"), w(1, 0.4, "y")],
            quiet_sharded(31),
        );
        let mut reference =
            native_trainer(vec![w(4, 1.0, "solo")], quiet(31));
        for s in 0..2 {
            t.step(s).unwrap();
            reference.step(s).unwrap();
        }
        let flat_len = t.num_params();
        let old_layout = t.layout().clone();
        let new_layout = ShardLayout::by_ratios(flat_len, &[1.0]);
        let survivors = vec![Some(0)];
        let (transfers, _resident, moved) = elastic::plan_migration(
            &old_layout, &new_layout, &survivors,
        );
        assert!(moved > 0);
        let ck = t.checkpoint();
        let flat_ref = flatten(&ck.params, flat_len);
        let old_p: Vec<&[f32]> = t
            .param_shards()
            .unwrap()
            .iter()
            .map(|s| s.as_slice())
            .collect();
        let new_p = elastic::apply_migration(
            &old_layout, &old_p, &new_layout, &survivors, &transfers,
            &flat_ref,
        );
        let old_m: Vec<&[f32]> =
            t.shards().iter().map(|s| s.m.as_slice()).collect();
        let new_m = elastic::apply_migration(
            &old_layout, &old_m, &new_layout, &survivors, &transfers,
            &ck.adam_m,
        );
        let old_v: Vec<&[f32]> =
            t.shards().iter().map(|s| s.v.as_slice()).collect();
        let new_v = elastic::apply_migration(
            &old_layout, &old_v, &new_layout, &survivors, &transfers,
            &ck.adam_v,
        );
        let shards: Vec<AdamShard> = new_m
            .into_iter()
            .zip(new_v)
            .map(|(m, v)| AdamShard {
                m,
                v,
                step: ck.step,
                cfg: AdamConfig::default(),
            })
            .collect();
        // A sharded trainer refuses to adopt WITHOUT weight shards ...
        assert!(t
            .adopt(vec![w(4, 1.0, "solo")], shards.clone(), None)
            .is_err());
        t.adopt(vec![w(4, 1.0, "solo")], shards, Some(new_p)).unwrap();
        assert!(t.is_sharded());
        for s in 2..5 {
            t.step(s).unwrap();
            reference.step(s).unwrap();
            assert_eq!(
                t.gather_params(),
                reference.gather_params(),
                "post-migration trajectory diverged at step {s}"
            );
        }
    }

    fn quiet_units(seed: u64, units: usize) -> TrainConfig {
        TrainConfig { fsdp_units: units, ..quiet_sharded(seed) }
    }

    #[test]
    fn unit_sharded_steps_match_whole_model_gather_bitwise() {
        // DESIGN.md invariant 13 at unit scale: cutting the gather
        // into per-layer FSDP units (prefetch unit k+1 while unit k
        // computes, free after its ReduceScatter) changes WHEN weights
        // are materialized, not one bit of the trajectory — across
        // unit counts, collective engines, and against the
        // leader-resident reference. Loss is deliberately not
        // compared: per-unit f64 accumulation reorders the sum
        // (parameters never move).
        let workers =
            || vec![w(3, 0.6, "a"), w(1, 0.4, "b"), w(2, 0.0, "c")];
        let mut whole = native_trainer(workers(), quiet_sharded(17));
        let mut units4 = native_trainer(workers(), quiet_units(17, 4));
        let mut units7 = native_trainer(workers(), quiet_units(17, 7))
            .with_comm(Box::new(comm::FabricRing::local(3).unwrap()));
        let mut leader = native_trainer(workers(), quiet(17));
        assert_eq!(whole.units().num_units(), 1);
        // 4 table units + the resident-tail (bias) unit.
        assert_eq!(units4.units().num_units(), 5);
        // fsdp_units without shard_params degrades to one whole unit.
        let ignored = native_trainer(
            workers(),
            TrainConfig { fsdp_units: 4, ..quiet(17) },
        );
        assert_eq!(ignored.units().num_units(), 1);

        for s in 0..4 {
            whole.step(s).unwrap();
            units4.step(s).unwrap();
            units7.step(s).unwrap();
            leader.step(s).unwrap();
            assert_eq!(
                units4.gather_params(),
                whole.gather_params(),
                "units=4 diverged from whole-model gather at step {s}"
            );
            assert_eq!(
                units7.gather_params(),
                leader.gather_params(),
                "units=7 over the channel fabric diverged at step {s}"
            );
        }

        // Transient parameter memory: the whole-gather path
        // materializes every element; the unit path holds at most TWO
        // table units (current + prefetched) plus the tail.
        let total = whole.num_params();
        assert_eq!(whole.peak_materialized_elems(), total);
        assert_eq!(leader.peak_materialized_elems(), 0);
        let peak = units4.peak_materialized_elems();
        let ul = units4.units();
        let tail_len = ul.unit_len(ul.num_units() - 1);
        assert!(
            peak <= 2 * ul.largest_unit() + tail_len,
            "unit peak {peak} exceeds two units + tail"
        );
        assert!(
            peak < total,
            "unit peak {peak} must undercut the full gather ({total})"
        );
    }
}
