//! The trainer's collective engine seam: the hot path (gradient
//! ReduceScatter, parameter AllGather) behind one small trait, so the
//! leader-resident [`crate::trainer::Trainer`] can run its collectives
//! either as in-process array transforms (the historical default) or
//! as real message traffic over a [`Transport`] fabric.
//!
//! Both engines are bit-identical by construction (DESIGN.md
//! invariant 10): `FabricRing` drives
//! `transport::collectives::ring_*`, whose ring schedule and
//! accumulation order match `collectives::ring_*` exactly.

use crate::collectives;
use crate::sharding::{ShardLayout, UnitLayout};
use crate::transport::collectives::RingOrder;
use crate::transport::{
    self, ChaosTransport, CrashMode, FaultPlan, HostTopology, LocalFabric,
    ShmFabric, Transport,
};
use crate::util::error::{anyhow, Result};

/// What the trainer needs from a collective substrate.
pub trait CollectiveEngine: Send {
    /// Label for logs ("inproc", "fabric:local", "fabric:tcp").
    fn name(&self) -> &'static str;

    /// Per-rank full-length contributions in, per-rank summed shards
    /// out (rank order).
    fn reduce_scatter(
        &mut self,
        full: &[Vec<f32>],
        layout: &ShardLayout,
    ) -> Result<Vec<Vec<f32>>>;

    /// Per-rank shards in, the reassembled full vector out.
    fn allgather(
        &mut self,
        shards: &[Vec<f32>],
        layout: &ShardLayout,
    ) -> Result<Vec<f32>>;

    /// Gather ONE FSDP unit: cut each rank's unit-local slice out of
    /// its GLOBAL parameter shard, then AllGather over the unit's own
    /// rebased layout. Provided — engines only ever see flat layouts,
    /// so every substrate (in-process, channel, TCP, chaotic) gets the
    /// unit dimension for free. The per-unit gradient ReduceScatter
    /// needs no counterpart: unit-length contributions go straight
    /// through [`CollectiveEngine::reduce_scatter`] with
    /// `units.unit_layout(u)`.
    fn allgather_unit(
        &mut self,
        global_shards: &[Vec<f32>],
        global: &ShardLayout,
        units: &UnitLayout,
        u: usize,
    ) -> Result<Vec<f32>> {
        if global_shards.len() != global.num_ranks() {
            return Err(anyhow!(
                "{} shards for a {}-rank layout",
                global_shards.len(),
                global.num_ranks()
            ));
        }
        let slices: Vec<Vec<f32>> = (0..global.num_ranks())
            .map(|r| {
                let base = global.range(r).start;
                let s = units.rank_slice(u, r);
                global_shards[r][s.start - base..s.end - base].to_vec()
            })
            .collect();
        self.allgather(&slices, units.unit_layout(u))
    }
}

/// The historical default: deterministic in-process ring transforms.
pub struct InProcessRing;

impl CollectiveEngine for InProcessRing {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn reduce_scatter(
        &mut self,
        full: &[Vec<f32>],
        layout: &ShardLayout,
    ) -> Result<Vec<Vec<f32>>> {
        Ok(collectives::ring_reduce_scatter(full, layout))
    }

    fn allgather(
        &mut self,
        shards: &[Vec<f32>],
        layout: &ShardLayout,
    ) -> Result<Vec<f32>> {
        Ok(collectives::ring_allgather(shards, layout))
    }
}

/// Transport-backed engine: one endpoint per worker rank; every
/// collective runs as N−1 rounds of real peer messages, one scoped
/// thread per rank. Supports shrunken groups (elastic memberships use
/// a prefix of the endpoints).
pub struct FabricRing {
    endpoints: Vec<Box<dyn Transport>>,
    label: &'static str,
    /// When set, every collective walks the locality-sorted ring order
    /// derived from this host map (same-host ranks adjacent — only
    /// `num_hosts` of the N−1 hops per round cross hosts). `None`
    /// keeps the classic rank-order ring.
    topo: Option<HostTopology>,
}

impl FabricRing {
    pub fn new(endpoints: Vec<Box<dyn Transport>>) -> Result<FabricRing> {
        if endpoints.is_empty() {
            return Err(anyhow!("fabric engine needs at least one endpoint"));
        }
        for (i, ep) in endpoints.iter().enumerate() {
            if ep.rank() != i {
                return Err(anyhow!(
                    "endpoint {i} reports rank {}; pass endpoints in \
                     rank order",
                    ep.rank()
                ));
            }
        }
        let label = match endpoints[0].backend() {
            "local" => "fabric:local",
            "tcp" => "fabric:tcp",
            "shm" => "fabric:shm",
            "hybrid" => "fabric:hybrid",
            _ => "fabric",
        };
        Ok(FabricRing { endpoints, label, topo: None })
    }

    /// Walk every collective in the locality-sorted order for `topo`
    /// instead of rank order. The reorder is bitwise-invisible on the
    /// native backend's dyadic grid (DESIGN.md invariant 10).
    pub fn with_topology(mut self, topo: HostTopology) -> Result<FabricRing> {
        if topo.world_size() != self.endpoints.len() {
            return Err(anyhow!(
                "host map names {} ranks, fabric has {}",
                topo.world_size(),
                self.endpoints.len()
            ));
        }
        self.topo = Some(topo);
        Ok(self)
    }

    /// The ring order for a `group`-rank collective.
    fn order(&self, group: usize) -> RingOrder {
        match &self.topo {
            Some(t) => RingOrder::from_topology(t, group),
            None => RingOrder::identity(group.max(1)),
        }
    }

    /// Channel-backed fabric for `world` ranks.
    pub fn local(world: usize) -> Result<FabricRing> {
        let eps = LocalFabric::new(world)
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn Transport>)
            .collect();
        FabricRing::new(eps)
    }

    /// TCP-loopback fabric for `world` ranks (threaded handshake).
    pub fn tcp_loopback(world: usize) -> Result<FabricRing> {
        FabricRing::new(transport::tcp::thread_fabric(world)?)
    }

    /// Shared-memory fabric for `world` ranks (mmap ring lanes).
    pub fn shm(world: usize) -> Result<FabricRing> {
        let eps = ShmFabric::new(world)?
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn Transport>)
            .collect();
        FabricRing::new(eps)
    }

    /// Locality-routed fabric: shm lanes within a host, TCP loopback
    /// across, rings walked in the locality-sorted order for `hosts`.
    pub fn hybrid(hosts: Vec<u64>) -> Result<FabricRing> {
        let topo = HostTopology::new(hosts);
        let dir = transport::shm::fresh_dir();
        let slow = transport::tcp::thread_fabric(topo.world_size())?;
        let eps: Vec<Box<dyn Transport>> = slow
            .into_iter()
            .map(|ep| {
                transport::HybridTransport::wrap(ep, &dir, topo.clone())
                    .map(|h| Box::new(h) as Box<dyn Transport>)
            })
            .collect::<Result<_>>()?;
        FabricRing::new(eps)?.with_topology(topo)
    }

    /// Wrap every endpoint in deterministic fault injection driven by
    /// `plan` (per-rank seeded delay/dup noise; crashes surface as
    /// typed errors). Injected faults must be bitwise-invisible to the
    /// collectives — DESIGN.md invariant 10 extended to a lossy-looking
    /// wire — which the parity tests assert against the clean engines.
    pub fn chaotic(
        endpoints: Vec<Box<dyn Transport>>,
        plan: &FaultPlan,
    ) -> Result<FabricRing> {
        let eps = endpoints
            .into_iter()
            .map(|e| {
                Box::new(ChaosTransport::new(e, plan, CrashMode::Error))
                    as Box<dyn Transport>
            })
            .collect();
        FabricRing::new(eps)
    }

    /// Channel-backed fabric with chaos middleware on every rank.
    pub fn chaotic_local(
        world: usize,
        plan: &FaultPlan,
    ) -> Result<FabricRing> {
        let eps = LocalFabric::new(world)
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn Transport>)
            .collect();
        FabricRing::chaotic(eps, plan)
    }

    fn check_group(&self, layout: &ShardLayout) -> Result<usize> {
        let group = layout.num_ranks();
        if group > self.endpoints.len() {
            return Err(anyhow!(
                "layout wants {group} ranks, fabric has {}",
                self.endpoints.len()
            ));
        }
        Ok(group)
    }
}

impl CollectiveEngine for FabricRing {
    fn name(&self) -> &'static str {
        self.label
    }

    fn reduce_scatter(
        &mut self,
        full: &[Vec<f32>],
        layout: &ShardLayout,
    ) -> Result<Vec<Vec<f32>>> {
        let group = self.check_group(layout)?;
        if full.len() != group {
            return Err(anyhow!(
                "{} contributions for a {group}-rank layout",
                full.len()
            ));
        }
        let order = self.order(group);
        let results: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self.endpoints[..group]
                .iter_mut()
                .zip(full)
                .map(|(ep, mine)| {
                    let order = &order;
                    scope.spawn(move || {
                        transport::collectives::ring_reduce_scatter_ordered(
                            ep.as_mut(),
                            mine,
                            layout,
                            order,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        results.into_iter().collect()
    }

    fn allgather(
        &mut self,
        shards: &[Vec<f32>],
        layout: &ShardLayout,
    ) -> Result<Vec<f32>> {
        let group = self.check_group(layout)?;
        if shards.len() != group {
            return Err(anyhow!(
                "{} shards for a {group}-rank layout",
                shards.len()
            ));
        }
        let order = self.order(group);
        let results: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self.endpoints[..group]
                .iter_mut()
                .zip(shards)
                .map(|(ep, mine)| {
                    let order = &order;
                    scope.spawn(move || {
                        transport::collectives::ring_allgather_ordered(
                            ep.as_mut(),
                            mine,
                            layout,
                            order,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut gathered = results.into_iter().collect::<Result<Vec<_>>>()?;
        // Every rank converged to the same full vector; rank 0's copy
        // is the leader's.
        Ok(gathered.swap_remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_and_data() -> (ShardLayout, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let layout = ShardLayout::by_ratios(11, &[0.5, 0.0, 0.5]);
        let full: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..11).map(|i| (r * 31 + i) as f32 * 0.25).collect())
            .collect();
        let shards: Vec<Vec<f32>> = (0..3)
            .map(|r| {
                let range = layout.range(r);
                full[0][range].to_vec()
            })
            .collect();
        (layout, full, shards)
    }

    #[test]
    fn fabric_engines_match_the_inprocess_engine_bitwise() {
        let (layout, full, shards) = layout_and_data();
        let mut inproc = InProcessRing;
        let expect_rs = inproc.reduce_scatter(&full, &layout).unwrap();
        let expect_ag = inproc.allgather(&shards, &layout).unwrap();
        for mut engine in [
            FabricRing::local(3).unwrap(),
            FabricRing::tcp_loopback(3).unwrap(),
        ] {
            let rs = engine.reduce_scatter(&full, &layout).unwrap();
            assert_eq!(rs, expect_rs, "{} RS diverged", engine.name());
            let ag = engine.allgather(&shards, &layout).unwrap();
            assert_eq!(ag, expect_ag, "{} AG diverged", engine.name());
        }
    }

    #[test]
    fn chaotic_fabric_matches_the_clean_engines_bitwise() {
        // Delay + duplicate noise on every rank of both wire fabrics.
        // Invariant 10 extended: a lossy-looking wire is still bitwise
        // invisible to the collectives.
        let (layout, full, shards) = layout_and_data();
        let mut inproc = InProcessRing;
        let expect_rs = inproc.reduce_scatter(&full, &layout).unwrap();
        let expect_ag = inproc.allgather(&shards, &layout).unwrap();
        let plan = FaultPlan::generate(
            21,
            3,
            &crate::transport::ChaosConfig {
                crash_ranks: 0,
                first_crash_step: 0,
                crash_step_stride: 1,
                delay_prob: 0.5,
                max_delay_ms: 1,
                dup_prob: 0.5,
                ..Default::default()
            },
        );
        for mut engine in [
            FabricRing::chaotic_local(3, &plan).unwrap(),
            FabricRing::chaotic(
                transport::tcp::thread_fabric(3).unwrap(),
                &plan,
            )
            .unwrap(),
        ] {
            let rs = engine.reduce_scatter(&full, &layout).unwrap();
            assert_eq!(rs, expect_rs, "{} chaotic RS diverged", engine.name());
            let ag = engine.allgather(&shards, &layout).unwrap();
            assert_eq!(ag, expect_ag, "{} chaotic AG diverged", engine.name());
        }
    }

    #[test]
    fn shm_and_hybrid_engines_match_the_inprocess_engine_bitwise() {
        // The fast-path fabrics: pure shm (identity ring) and hybrid
        // with an interleaved host map (locality-REORDERED ring). The
        // data is dyadic (quarter-integers), so the reordered RS
        // accumulation is exactly associative — bitwise invisible.
        let (layout, full, shards) = layout_and_data();
        let mut inproc = InProcessRing;
        let expect_rs = inproc.reduce_scatter(&full, &layout).unwrap();
        let expect_ag = inproc.allgather(&shards, &layout).unwrap();
        for mut engine in [
            FabricRing::shm(3).unwrap(),
            FabricRing::hybrid(vec![0, 1, 0]).unwrap(),
        ] {
            let rs = engine.reduce_scatter(&full, &layout).unwrap();
            assert_eq!(rs, expect_rs, "{} RS diverged", engine.name());
            let ag = engine.allgather(&shards, &layout).unwrap();
            assert_eq!(ag, expect_ag, "{} AG diverged", engine.name());
        }
    }

    #[test]
    fn topology_must_match_the_fabric_world() {
        let ring = FabricRing::local(3).unwrap();
        assert!(ring.with_topology(HostTopology::new(vec![0, 1])).is_err());
        let ring = FabricRing::local(3).unwrap();
        let named = ring
            .with_topology(HostTopology::new(vec![0, 1, 0]))
            .unwrap();
        assert_eq!(named.name(), "fabric:local");
    }

    #[test]
    fn unit_gather_reassembles_each_unit_from_global_shards() {
        // The unit dimension: gathering unit u from the per-rank
        // GLOBAL shards yields exactly that slice of the full vector,
        // on every engine, including units where some rank owns
        // nothing.
        let (layout, full, shards) = layout_and_data();
        let units = UnitLayout::split(&layout, 3);
        let mut engines: Vec<Box<dyn CollectiveEngine>> = vec![
            Box::new(InProcessRing),
            Box::new(FabricRing::local(3).unwrap()),
            Box::new(FabricRing::tcp_loopback(3).unwrap()),
        ];
        for engine in engines.iter_mut() {
            for u in 0..units.num_units() {
                let got = engine
                    .allgather_unit(&shards, &layout, &units, u)
                    .unwrap();
                assert_eq!(
                    got,
                    full[0][units.unit_range(u)].to_vec(),
                    "{} unit {u} diverged",
                    engine.name()
                );
            }
        }
    }

    #[test]
    fn fabric_supports_prefix_groups() {
        // 3 endpoints, 2-rank layout: only the prefix participates.
        let layout = ShardLayout::by_ratios(6, &[0.5, 0.5]);
        let shards = vec![vec![1f32, 2., 3.], vec![4f32, 5., 6.]];
        let mut engine = FabricRing::local(3).unwrap();
        let ag = engine.allgather(&shards, &layout).unwrap();
        assert_eq!(ag, vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn arity_mismatches_error() {
        let layout = ShardLayout::by_ratios(4, &[0.5, 0.5]);
        let mut engine = FabricRing::local(1).unwrap();
        assert!(engine
            .reduce_scatter(&[vec![0.0; 4], vec![0.0; 4]], &layout)
            .is_err());
        let mut small = FabricRing::local(2).unwrap();
        assert!(small.allgather(&[vec![0.0; 2]], &layout).is_err());
    }
}
