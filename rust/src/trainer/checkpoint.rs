//! Training-state checkpointing: save/restore parameters, sharded Adam
//! moments and step counters to a single binary file.
//!
//! Production trainers must survive restarts — and Cephalo's own
//! motivation (Fig. 1: cloud GPUs appear and vanish hourly) makes
//! suspend/resume + re-planning a first-class workflow (see
//! `coordinator::elastic`). Format: a small hand-rolled container
//! (magic, version, metadata, length-prefixed f32 sections) since serde
//! is not in the offline dependency closure.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{anyhow, Context, Result};

const MAGIC: &[u8; 8] = b"CEPHCKPT";
const VERSION: u32 = 1;

/// A complete training-state snapshot (leader view: full vectors).
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Optimizer step count.
    pub step: u64,
    /// Parameter tensors in manifest order.
    pub params: Vec<Vec<f32>>,
    /// First-moment vector over the FLAT parameter space.
    pub adam_m: Vec<f32>,
    /// Second-moment vector over the flat space.
    pub adam_v: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for p in &self.params {
            write_f32s(&mut buf, p);
        }
        write_f32s(&mut buf, &self.adam_m);
        write_f32s(&mut buf, &self.adam_v);
        // Trailing checksum (FNV-1a over everything before it).
        let sum = fnv1a(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(path, &buf)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut data)?;
        if data.len() < MAGIC.len() + 4 + 8 + 4 + 8 {
            return Err(anyhow!("checkpoint truncated"));
        }
        let (body, tail) = data.split_at(data.len() - 8);
        let expect = u64::from_le_bytes(tail.try_into().unwrap());
        if fnv1a(body) != expect {
            return Err(anyhow!("checkpoint checksum mismatch"));
        }
        let mut r = Reader { b: body, i: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC {
            return Err(anyhow!("not a cephalo checkpoint"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        let step = r.u64()?;
        let n_tensors = r.u32()? as usize;
        let mut params = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            params.push(r.f32s()?);
        }
        let adam_m = r.f32s()?;
        let adam_v = r.f32s()?;
        if r.i != body.len() {
            return Err(anyhow!("trailing bytes in checkpoint"));
        }
        Ok(Checkpoint { step, params, adam_m, adam_v })
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }

    /// Consistency: moment vectors must cover the flat space.
    pub fn validate(&self) -> Result<()> {
        let n = self.param_count();
        if self.adam_m.len() != n || self.adam_v.len() != n {
            return Err(anyhow!(
                "moment length {} / {} != param count {n}",
                self.adam_m.len(),
                self.adam_v.len()
            ));
        }
        Ok(())
    }
}

fn write_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    // Little-endian bulk write.
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(anyhow!("checkpoint truncated at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
}

use crate::util::fnv1a;

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 42,
            params: vec![vec![1.0, -2.5, 3.25], vec![0.5; 7]],
            adam_m: vec![0.1; 10],
            adam_v: vec![0.2; 10],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ceph_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        back.validate().unwrap();
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("ceph_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.ckpt");
        sample().save(&path).unwrap();
        let mut data = std::fs::read(&path).unwrap();
        let mid = data.len() / 2;
        data[mid] ^= 0xFF;
        std::fs::write(&path, &data).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"));
    }

    #[test]
    fn truncation_detected() {
        let dir = std::env::temp_dir().join("ceph_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.ckpt");
        sample().save(&path).unwrap();
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..10]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = std::env::temp_dir().join("ceph_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("magic.ckpt");
        // Valid checksum over an invalid body.
        let mut buf = b"NOTCKPT!".to_vec();
        buf.extend_from_slice(&[0u8; 16]);
        let sum = super::fnv1a(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("not a cephalo"), "{err}");
    }

    #[test]
    fn validate_catches_length_mismatch() {
        let mut ck = sample();
        ck.adam_m.pop();
        assert!(ck.validate().is_err());
    }
}
