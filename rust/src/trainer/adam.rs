//! Sharded Adam optimizer (Kingma & Ba, 2015), fp32, matching the
//! paper's training setup and the 16-bytes-per-parameter state layout:
//! each GPU updates only its training-state shard (4 B param + 4 B grad
//! + 8 B moments per parameter), exactly the FSDP/ZeRO-3 division.

/// Adam hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self {
            lr: 3e-4,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

/// Optimizer state for one contiguous parameter shard.
#[derive(Debug, Clone)]
pub struct AdamShard {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
    pub cfg: AdamConfig,
}

impl AdamShard {
    pub fn new(len: usize, cfg: AdamConfig) -> AdamShard {
        AdamShard { m: vec![0.0; len], v: vec![0.0; len], step: 0, cfg }
    }

    /// In-place Adam update of `params` with `grads` (same shard range).
    pub fn update(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.step += 1;
        let c = self.cfg;
        let t = self.step as f32;
        let bc1 = 1.0 - c.beta1.powf(t);
        let bc2 = 1.0 - c.beta2.powf(t);
        for i in 0..params.len() {
            let g = grads[i] + c.weight_decay * params[i];
            self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * g;
            self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= c.lr * mhat / (vhat.sqrt() + c.eps);
        }
    }

    /// State bytes held by this shard (the 16 B/param accounting minus
    /// the 4 B gradient, which is transient).
    pub fn state_bytes(&self) -> usize {
        self.m.len() * 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::ShardLayout;

    /// Adam on a quadratic: converges to the minimum.
    #[test]
    fn minimizes_quadratic() {
        let cfg = AdamConfig { lr: 0.05, ..Default::default() };
        let mut adam = AdamShard::new(3, cfg);
        let target = [1.0f32, -2.0, 0.5];
        let mut x = vec![5.0f32, 5.0, 5.0];
        for _ in 0..600 {
            let grads: Vec<f32> =
                x.iter().zip(&target).map(|(xi, t)| 2.0 * (xi - t)).collect();
            adam.update(&mut x, &grads);
        }
        for (xi, t) in x.iter().zip(&target) {
            assert!((xi - t).abs() < 0.05, "{xi} vs {t}");
        }
    }

    /// Sharded update == full update (DESIGN.md's sharded-Adam
    /// equivalence): splitting parameters across shards and updating
    /// independently produces the same vector as one big update.
    #[test]
    fn sharded_equals_full() {
        let cfg = AdamConfig::default();
        let n = 101;
        let mut full = AdamShard::new(n, cfg);
        let mut params_full: Vec<f32> =
            (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let grads: Vec<f32> =
            (0..n).map(|i| (i as f32 * 0.11).cos()).collect();

        let layout = ShardLayout::by_ratios(n, &[0.5, 0.3, 0.2]);
        let mut params_sharded = params_full.clone();
        let mut shards: Vec<AdamShard> = (0..3)
            .map(|r| AdamShard::new(layout.size(r), cfg))
            .collect();

        for _ in 0..5 {
            full.update(&mut params_full, &grads);
            for r in 0..3 {
                let range = layout.range(r);
                shards[r].update(
                    &mut params_sharded[range.clone()],
                    &grads[range],
                );
            }
        }
        for (a, b) in params_full.iter().zip(&params_sharded) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with g, update ≈ -lr * sign(g).
        let cfg = AdamConfig { lr: 0.1, ..Default::default() };
        let mut adam = AdamShard::new(2, cfg);
        let mut x = vec![0.0f32, 0.0];
        adam.update(&mut x, &[1.0, -3.0]);
        assert!((x[0] + 0.1).abs() < 1e-3);
        assert!((x[1] - 0.1).abs() < 1e-3);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let cfg = AdamConfig {
            lr: 0.01,
            weight_decay: 0.1,
            ..Default::default()
        };
        let mut adam = AdamShard::new(1, cfg);
        let mut x = vec![10.0f32];
        for _ in 0..400 {
            adam.update(&mut x, &[0.0]);
        }
        assert!(x[0].abs() < 9.0);
    }
}
