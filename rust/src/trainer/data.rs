//! Synthetic training corpus: a seeded first-order Markov chain over the
//! vocabulary. The chain has real learnable structure (each token
//! strongly prefers a few successors), so the LM loss drops from
//! ~ln(vocab) at init toward the chain's conditional entropy — giving
//! the e2e example a meaningful loss curve without external data.

use crate::util::prng::Rng;

/// Markov-chain corpus generator.
pub struct Corpus {
    vocab: usize,
    /// For each token, `branch` candidate successors with geometric
    /// weights.
    successors: Vec<Vec<(usize, f64)>>,
    rng: Rng,
}

impl Corpus {
    /// `branch` successors per state; smaller branch = lower entropy =
    /// easier to learn.
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Corpus {
        assert!(vocab >= 2 && branch >= 1);
        let mut rng = Rng::new(seed);
        let successors = (0..vocab)
            .map(|_| {
                let mut weights = Vec::with_capacity(branch);
                let mut w = 1.0;
                for _ in 0..branch {
                    weights.push((rng.range(0, vocab), w));
                    w *= 0.5;
                }
                weights
            })
            .collect();
        Corpus { vocab, successors, rng: Rng::new(seed ^ 0xDA7A) }
    }

    /// Theoretical per-token conditional entropy (nats) of the chain —
    /// the loss floor a perfect model reaches.
    pub fn entropy(&self) -> f64 {
        // All states share the same weight profile.
        let ws: Vec<f64> =
            self.successors[0].iter().map(|(_, w)| *w).collect();
        let total: f64 = ws.iter().sum();
        -ws.iter().map(|w| (w / total) * (w / total).ln()).sum::<f64>()
    }

    fn next_token(&mut self, state: usize) -> usize {
        let weights: Vec<f64> =
            self.successors[state].iter().map(|(_, w)| *w).collect();
        let idx = self.rng.weighted(&weights);
        self.successors[state][idx].0
    }

    /// Sample a [batch, seq+1] token grid; returns (tokens, targets)
    /// each of batch*seq i32 (targets are tokens shifted by one).
    pub fn sample_batch(&mut self, batch: usize, seq: usize)
        -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut state = self.rng.range(0, self.vocab);
            let mut row = Vec::with_capacity(seq + 1);
            for _ in 0..=seq {
                row.push(state);
                state = self.next_token(state);
            }
            tokens.extend(row[..seq].iter().map(|&t| t as i32));
            targets.extend(row[1..].iter().map(|&t| t as i32));
        }
        (tokens, targets)
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

/// Split a (tokens, targets) batch row-wise into per-worker slices of
/// the given batch sizes (Σ sizes == batch rows).
pub fn split_batch(
    tokens: &[i32],
    targets: &[i32],
    seq: usize,
    sizes: &[usize],
) -> Vec<(Vec<i32>, Vec<i32>)> {
    let total: usize = sizes.iter().sum();
    assert_eq!(tokens.len(), total * seq);
    assert_eq!(targets.len(), total * seq);
    let mut out = Vec::with_capacity(sizes.len());
    let mut row = 0usize;
    for &b in sizes {
        let lo = row * seq;
        let hi = (row + b) * seq;
        out.push((tokens[lo..hi].to_vec(), targets[lo..hi].to_vec()));
        row += b;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(64, 4, 7);
        let mut b = Corpus::new(64, 4, 7);
        assert_eq!(a.sample_batch(3, 16), b.sample_batch(3, 16));
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut c = Corpus::new(64, 4, 1);
        let (tokens, targets) = c.sample_batch(2, 8);
        // Within each row, targets[i] == tokens[i+1].
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(targets[row * 8 + i], tokens[row * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let mut c = Corpus::new(32, 3, 2);
        let (tokens, targets) = c.sample_batch(4, 32);
        assert!(tokens.iter().all(|&t| (0..32).contains(&t)));
        assert!(targets.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn entropy_below_uniform() {
        let c = Corpus::new(1024, 4, 3);
        assert!(c.entropy() < (1024f64).ln());
        assert!(c.entropy() > 0.0);
        // 4 successors with geometric weights (8:4:2:1): H ~ 1.14 nats.
        assert!((c.entropy() - 1.14).abs() < 0.05, "{}", c.entropy());
    }

    #[test]
    fn split_batch_rows() {
        let mut c = Corpus::new(16, 2, 4);
        let (tokens, targets) = c.sample_batch(7, 4);
        let parts = split_batch(&tokens, &targets, 4, &[3, 1, 3]);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].0.len(), 12);
        assert_eq!(parts[1].0.len(), 4);
        assert_eq!(parts[0].0[..], tokens[..12]);
        assert_eq!(parts[2].1[..], targets[16..]);
    }
}
