//! Top-level coordination: profile -> optimize -> simulate/train, plus
//! the CLI application surface (`coordinator::app`).

pub mod app;
pub mod elastic;
pub mod real_profile;
pub mod report;

use crate::cluster::Cluster;
use crate::model::{find_model, TransformerSpec};
use crate::optimizer::{Assignment, DpOptimizer, DpStats, PlanError};
use crate::perfmodel::{ClusterPerfProfile, CollectiveModel, Profiler,
                       SyntheticOracle};
use crate::sim::cephalo::{simulate_assignment, IterStats};
use crate::sim::GaVariant;

/// Everything needed to evaluate one (cluster, model) workload.
pub struct Workload {
    pub cluster: Cluster,
    pub model: TransformerSpec,
    pub oracle: SyntheticOracle,
    pub profile: ClusterPerfProfile,
    pub collective: CollectiveModel,
}

impl Workload {
    /// Standard pipeline: build the synthetic oracle (the stand-in for
    /// profiling real GPUs; see DESIGN.md §Substitutions) and fit the
    /// performance models.
    pub fn prepare(cluster: Cluster, model_name: &str, seed: u64)
        -> Result<Workload, PlanError> {
        let model = find_model(model_name).ok_or_else(|| {
            PlanError::Infeasible(format!("unknown model '{model_name}'"))
        })?;
        let oracle = SyntheticOracle::new(&cluster, &model, seed);
        let profile = Profiler::default().profile(&cluster, &model, &oracle);
        let collective = CollectiveModel::from_cluster(&cluster);
        Ok(Workload { cluster, model, oracle, profile, collective })
    }

    /// Run the Cephalo optimizer.
    pub fn optimize(&self, batch: usize)
        -> Result<(Assignment, DpStats), PlanError> {
        DpOptimizer::default().solve(&self.profile, batch)
    }

    /// Optimize then simulate the full Cephalo execution (LGA+CO+S+O).
    pub fn cephalo_throughput(&self, batch: usize)
        -> Result<(Assignment, IterStats), PlanError> {
        let (asg, _) = self.optimize(batch)?;
        let stats = simulate_assignment(
            &self.model,
            &self.oracle,
            &self.collective,
            &asg,
            GaVariant::LGA_CO_S_O,
        );
        Ok((asg, stats))
    }

    /// Simulate an arbitrary assignment under a GA variant — used for
    /// the Fig.-7 ablations so every variant is measured on the SAME
    /// simulator (not its planner's optimistic model).
    pub fn simulate(&self, asg: &Assignment, variant: GaVariant)
        -> IterStats {
        simulate_assignment(
            &self.model,
            &self.oracle,
            &self.collective,
            asg,
            variant,
        )
    }

    /// Baseline planner context.
    pub fn ctx(&self, batch: usize) -> crate::baselines::PlanContext<'_> {
        crate::baselines::PlanContext {
            cluster: &self.cluster,
            model: &self.model,
            profile: &self.profile,
            oracle: &self.oracle,
            batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_and_optimize() {
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (asg, stats) = w.cephalo_throughput(128).unwrap();
        assert_eq!(asg.global_batch(), 128);
        assert!(stats.throughput > 0.0);
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(
            Workload::prepare(Cluster::cluster_a(), "GPT-9T", 1).is_err()
        );
    }

    #[test]
    fn cephalo_beats_every_baseline_bert_cluster_a() {
        // The paper's headline: Cephalo wins Table 4 across the board.
        use crate::baselines::*;
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (_, cephalo) = w.cephalo_throughput(128).unwrap();
        let planners: Vec<Box<dyn BaselinePlanner>> = vec![
            Box::new(megatron::MegatronHet),
            Box::new(flashflex::FlashFlex),
            Box::new(whale::Whale),
            Box::new(hap::Hap),
            Box::new(fsdp::FsdpBaseline),
        ];
        for p in planners {
            if let Ok(out) = p.plan(&w.ctx(128)) {
                assert!(
                    cephalo.throughput > out.throughput,
                    "{} ({}) beat cephalo ({})",
                    p.name(),
                    out.throughput,
                    cephalo.throughput
                );
            }
        }
    }
}
