//! Top-level coordination: profile -> plan (registry/cache/sweep) ->
//! simulate/train, plus the CLI application surface
//! (`coordinator::app`) and elastic re-planning
//! (`coordinator::elastic`).

#![warn(missing_docs)]

pub mod app;
pub mod elastic;
#[cfg(feature = "xla")]
pub mod real_profile;
pub mod report;
pub mod session;

use crate::cluster::Cluster;
use crate::model::{find_model, TransformerSpec};
use crate::optimizer::{Assignment, DpOptimizer, DpStats, PlanError};
use crate::perfmodel::{ClusterPerfProfile, CollectiveModel, Profiler,
                       SyntheticOracle};
use crate::plan::{sweep, PlanCache, PlanContext, PlanOutcome,
                  PlannerRegistry, SweepCell};
use crate::sim::cephalo::{simulate_assignment, IterStats};
use crate::sim::GaVariant;

/// Everything needed to evaluate one (cluster, model) workload.
pub struct Workload {
    /// The heterogeneous GPU cluster being planned for.
    pub cluster: Cluster,
    /// The transformer being trained (a Table-1 architecture).
    pub model: TransformerSpec,
    /// Synthetic profiling oracle (the stand-in for timing real GPUs).
    pub oracle: SyntheticOracle,
    /// Fitted per-GPU compute/memory performance models.
    pub profile: ClusterPerfProfile,
    /// Fitted collective-communication cost model.
    pub collective: CollectiveModel,
    /// `plan::fingerprint(cluster, profile)`, memoized so every
    /// `ctx()`/cache lookup is a hash probe, not a profile re-render.
    pub fingerprint: u64,
}

impl Workload {
    /// Standard pipeline: build the synthetic oracle (the stand-in for
    /// profiling real GPUs; see DESIGN.md §Substitutions) and fit the
    /// performance models.
    pub fn prepare(cluster: Cluster, model_name: &str, seed: u64)
        -> Result<Workload, PlanError> {
        let model = find_model(model_name).ok_or_else(|| {
            PlanError::Infeasible(format!("unknown model '{model_name}'"))
        })?;
        let oracle = SyntheticOracle::new(&cluster, &model, seed);
        let profile = Profiler::default().profile(&cluster, &model, &oracle);
        let collective = CollectiveModel::from_cluster(&cluster);
        let fingerprint = crate::plan::fingerprint(&cluster, &profile);
        Ok(Workload {
            cluster,
            model,
            oracle,
            profile,
            collective,
            fingerprint,
        })
    }

    /// Run the Cephalo optimizer.
    pub fn optimize(&self, batch: usize)
        -> Result<(Assignment, DpStats), PlanError> {
        DpOptimizer::default().solve(&self.profile, batch)
    }

    /// Optimize then simulate the full Cephalo execution (LGA+CO+S+O).
    pub fn cephalo_throughput(&self, batch: usize)
        -> Result<(Assignment, IterStats), PlanError> {
        let (asg, _) = self.optimize(batch)?;
        let stats = simulate_assignment(
            &self.model,
            &self.oracle,
            &self.collective,
            &asg,
            GaVariant::LGA_CO_S_O,
        );
        Ok((asg, stats))
    }

    /// Simulate an arbitrary assignment under a GA variant — used for
    /// the Fig.-7 ablations so every variant is measured on the SAME
    /// simulator (not its planner's optimistic model).
    pub fn simulate(&self, asg: &Assignment, variant: GaVariant)
        -> IterStats {
        simulate_assignment(
            &self.model,
            &self.oracle,
            &self.collective,
            asg,
            variant,
        )
    }

    /// Planner context at `batch` (every `plan::Planner` input).
    pub fn ctx(&self, batch: usize) -> PlanContext<'_> {
        PlanContext {
            cluster: &self.cluster,
            model: &self.model,
            profile: &self.profile,
            oracle: &self.oracle,
            batch,
            cluster_fingerprint: self.fingerprint,
            intra_gbps: self.cluster.intra_bw_min_gbps(),
            inter_gbps: self.cluster.inter_bw_gbps,
        }
    }

    /// Plan through a registry entry by name, optionally memoized.
    pub fn plan_with(
        &self,
        registry: &PlannerRegistry,
        name: &str,
        batch: usize,
        cache: Option<&PlanCache>,
    ) -> Result<PlanOutcome, PlanError> {
        let planner = registry.get(name).ok_or_else(|| {
            PlanError::Infeasible(format!("unknown planner '{name}'"))
        })?;
        match cache {
            Some(c) => c.get_or_plan(&*planner, &self.ctx(batch)),
            None => planner.plan(&self.ctx(batch)),
        }
    }

    /// Solve every registered planner at every batch in parallel (cells
    /// in planner-major order — see `plan::sweep`).
    pub fn sweep(
        &self,
        registry: &PlannerRegistry,
        batches: &[usize],
        cache: Option<&PlanCache>,
    ) -> Vec<SweepCell> {
        sweep(&self.ctx(0), registry.planners(), batches, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_and_optimize() {
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (asg, stats) = w.cephalo_throughput(128).unwrap();
        assert_eq!(asg.global_batch(), 128);
        assert!(stats.throughput > 0.0);
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(
            Workload::prepare(Cluster::cluster_a(), "GPT-9T", 1).is_err()
        );
    }

    #[test]
    fn cephalo_beats_every_baseline_bert_cluster_a() {
        // The paper's headline: Cephalo wins Table 4 across the board —
        // asserted through the unified registry sweep.
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (_, cephalo) = w.cephalo_throughput(128).unwrap();
        let registry = PlannerRegistry::with_defaults();
        for name in ["Megatron-Het", "FlashFlex", "Whale", "HAP", "FSDP"] {
            if let Ok(out) = w.plan_with(&registry, name, 128, None) {
                assert!(
                    cephalo.throughput > out.throughput,
                    "{name} ({}) beat cephalo ({})",
                    out.throughput,
                    cephalo.throughput
                );
            }
        }
    }

    #[test]
    fn workload_sweep_covers_the_grid() {
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let registry = PlannerRegistry::with_defaults();
        let cache = PlanCache::new();
        let cells = w.sweep(&registry, &[64, 128], Some(&cache));
        assert_eq!(cells.len(), registry.len() * 2);
        // The Cephalo cells must be feasible on BERT-Large.
        let cephalo: Vec<_> =
            cells.iter().filter(|c| c.planner == "Cephalo").collect();
        assert_eq!(cephalo.len(), 2);
        assert!(cephalo.iter().all(|c| c.throughput().is_some()));
        // Re-sweeping is served entirely from cache.
        let before = cache.misses();
        let again = w.sweep(&registry, &[64, 128], Some(&cache));
        assert_eq!(cache.misses(), before);
        assert!(again
            .iter()
            .all(|c| match &c.result {
                Ok(o) => o.diagnostics.cache_hit,
                Err(_) => true, // cached failures are indistinguishable
            }));
    }
}
