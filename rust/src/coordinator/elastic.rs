//! Elastic re-planning: adapt a running job to cluster membership
//! changes — the workflow Fig. 1 motivates (cloud GPUs appear and
//! vanish hour to hour).
//!
//! Given the old assignment + shard layout and a NEW cluster, this
//! module re-plans THROUGH the unified planner interface (any
//! `plan::Planner` that yields an `Assignment`, memoized by an optional
//! `plan::PlanCache`) and computes a **state migration plan**: which
//! contiguous byte ranges of the flat training state (16 B/param:
//! parameters + Adam moments) each surviving GPU must send/receive so
//! the new shard layout is materialized with minimal traffic (only the
//! deltas move; bytes already resident stay put).
//!
//! The cache is what makes elasticity cheap in practice: cloud
//! memberships recur (Fig. 1's hourly availability oscillates between
//! a few states), and a re-plan over a previously seen membership is a
//! lookup instead of a DP solve.

use crate::optimizer::{Assignment, PlanError};
use crate::perfmodel::ClusterPerfProfile;
use crate::plan::{PlanCache, PlanContext, Planner};
use crate::sharding::ShardLayout;

/// One transfer in the migration plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Transfer {
    /// Source GPU in the OLD layout (None = must be restored from the
    /// checkpoint/leader — its old owner left the cluster).
    pub from: Option<usize>,
    /// Destination GPU in the NEW layout.
    pub to: usize,
    /// Flat element range being moved.
    pub start: usize,
    /// Length of the range, in elements.
    pub len: usize,
}

/// Result of an elastic re-plan.
#[derive(Debug)]
pub struct Replan {
    /// The new batch/stage assignment for the surviving membership.
    pub assignment: Assignment,
    /// The new shard layout the assignment implies.
    pub new_layout: ShardLayout,
    /// Ranges to move, in deterministic destination-major order.
    pub transfers: Vec<Transfer>,
    /// Elements that stay on their current owner (no traffic).
    pub resident_elems: usize,
    /// Elements that move between GPUs or from the checkpoint.
    pub moved_elems: usize,
    /// True when the plan came from the `PlanCache` (recurring
    /// membership) instead of a fresh solve.
    pub from_cache: bool,
    /// Planning wall-clock (0 on cache hits).
    pub solve_seconds: f64,
}

impl Replan {
    /// Migration traffic in bytes (16 B per element of training state).
    pub fn migration_bytes(&self) -> f64 {
        self.moved_elems as f64 * 16.0
    }
}

/// Map each flat element range of the new layout onto its old owner,
/// emitting transfers only where ownership changes.
///
/// `survivor_map[new_gpu] = Some(old_gpu_index)` identifies which old
/// rank (if any) the new rank is the same physical GPU as.
pub fn plan_migration(
    old_layout: &ShardLayout,
    new_layout: &ShardLayout,
    survivor_map: &[Option<usize>],
) -> (Vec<Transfer>, usize, usize) {
    assert_eq!(new_layout.len(), old_layout.len(),
               "state size changed between plans");
    assert_eq!(survivor_map.len(), new_layout.num_ranks());
    let mut transfers = Vec::new();
    let mut resident = 0usize;
    let mut moved = 0usize;

    // Reverse map: old gpu -> new gpu (if it survived).
    for new_gpu in 0..new_layout.num_ranks() {
        let range = new_layout.range(new_gpu);
        if range.is_empty() {
            continue;
        }
        // Walk the old layout's ranks overlapping this range.
        let mut pos = range.start;
        while pos < range.end {
            // Find old owner of `pos`.
            let old_owner = (0..old_layout.num_ranks())
                .find(|&r| old_layout.range(r).contains(&pos));
            let old_end = old_owner
                .map(|r| old_layout.range(r).end)
                .unwrap_or(range.end);
            let chunk_end = range.end.min(old_end);
            let len = chunk_end - pos;
            let stays = old_owner.is_some()
                && survivor_map[new_gpu] == old_owner;
            if stays {
                resident += len;
            } else {
                moved += len;
                transfers.push(Transfer {
                    from: old_owner.and_then(|r| {
                        // The old rank only still exists if some new
                        // rank maps to it.
                        survivor_map
                            .iter()
                            .position(|s| *s == Some(r))
                            .map(|_| r)
                    }),
                    to: new_gpu,
                    start: pos,
                    len,
                });
            }
            pos = chunk_end;
        }
    }
    (transfers, resident, moved)
}

/// Materialize a migration plan against live state: build each NEW
/// rank's contiguous shard of the flat state vector by (a) copying the
/// resident ranges straight from the surviving old shards and (b)
/// applying the transfer list — peer copies for `from: Some(_)`,
/// checkpoint restores (`reference`, the leader-view full vector) for
/// `from: None` (the old owner left the cluster, so its memory is
/// gone). Invariant 4 extended to execution: resident + transferred +
/// restored ranges cover the new layout exactly once, so every output
/// element is written exactly once (property-tested over churn
/// sequences below).
///
/// Call once per migrating vector (Adam m, Adam v, ...): the plan is
/// layout-level and shared.
pub fn apply_migration(
    old_layout: &ShardLayout,
    old_shards: &[&[f32]],
    new_layout: &ShardLayout,
    survivor_map: &[Option<usize>],
    transfers: &[Transfer],
    reference: &[f32],
) -> Vec<Vec<f32>> {
    assert_eq!(old_shards.len(), old_layout.num_ranks());
    assert_eq!(survivor_map.len(), new_layout.num_ranks());
    assert_eq!(reference.len(), new_layout.len());
    for (r, s) in old_shards.iter().enumerate() {
        assert_eq!(s.len(), old_layout.size(r), "old shard {r} size");
    }
    let mut out: Vec<Vec<f32>> = (0..new_layout.num_ranks())
        .map(|r| vec![0f32; new_layout.size(r)])
        .collect();
    // Resident prefill: where the new rank IS the old owner, the
    // overlap of its old and new ranges never leaves the device.
    for (new_gpu, survivor) in survivor_map.iter().enumerate() {
        let Some(old_gpu) = survivor else { continue };
        let nr = new_layout.range(new_gpu);
        let or = old_layout.range(*old_gpu);
        let lo = nr.start.max(or.start);
        let hi = nr.end.min(or.end);
        if lo < hi {
            out[new_gpu][lo - nr.start..hi - nr.start].copy_from_slice(
                &old_shards[*old_gpu][lo - or.start..hi - or.start],
            );
        }
    }
    // The transfer list: everything that moves between GPUs or comes
    // back from the checkpoint.
    for t in transfers {
        let nr = new_layout.range(t.to);
        debug_assert!(nr.start <= t.start && t.start + t.len <= nr.end);
        let dst =
            &mut out[t.to][t.start - nr.start..t.start + t.len - nr.start];
        match t.from {
            Some(src) => {
                let or = old_layout.range(src);
                dst.copy_from_slice(
                    &old_shards[src]
                        [t.start - or.start..t.start + t.len - or.start],
                );
            }
            None => {
                dst.copy_from_slice(&reference[t.start..t.start + t.len]);
            }
        }
    }
    out
}

/// Re-plan after cluster membership changed, through the unified
/// planner interface.
///
/// * `old_assignment` / `old_profile` — the running configuration.
/// * `new_ctx` — planner context for the surviving/expanded cluster at
///   the (possibly unchanged) global batch.
/// * `survivor_map[new_gpu]` — the old index of each new GPU (None for
///   newly added GPUs).
/// * `planner` — any registered strategy that yields an `Assignment`
///   (the Cephalo DP by default — see [`replan_default`]).
/// * `cache` — optional memoization; recurring memberships hit.
pub fn replan(
    old_assignment: &Assignment,
    old_profile: &ClusterPerfProfile,
    new_ctx: &PlanContext<'_>,
    survivor_map: &[Option<usize>],
    planner: &dyn Planner,
    cache: Option<&PlanCache>,
) -> Result<Replan, PlanError> {
    let outcome = match cache {
        Some(c) => c.get_or_plan(planner, new_ctx)?,
        None => planner.plan(new_ctx)?,
    };
    let assignment = outcome.assignment.ok_or_else(|| {
        PlanError::Internal(format!(
            "planner '{}' yields no per-GPU assignment; elastic \
             re-planning needs one",
            outcome.planner
        ))
    })?;
    // Flat state layouts (in elements) from the ratio vectors; use the
    // parameter count as the flat length (moments scale with it).
    let total = old_profile.total_params as usize;
    let old_ratios: Vec<f64> =
        old_assignment.per_gpu.iter().map(|g| g.state_ratio).collect();
    let new_ratios: Vec<f64> =
        assignment.per_gpu.iter().map(|g| g.state_ratio).collect();
    let old_layout = ShardLayout::by_ratios(total, &old_ratios);
    let new_layout = ShardLayout::by_ratios(total, &new_ratios);
    let (transfers, resident_elems, moved_elems) =
        plan_migration(&old_layout, &new_layout, survivor_map);
    Ok(Replan {
        assignment,
        new_layout,
        transfers,
        resident_elems,
        moved_elems,
        from_cache: outcome.diagnostics.cache_hit,
        solve_seconds: outcome.diagnostics.solve_seconds,
    })
}

/// [`replan`] with the default Cephalo DP planner and no cache — the
/// drop-in for the old signature.
pub fn replan_default(
    old_assignment: &Assignment,
    old_profile: &ClusterPerfProfile,
    new_ctx: &PlanContext<'_>,
    survivor_map: &[Option<usize>],
) -> Result<Replan, PlanError> {
    replan(
        old_assignment,
        old_profile,
        new_ctx,
        survivor_map,
        &crate::plan::CephaloPlanner::default(),
        None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::Workload;
    use crate::testkit::check;

    #[test]
    fn identity_replan_moves_nothing() {
        let layout = ShardLayout::by_ratios(1000, &[0.5, 0.3, 0.2]);
        let survivors = vec![Some(0), Some(1), Some(2)];
        let (transfers, resident, moved) =
            plan_migration(&layout, &layout, &survivors);
        assert!(transfers.is_empty());
        assert_eq!(resident, 1000);
        assert_eq!(moved, 0);
    }

    #[test]
    fn lost_gpu_state_is_resourced() {
        // 3 GPUs -> 2 survivors (old rank 1 left).
        let old = ShardLayout::by_ratios(900, &[1.0, 1.0, 1.0]);
        let new = ShardLayout::by_ratios(900, &[0.5, 0.5]);
        let survivors = vec![Some(0), Some(2)];
        let (transfers, resident, moved) =
            plan_migration(&old, &new, &survivors);
        assert_eq!(resident + moved, 900);
        // Old rank 0's first 300 elements stay on new rank 0.
        assert_eq!(resident, 300 + 300); // rank0 keeps 300; old rank2's
                                         // last 300 land on new rank 1
        // The departed rank 1's range must be transferred with from=None
        // only if rank1 truly vanished from the survivor map.
        let orphan: usize = transfers
            .iter()
            .filter(|t| t.from.is_none())
            .map(|t| t.len)
            .sum();
        assert_eq!(orphan, 300, "old rank 1's shard must be restored");
    }

    #[test]
    fn prop_migration_covers_new_layout_exactly() {
        check("migration-coverage", 100, |g| {
            let total = g.usize_in(10, 5000);
            let n_old = g.usize_in(1, 6);
            let n_new = g.usize_in(1, 6);
            let old = ShardLayout::by_ratios(total, &g.ratios(n_old));
            let new = ShardLayout::by_ratios(total, &g.ratios(n_new));
            let survivors: Vec<Option<usize>> = (0..n_new)
                .map(|i| if i < n_old && g.bool() { Some(i) } else { None })
                .collect();
            let (transfers, resident, moved) =
                plan_migration(&old, &new, &survivors);
            assert_eq!(resident + moved, total);
            // Transfers are disjoint and within bounds.
            let mut covered = vec![false; total];
            for t in &transfers {
                for i in t.start..t.start + t.len {
                    assert!(!covered[i], "overlap at {i}");
                    covered[i] = true;
                }
                // Destination must own the range in the new layout.
                let r = new.range(t.to);
                assert!(r.start <= t.start && t.start + t.len <= r.end);
            }
            assert_eq!(covered.iter().filter(|&&c| c).count(), moved);
        });
    }

    #[test]
    fn prop_migration_sequences_cover_and_apply_exactly() {
        // DESIGN.md invariant 4 extended from one-shot to SEQUENCES:
        // over random churn chains (random layouts, random survivor
        // maps, r_i = 0 ranks included), resident + transferred +
        // restored ranges cover each new layout exactly once — verified
        // at the data level by applying every migration to live shards
        // and checking them against the ground-truth vector.
        check("migration-sequences", 60, |g| {
            let total = g.usize_in(50, 2000);
            // Ground truth: distinguishable values per element.
            let reference: Vec<f32> =
                (0..total).map(|i| i as f32 * 0.5 + 1.0).collect();
            let n0 = g.usize_in(1, 5);
            let mut layout =
                ShardLayout::by_ratios(total, &g.sparse_ratios(n0));
            let mut shards: Vec<Vec<f32>> = (0..n0)
                .map(|r| reference[layout.range(r)].to_vec())
                .collect();
            for _event in 0..g.usize_in(2, 6) {
                let n_new = g.usize_in(1, 5);
                let survivors: Vec<Option<usize>> = (0..n_new)
                    .map(|i| {
                        if i < layout.num_ranks() && g.bool() {
                            Some(i)
                        } else {
                            None
                        }
                    })
                    .collect();
                let new_layout =
                    ShardLayout::by_ratios(total, &g.sparse_ratios(n_new));
                let (transfers, resident, moved) =
                    plan_migration(&layout, &new_layout, &survivors);
                assert_eq!(resident + moved, total);
                // Transfers: disjoint, in-bounds, and peer sources must
                // be surviving old ranks that own the range.
                let mut covered = vec![false; total];
                for t in &transfers {
                    for i in t.start..t.start + t.len {
                        assert!(!covered[i], "overlap at {i}");
                        covered[i] = true;
                    }
                    let r = new_layout.range(t.to);
                    assert!(
                        r.start <= t.start && t.start + t.len <= r.end
                    );
                    if let Some(src) = t.from {
                        assert!(
                            survivors.iter().any(|s| *s == Some(src)),
                            "transfer from departed rank {src}"
                        );
                        let or = layout.range(src);
                        assert!(
                            or.start <= t.start
                                && t.start + t.len <= or.end
                        );
                    }
                }
                assert_eq!(
                    covered.iter().filter(|&&c| c).count(),
                    moved
                );
                // Apply. Any coverage gap would leave a 0.0 (reference
                // values are all >= 1.0), any overlap was caught above.
                let views: Vec<&[f32]> =
                    shards.iter().map(|s| s.as_slice()).collect();
                let new_shards = apply_migration(
                    &layout, &views, &new_layout, &survivors,
                    &transfers, &reference,
                );
                for r in 0..n_new {
                    assert_eq!(
                        new_shards[r].as_slice(),
                        &reference[new_layout.range(r)],
                        "rank {r} state corrupted after migration"
                    );
                }
                layout = new_layout;
                shards = new_shards;
            }
        });
    }

    #[test]
    fn end_to_end_replan_on_gpu_loss() {
        // Cluster A loses its A6000 (the big-memory GPU): the re-plan
        // must redistribute its state to the P40s and stay feasible.
        let full = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (old_asg, _) = full.optimize(64).unwrap();

        let mut degraded = Cluster::cluster_a();
        degraded.nodes[0].gpus.remove(2); // the A6000
        let small = Workload::prepare(degraded, "BERT-Large", 42).unwrap();
        // New rank i maps to old rank (skipping old index 2).
        let survivor_map: Vec<Option<usize>> =
            vec![Some(0), Some(1), Some(3), Some(4), Some(5), Some(6),
                 Some(7)];
        let re = replan_default(&old_asg, &full.profile, &small.ctx(64),
                                &survivor_map)
            .expect("replan feasible");
        assert_eq!(re.assignment.global_batch(), 64);
        assert!(!re.from_cache);
        assert!(re.moved_elems > 0, "A6000's ~40% state share must move");
        assert!(re.migration_bytes() > 0.0);
        // Conservation.
        assert_eq!(
            re.resident_elems + re.moved_elems,
            full.profile.total_params as usize
        );
    }

    #[test]
    fn replan_on_unchanged_cluster_is_served_from_cache() {
        // Acceptance: an elastic re-plan over a membership the cache
        // has already seen is a lookup, not a solve.
        let planner = crate::plan::CephaloPlanner::default();
        let cache = crate::plan::PlanCache::new();
        let full = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (old_asg, _) = full.optimize(64).unwrap();
        let survivors: Vec<Option<usize>> = (0..8).map(Some).collect();

        let first = replan(&old_asg, &full.profile, &full.ctx(64),
                           &survivors, &planner, Some(&cache))
            .unwrap();
        assert!(!first.from_cache);
        assert_eq!(cache.misses(), 1);

        let second = replan(&old_asg, &full.profile, &full.ctx(64),
                            &survivors, &planner, Some(&cache))
            .unwrap();
        assert!(second.from_cache, "unchanged cluster must hit the cache");
        assert_eq!(cache.hits(), 1);
        assert_eq!(second.assignment, first.assignment);
        assert_eq!(second.solve_seconds, 0.0);
        // Identity membership + identical plan: nothing moves.
        assert_eq!(second.moved_elems, first.moved_elems);
    }

    #[test]
    fn replan_rejects_planners_without_assignments() {
        let full = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let (old_asg, _) = full.optimize(64).unwrap();
        let survivors: Vec<Option<usize>> = (0..8).map(Some).collect();
        let err = replan(&old_asg, &full.profile, &full.ctx(64),
                         &survivors, &crate::baselines::whale::Whale, None)
            .unwrap_err();
        assert!(err.to_string().contains("no per-GPU assignment"), "{err}");
    }
}
