//! Real profiling through PJRT (§3.1 on this testbed): time the
//! AOT-compiled single-layer forward at each compiled microbatch size.
//!
//! This is the CPU-host analogue of the paper's profiler — the numbers
//! feed the Fig.-5 "real" series and the e2e example's reporting. For
//! heterogeneous *simulation* the synthetic oracle is used instead
//! (DESIGN.md §Substitutions); this module proves the profiling code
//! path against real executions.

use std::path::Path;
use std::time::Instant;

use crate::util::error::Result;

use crate::runtime::XlaEngine;
use crate::util::prng::Rng;

/// One measured point.
#[derive(Debug, Clone)]
pub struct LayerSample {
    /// Microbatch size the executable was compiled for.
    pub microbatch: usize,
    /// Mean wall time per forward over `reps` repetitions.
    pub mean_seconds: f64,
    /// Fastest single repetition (the least-noisy estimate).
    pub min_seconds: f64,
    /// Number of timed repetitions.
    pub reps: usize,
}

/// Time `layer_fwd` for each compiled microbatch size.
pub fn profile_layer_fwd(artifacts_dir: &Path, reps: usize)
    -> Result<Vec<LayerSample>> {
    let engine = XlaEngine::load(artifacts_dir, &["layer_fwd"])?;
    let manifest = engine.manifest().clone();
    let seq = manifest.model.seq_len;
    let d = manifest.model.d_model;
    let dff = manifest.model.d_ff;

    // Unstacked single-layer parameter shapes (layer_forward order).
    let layer_shapes: Vec<Vec<usize>> = vec![
        vec![d],        // ln1_scale
        vec![d],        // ln1_bias
        vec![d, d],     // wq
        vec![d, d],     // wk
        vec![d, d],     // wv
        vec![d, d],     // wo
        vec![d],        // ln2_scale
        vec![d],        // ln2_bias
        vec![d, dff],   // w1
        vec![dff],      // b1
        vec![dff, d],   // w2
        vec![d],        // b2
    ];
    let mut rng = Rng::new(7);
    let layer_params: Vec<Vec<f32>> = layer_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.iter().product();
            match i {
                0 | 6 => vec![1.0; n],          // scales
                1 | 7 | 9 | 11 => vec![0.0; n], // biases
                _ => {
                    let mut v = vec![0f32; n];
                    rng.fill_normal(&mut v, 0.02);
                    v
                }
            }
        })
        .collect();

    let mut out = Vec::new();
    for m in engine.available("layer_fwd") {
        let mut x = vec![0f32; m * seq * d];
        rng.fill_normal(&mut x, 1.0);
        // Warmup.
        engine.layer_fwd(&x, &layer_params, &layer_shapes, m)?;
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            let y = engine.layer_fwd(&x, &layer_params, &layer_shapes, m)?;
            times.push(t0.elapsed().as_secs_f64());
            assert_eq!(y.len(), x.len());
        }
        out.push(LayerSample {
            microbatch: m,
            mean_seconds: crate::util::stats::mean(&times),
            min_seconds: crate::util::stats::min(&times),
            reps,
        });
    }
    Ok(out)
}
