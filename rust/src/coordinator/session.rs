//! Live elastic training session: the Fig.-1 workflow end to end, on
//! real numerics, in the default (no-`xla`) build.
//!
//! A [`Session`] owns a training engine and reacts to cluster churn the
//! way the paper's coordinator does:
//!
//! 1. **churn event** — an `cluster/aws_trace` hour folds onto a
//!    membership size (`aws_trace::membership_size`); the live cluster
//!    is the corresponding prefix of the base cluster;
//! 2. **re-plan** — through the PR-1 planner registry interface with a
//!    shared [`PlanCache`] (optionally persisted to JSON, so a resumed
//!    session keeps recurring-membership plans warm);
//! 3. **migrate** — `elastic::plan_migration` emits the transfer list
//!    at both scales: the PLANNING scale (the Table-2 model's
//!    parameter count, for reported traffic) and the EXECUTED scale
//!    (the running trainer's flat state); the executed-scale list is
//!    applied to the resident Adam shards;
//! 4. **resume** — training continues on the same corpus stream; with
//!    the native backend's exact gradient summation, parameters stay
//!    bitwise on the single-worker reference trajectory across every
//!    migration (asserted in `tests/elastic_session.rs` and
//!    `tests/dist_session.rs`).
//!
//! The engine behind steps 3–4 is selected by
//! [`SessionConfig::fabric`]:
//!
//! * `None` — the in-process [`Trainer`] (historical default): all
//!    worker state in one address space, migration via
//!    `elastic::apply_migration` + [`Trainer::adopt`].
//! * `Some(FabricSpec)` — a [`DistDriver`]: one SPMD rank per cluster
//!    GPU over channels (`local`) or TCP sockets (`tcp`, threads or
//!    spawned `cephalo worker` processes), with the SAME transfer list
//!    executed as rank-to-rank wire traffic. Both engines produce
//!    bit-identical trajectories (DESIGN.md invariant 10).

//! Fault tolerance: with [`SessionConfig::ft`] (or a chaos spec) on a
//! distributed fabric, the session polls the driver's failure detector
//! before every migration and every step. A suspected rank first gets
//! a bounded rejoin window ([`SessionConfig::rejoin_window_ms`]): if
//! it answers the REJOIN handshake with a shard fingerprint matching
//! the driver's ledger it resumes in place — zero bytes move, no
//! migration is planned — and with a stale fingerprint it is
//! re-streamed from the mirror like a fresh joiner. A rank that never
//! answers inside the window is declared dead and synthesized into the
//! SAME elastic departure path as a trace-driven shrink — re-plan via
//! the cache, wire-migrate with the mirror (spread across survivors by
//! [`crate::transport::MirrorLayout`] by default, rank-0 flat under
//! [`SessionConfig::mirror_leader`]) substituting for the corpse — so
//! a crash-recovered session is bitwise identical to one that planned
//! the same membership change gracefully (DESIGN.md invariants 12 and
//! 15). Dead ranks clamp `max_live`, so later regrow events never
//! re-admit a corpse.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{aws_trace, Cluster, Node};
use crate::coordinator::{elastic, Workload};
use crate::exec::{NativeExecutor, StepTimeModel, SurrogateSpec};
use crate::optimizer::Assignment;
use crate::plan::{PlanCache, Planner};
use crate::sharding::ShardLayout;
use crate::trainer::adam::{AdamConfig, AdamShard};
use crate::trainer::{StepStats, TrainConfig, Trainer};
use crate::transport::{
    ChaosConfig, ChaosOpts, DistConfig, DistDriver, FabricSpec, FaultPlan,
    PollReport,
};
use crate::util::error::{anyhow, Result};

/// Session configuration. `model`/`batch` drive the PLANNING scale
/// (profiles, DP, migration-traffic accounting); `surrogate` is the
/// EXECUTED model the native backend actually trains.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Table-2 model used for profiling and planning.
    pub model: String,
    /// Global batch, held constant across churn (what keeps the data
    /// stream — and the reference trajectory — membership-invariant).
    pub batch: usize,
    /// Training steps to run after each membership change.
    pub steps_per_event: usize,
    /// Seed for weight init, the corpus stream and chaos schedules.
    pub seed: u64,
    /// Adam hyperparameters shared by every engine.
    pub adam: AdamConfig,
    /// Smallest membership a churn event may shrink to; 0 = auto
    /// (two below the full cluster, at least 1).
    pub min_gpus: usize,
    /// The native backend's executed model.
    pub surrogate: SurrogateSpec,
    /// `None` = in-process trainer; `Some(spec)` = one SPMD rank per
    /// cluster GPU over the given transport fabric.
    pub fabric: Option<FabricSpec>,
    /// Fully-sharded parameters: no leader-resident weight copy; each
    /// rank keeps only its `r_i` slice and migrations move weight
    /// ranges alongside the Adam moments. Bitwise-identical to the
    /// leader-resident default (DESIGN.md invariant 11).
    pub shard_params: bool,
    /// FSDP unit count for sharded engines: split the parameters into
    /// this many per-layer groups and gather/free them one at a time
    /// (next unit prefetched during compute) instead of materializing
    /// the whole model per step. `<= 1` keeps whole-model gather.
    /// Bitwise-identical either way (DESIGN.md invariant 13).
    pub fsdp_units: usize,
    /// When set, the plan cache is loaded from this JSON file at
    /// session start (if it exists) and can be saved back with
    /// [`Session::save_plan_cache`] — recurring memberships stay warm
    /// across restarts.
    pub plan_cache_path: Option<PathBuf>,
    /// Fault-tolerant mode (distributed fabrics only): keep the state
    /// mirror current every step, probe liveness at step boundaries,
    /// and recover detected-dead ranks through the elastic departure
    /// path. Implied by `chaos`.
    pub ft: bool,
    /// Use the legacy rank-0 flat mirror instead of the default
    /// [`crate::transport::MirrorLayout`] sharded placement. Recovery
    /// is bitwise identical either way (DESIGN.md invariant 15).
    pub mirror_leader: bool,
    /// Bounded rejoin window (`--rejoin-window`, milliseconds): how
    /// long the driver courts a suspected rank with REJOIN handshakes
    /// before declaring it dead. 0 = legacy behavior, suspicion is
    /// death.
    pub rejoin_window_ms: u64,
    /// How long a liveness probe waits for its PING echo before the
    /// rank is suspected (milliseconds).
    pub ping_timeout_ms: u64,
    /// Deterministic fault injection: a `seed=N[,crash=..,..]` spec
    /// (see [`ChaosConfig::parse`]) wrapping every worker endpoint in a
    /// seeded [`crate::transport::ChaosTransport`]. Requires a
    /// distributed fabric.
    pub chaos: Option<String>,
    /// Rank → host-id map for hybrid fabrics (`--hosts`): same-host
    /// lanes ride shm, rings walk a locality-sorted order. `None` =
    /// single host. Forwarded verbatim into [`DistConfig::hosts`].
    pub hosts: Option<Vec<u64>>,
    /// Trace-output base path (`--trace-out`), forwarded into
    /// [`DistConfig::trace_out`] so spawned worker processes write
    /// per-rank traces. The coordinator's own trace file is written by
    /// the CLI at exit.
    pub trace_out: Option<String>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            model: "BERT-Large".into(),
            batch: 64,
            steps_per_event: 5,
            seed: 42,
            adam: AdamConfig::default(),
            min_gpus: 0,
            surrogate: SurrogateSpec::default(),
            fabric: None,
            shard_params: false,
            fsdp_units: 1,
            plan_cache_path: None,
            ft: false,
            mirror_leader: false,
            rejoin_window_ms: 0,
            ping_timeout_ms: 2000,
            chaos: None,
            hosts: None,
            trace_out: None,
        }
    }
}

/// What one churn event did.
#[derive(Debug, Clone)]
pub struct EventReport {
    /// Ordinal of this churn event within the session.
    pub event: usize,
    /// Trace hour the event's membership size came from.
    pub hour: usize,
    /// Membership size after the event.
    pub gpus: usize,
    /// True when the re-plan was served by the shared [`PlanCache`].
    pub from_cache: bool,
    /// Wall time of the re-plan (0 on cache hits).
    pub solve_seconds: f64,
    /// Planning-scale migration traffic (16 B per Table-2 parameter).
    pub migration_bytes: f64,
    /// Executed-scale elements actually copied between shards or
    /// restored from the checkpoint.
    pub moved_state_elems: usize,
    /// Training steps executed in this event.
    pub steps: usize,
    /// Mean per-token loss over the event's steps.
    pub mean_loss: f64,
    /// Steps/sec under the executor's `step_seconds` timing hook —
    /// MODELED time when a `StepTimeModel` is attached (the number the
    /// planner's throughput predictions are comparable to).
    pub steps_per_sec: f64,
    /// Steps/sec on actually measured wall time — what this host
    /// really executed. Kept separate from `steps_per_sec` so logs and
    /// bench output can never conflate simulated with executed rates.
    pub measured_steps_per_sec: f64,
}

/// What one crash recovery did (ft sessions; one entry per
/// failure-detector poll that found newly dead ranks).
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// Trace hour of the enclosing churn event.
    pub hour: usize,
    /// Global steps executed when the failure was detected.
    pub step: usize,
    /// The newly dead ranks, ascending.
    pub ranks: Vec<usize>,
    /// Membership size after recovery.
    pub gpus: usize,
    /// Wall time the liveness poll took to return the verdict.
    pub detect_ms: f64,
    /// Wall time of the (cache-assisted) re-plan; 0 when the dead
    /// ranks were standby and no migration was needed.
    pub replan_ms: f64,
    /// Wall time of the wire migration; 0 when no migration was
    /// needed.
    pub migrate_ms: f64,
    /// Planning-scale migration traffic (16 B per Table-2 parameter);
    /// deterministic, so the perf gate can pin it exactly.
    pub migration_bytes: f64,
    /// Executed-scale state elements re-sourced over the wire — ranges
    /// owned by the corpse come from its mirror. Deterministic.
    pub moved_state_elems: usize,
}

/// What one rejoin handshake did (ft sessions with a rejoin window;
/// one entry per partitioned-then-returned rank).
#[derive(Debug, Clone)]
pub struct RejoinReport {
    /// Trace hour of the enclosing churn event.
    pub hour: usize,
    /// Global steps executed when the rank rejoined.
    pub step: usize,
    /// The rank that went silent and came back.
    pub rank: usize,
    /// REJOIN probes before the rank answered.
    pub attempts: u64,
    /// True when the reported shard fingerprint matched the driver's
    /// ledger: the rank resumed from its resident shards and ZERO
    /// bytes moved. False: its state was untrusted and re-streamed
    /// from the mirror like a fresh joiner's.
    pub hit: bool,
    /// Wall time of the re-stream migration; 0 for fingerprint hits.
    pub migrate_ms: f64,
    /// Executed-scale state elements re-streamed; 0 for hits.
    pub moved_state_elems: usize,
}

/// Re-plan + migrate bookkeeping shared by churn events and crash
/// recovery.
struct MigrationStats {
    from_cache: bool,
    solve_seconds: f64,
    migration_bytes: f64,
    moved: usize,
    replan_ms: f64,
    migrate_ms: f64,
}

/// The training engine behind a session: one address space, or one
/// SPMD rank per GPU over a transport fabric (boxed: both engines are
/// field-heavy).
enum Engine {
    InProcess(Box<Trainer>),
    Dist(Box<DistDriver>),
}

/// A running elastic trainer; see the module docs.
pub struct Session {
    base: Cluster,
    cfg: SessionConfig,
    planner: Arc<dyn Planner>,
    cache: PlanCache,
    /// Per-membership-size workloads (profile + fingerprint), memoized
    /// so recurring sizes reuse the exact same `PlanContext`.
    workloads: BTreeMap<usize, Workload>,
    engine: Engine,
    current_size: usize,
    current_asg: Assignment,
    /// Largest membership the session may still use: `min(dead) ` over
    /// every rank declared dead (dead ranks are never re-admitted, and
    /// memberships must stay canonical prefixes).
    max_live: usize,
    /// The generated fault schedule, when chaos injection is on.
    fault_plan: Option<FaultPlan>,
    /// Recovery migrations executed so far (deaths or re-streams) —
    /// the counter the coordinator-crash chaos point keys on.
    recovery_migrations: u64,
    /// One entry per completed churn event.
    pub reports: Vec<EventReport>,
    /// One entry per recovery migration triggered by dead ranks.
    pub recoveries: Vec<RecoveryReport>,
    /// One entry per completed rejoin handshake (hits and re-streams).
    pub rejoins: Vec<RejoinReport>,
}

/// The first `k` GPUs of `base` in canonical (node, slot) order,
/// reconstructed as a cluster (empty nodes dropped). Deterministic, so
/// a recurring size yields a fingerprint-identical cluster — the
/// property the plan cache keys on.
pub fn prefix_cluster(base: &Cluster, k: usize) -> Cluster {
    assert!(k >= 1 && k <= base.num_gpus());
    let mut nodes = Vec::new();
    let mut left = k;
    for n in &base.nodes {
        if left == 0 {
            break;
        }
        let take = left.min(n.gpus.len());
        if take > 0 {
            nodes.push(Node {
                name: n.name.clone(),
                gpus: n.gpus[..take].to_vec(),
                intra_bw_gbps: n.intra_bw_gbps,
            });
        }
        left -= take;
    }
    Cluster {
        name: format!("{}[..{k}]", base.name),
        nodes,
        inter_bw_gbps: base.inter_bw_gbps,
    }
}

fn ensure_workload<'a>(
    workloads: &'a mut BTreeMap<usize, Workload>,
    base: &Cluster,
    model: &str,
    seed: u64,
    k: usize,
) -> Result<&'a Workload> {
    if !workloads.contains_key(&k) {
        let w = Workload::prepare(prefix_cluster(base, k), model, seed)
            .map_err(|e| anyhow!(e.to_string()))?;
        workloads.insert(k, w);
    }
    Ok(&workloads[&k])
}

impl Session {
    /// Start a session on the full `base` cluster: profile, plan (the
    /// first cache entry), and stand up the training engine.
    pub fn new(
        base: Cluster,
        planner: Arc<dyn Planner>,
        cfg: SessionConfig,
    ) -> Result<Session> {
        let n = base.num_gpus();
        if n == 0 {
            return Err(anyhow!("empty base cluster"));
        }
        // A plan cache is purely an optimization: an unreadable or
        // malformed file degrades to a cold start, never a refusal.
        let cache = match &cfg.plan_cache_path {
            Some(p) if p.exists() => match PlanCache::load(p) {
                Ok(c) => c,
                Err(e) => {
                    crate::warn!(
                        "ignoring plan cache {}: {e}",
                        p.display()
                    );
                    PlanCache::new()
                }
            },
            _ => PlanCache::new(),
        };
        let mut workloads = BTreeMap::new();
        let (asg, workers, timer) = {
            let w = ensure_workload(
                &mut workloads, &base, &cfg.model, cfg.seed, n,
            )?;
            let outcome = cache
                .get_or_plan(&*planner, &w.ctx(cfg.batch))
                .map_err(|e| anyhow!(e.to_string()))?;
            let asg = outcome.assignment.ok_or_else(|| {
                anyhow!(
                    "planner '{}' yields no per-GPU assignment; a live \
                     session needs one",
                    planner.name()
                )
            })?;
            let names: Vec<String> = w
                .cluster
                .gpus()
                .iter()
                .map(|g| g.spec.name.clone())
                .collect();
            let workers = Trainer::workers_from_assignment(&asg, &names);
            let timer =
                StepTimeModel::from_oracle(&w.oracle, w.model.layers);
            (asg, workers, timer)
        };
        let mut fault_plan = None;
        let engine = match cfg.fabric {
            None => {
                if cfg.ft || cfg.chaos.is_some() {
                    return Err(anyhow!(
                        "fault tolerance / chaos need a distributed \
                         fabric (--transport local|tcp)"
                    ));
                }
                let exec = NativeExecutor::new(cfg.surrogate.clone())
                    .with_timer(timer);
                let tcfg = TrainConfig {
                    steps: cfg.steps_per_event,
                    seed: cfg.seed,
                    adam: cfg.adam,
                    corpus_branch: 4,
                    log_every: 0,
                    shard_params: cfg.shard_params,
                    fsdp_units: cfg.fsdp_units,
                };
                Engine::InProcess(Box::new(Trainer::from_executor(
                    Box::new(exec),
                    workers,
                    tcfg,
                )?))
            }
            Some(spec) => {
                let dcfg = DistConfig {
                    seed: cfg.seed,
                    adam: cfg.adam,
                    corpus_branch: 4,
                    surrogate: cfg.surrogate.clone(),
                    shard_params: cfg.shard_params,
                    fsdp_units: cfg.fsdp_units,
                    ft: cfg.ft || cfg.chaos.is_some(),
                    mirror_leader: cfg.mirror_leader,
                    rejoin_window_ms: cfg.rejoin_window_ms,
                    ping_timeout_ms: cfg.ping_timeout_ms,
                    hosts: cfg.hosts.clone(),
                    trace_out: cfg.trace_out.clone(),
                };
                let chaos = match &cfg.chaos {
                    Some(chaos_spec) => {
                        let (cseed, ccfg) = ChaosConfig::parse(chaos_spec)?;
                        let plan = FaultPlan::generate(cseed, n, &ccfg);
                        fault_plan = Some(plan.clone());
                        Some(ChaosOpts {
                            plan,
                            cli_spec: Some(chaos_spec.clone()),
                        })
                    }
                    None => None,
                };
                Engine::Dist(Box::new(
                    DistDriver::launch_with_chaos(
                        spec, n, dcfg, workers, chaos,
                    )?
                    .with_timer(timer),
                ))
            }
        };
        Ok(Session {
            base,
            cfg,
            planner,
            cache,
            workloads,
            engine,
            current_size: n,
            current_asg: asg,
            max_live: n,
            fault_plan,
            recovery_migrations: 0,
            reports: Vec::new(),
            recoveries: Vec::new(),
            rejoins: Vec::new(),
        })
    }

    fn min_gpus(&self) -> usize {
        let n = self.base.num_gpus();
        if self.cfg.min_gpus >= 1 {
            self.cfg.min_gpus.min(n)
        } else {
            n.saturating_sub(2).max(1)
        }
    }

    /// Membership sizes for the next `events` hours of the AWS
    /// availability trace.
    pub fn churn_sizes(&self, events: usize) -> Vec<usize> {
        let profiles = aws_trace::default_profiles();
        let trace =
            aws_trace::generate(self.cfg.seed, events, &profiles);
        let (lo, hi) = (self.min_gpus(), self.base.num_gpus());
        trace
            .iter()
            .map(|h| aws_trace::membership_size(h, lo, hi))
            .collect()
    }

    /// Re-plan for `size` GPUs and migrate the engine onto the new
    /// layout — the shared backbone of churn events AND crash
    /// recovery. Updates `current_asg`/`current_size`.
    fn replan_and_migrate(&mut self, size: usize)
        -> Result<MigrationStats> {
        self.replan_and_migrate_with(size, &[])
    }

    /// [`Session::replan_and_migrate`] with a RESTREAM list: live
    /// ranks whose state is untrusted after a fingerprint-miss rejoin.
    /// They drop out of the survivor map (their full new range streams
    /// over the wire, sourced from mirror holders) but stay in the
    /// membership — re-admitted exactly like fresh arrivals.
    fn replan_and_migrate_with(
        &mut self,
        size: usize,
        restream: &[usize],
    ) -> Result<MigrationStats> {
        // Prefix memberships: new rank i is the same physical GPU as
        // old rank i while it existed; ranks past the old size are
        // fresh arrivals (checkpoint-restore targets), and restreamed
        // ranks are treated as arrivals wherever they land.
        let survivors: Vec<Option<usize>> = (0..size)
            .map(|i| {
                if i < self.current_size && !restream.contains(&i) {
                    Some(i)
                } else {
                    None
                }
            })
            .collect();
        ensure_workload(
            &mut self.workloads,
            &self.base,
            &self.cfg.model,
            self.cfg.seed,
            size,
        )?;
        let sp =
            crate::telemetry::span(crate::telemetry::CAT_REPLAN, "replan");
        let t_plan = Instant::now();
        let (re, names) = {
            let old_w = &self.workloads[&self.current_size];
            let new_w = &self.workloads[&size];
            let re = elastic::replan(
                &self.current_asg,
                &old_w.profile,
                &new_w.ctx(self.cfg.batch),
                &survivors,
                &*self.planner,
                Some(&self.cache),
            )
            .map_err(|e| anyhow!(e.to_string()))?;
            let names: Vec<String> = new_w
                .cluster
                .gpus()
                .iter()
                .map(|g| g.spec.name.clone())
                .collect();
            (re, names)
        };
        let replan_ms = t_plan.elapsed().as_secs_f64() * 1e3;
        drop(sp);

        // Executed-scale migration: same r_i division, applied to the
        // engine's actual flat state. A recurring membership that
        // re-plans to the EXACT running assignment (the cache-hit
        // steady state) is a true no-op: skip the checkpoint/copy/adopt
        // churn entirely — unless a rank needs its state re-streamed,
        // which is wire traffic even at an unchanged layout.
        let unchanged = restream.is_empty()
            && size == self.current_size
            && re.assignment == self.current_asg;
        let sp =
            crate::telemetry::span(crate::telemetry::CAT_MIGRATE, "migrate");
        let t_mig = Instant::now();
        let moved = if unchanged {
            0
        } else {
            let old_layout = self.layout().clone();
            let new_ratios: Vec<f64> = re
                .assignment
                .per_gpu
                .iter()
                .map(|g| g.state_ratio)
                .collect();
            let new_layout =
                ShardLayout::by_ratios(old_layout.len(), &new_ratios);
            let (transfers, _resident, moved) = elastic::plan_migration(
                &old_layout, &new_layout, &survivors,
            );
            let workers =
                Trainer::workers_from_assignment(&re.assignment, &names);
            match &mut self.engine {
                Engine::InProcess(trainer) => {
                    let ck = trainer.checkpoint();
                    let old_m: Vec<&[f32]> = trainer
                        .shards()
                        .iter()
                        .map(|s| s.m.as_slice())
                        .collect();
                    let new_m = elastic::apply_migration(
                        &old_layout, &old_m, &new_layout, &survivors,
                        &transfers, &ck.adam_m,
                    );
                    let old_v: Vec<&[f32]> = trainer
                        .shards()
                        .iter()
                        .map(|s| s.v.as_slice())
                        .collect();
                    let new_v = elastic::apply_migration(
                        &old_layout, &old_v, &new_layout, &survivors,
                        &transfers, &ck.adam_v,
                    );
                    // Fully-sharded trainers migrate the WEIGHTS with
                    // the same transfer list (the checkpoint's
                    // assembled params stand in for departed owners,
                    // exactly like the moment restores).
                    let new_params = trainer.param_shards().map(|old_p| {
                        let flat_ref = crate::trainer::flatten(
                            &ck.params,
                            old_layout.len(),
                        );
                        let views: Vec<&[f32]> =
                            old_p.iter().map(|s| s.as_slice()).collect();
                        elastic::apply_migration(
                            &old_layout, &views, &new_layout, &survivors,
                            &transfers, &flat_ref,
                        )
                    });
                    let shards: Vec<AdamShard> = new_m
                        .into_iter()
                        .zip(new_v)
                        .map(|(m, v)| AdamShard {
                            m,
                            v,
                            step: ck.step,
                            cfg: self.cfg.adam,
                        })
                        .collect();
                    trainer.adopt(workers, shards, new_params)?;
                }
                Engine::Dist(driver) => {
                    // The SAME transfer list, executed as rank-to-rank
                    // wire traffic (peer copies; departed owners are
                    // standby processes — or, once declared dead or
                    // restreamed, their mirror holders — re-streaming
                    // their ranges, numerically the checkpoint
                    // restore).
                    driver.migrate_with(
                        workers, &survivors, &transfers, restream,
                    )?;
                }
            }
            moved
        };
        let migrate_ms = t_mig.elapsed().as_secs_f64() * 1e3;
        drop(sp);
        let stats = MigrationStats {
            from_cache: re.from_cache,
            solve_seconds: re.solve_seconds,
            migration_bytes: re.migration_bytes(),
            moved,
            replan_ms,
            migrate_ms,
        };
        self.current_asg = re.assignment;
        self.current_size = size;
        Ok(stats)
    }

    /// Poll the distributed failure detector and absorb the verdicts:
    /// fingerprint-hit rejoins resume in place (recorded, nothing
    /// moves); fingerprint-miss rejoins are re-streamed from the
    /// mirror at the current membership; newly dead ranks clamp
    /// `max_live` and — when inside the current membership —
    /// synthesize the SAME elastic departure a graceful shrink would
    /// take (re-plan + wire migrate with the mirror standing in for
    /// the corpse). Deaths and re-streams found in one sweep fold into
    /// ONE migration. No-op on in-process engines and non-ft drivers.
    fn recover_failures(&mut self, hour: usize) -> Result<()> {
        let sp =
            crate::telemetry::span(crate::telemetry::CAT_DETECT, "detect");
        let t_detect = Instant::now();
        let poll = match &mut self.engine {
            Engine::Dist(d) => d.poll_failures(),
            Engine::InProcess(_) => PollReport::default(),
        };
        drop(sp);
        if poll.is_empty() {
            return Ok(());
        }
        let detect_ms = t_detect.elapsed().as_secs_f64() * 1e3;
        let _recover_sp =
            crate::telemetry::span(crate::telemetry::CAT_RECOVER, "recover");
        for ev in poll.rejoined.iter().filter(|e| e.hit) {
            crate::info!(
                "rank {} rejoined in place after {} probe(s) at step {} \
                 (fingerprint hit: resident shards trusted, zero bytes \
                 moved)",
                ev.rank,
                ev.attempts,
                self.steps_run()
            );
            self.rejoins.push(RejoinReport {
                hour,
                step: self.steps_run(),
                rank: ev.rank,
                attempts: ev.attempts,
                hit: true,
                migrate_ms: 0.0,
                moved_state_elems: 0,
            });
        }
        let newly = poll.dead.clone();
        let restream = poll.restream();
        if newly.is_empty() && restream.is_empty() {
            return Ok(());
        }
        for &d in &newly {
            if d == 0 {
                return Err(anyhow!("coordinator rank cannot die"));
            }
            self.max_live = self.max_live.min(d);
        }
        if !newly.is_empty() {
            crate::warn!(
                "rank(s) {newly:?} declared dead at step {}; max \
                 membership now {}",
                self.steps_run(),
                self.max_live
            );
        }
        for &r in &restream {
            crate::warn!(
                "rank {r} rejoined with a stale fingerprint at step {}; \
                 re-streaming its state from the mirror",
                self.steps_run()
            );
        }
        let target = self.current_size.min(self.max_live);
        let need_migration =
            self.current_size > self.max_live || !restream.is_empty();
        let (replan_ms, migrate_ms, migration_bytes, moved) =
            if need_migration {
                self.recovery_migrations += 1;
                let crash_here = self
                    .fault_plan
                    .as_ref()
                    .and_then(|p| p.driver.coord_crash_recovery)
                    == Some(self.recovery_migrations);
                if crash_here {
                    // Chaos: the coordinator "dies" after the re-plan
                    // lands in the cache but before the migration
                    // executes, then restarts and replays the whole
                    // recovery. The replay must be idempotent: the
                    // cache serves the same plan and the migration
                    // runs exactly once.
                    self.plan_only(target)?;
                    crate::warn!(
                        "chaos: coordinator crash between re-plan and \
                         migrate (recovery {}); replaying recovery",
                        self.recovery_migrations
                    );
                }
                let st =
                    self.replan_and_migrate_with(target, &restream)?;
                (st.replan_ms, st.migrate_ms, st.migration_bytes, st.moved)
            } else {
                // Dead ranks were standby: nothing to migrate, the clamp
                // alone keeps them out of future memberships.
                (0.0, 0.0, 0.0, 0)
            };
        if !newly.is_empty() {
            // Dead ranks are never re-admitted, so plans for
            // memberships larger than `max_live` can never be served
            // again: age their fingerprints out of the cache (counted
            // apart from LRU).
            let live: Vec<u64> = self
                .workloads
                .iter()
                .filter(|(size, _)| **size <= self.max_live)
                .map(|(_, w)| w.fingerprint)
                .collect();
            let aged = self.cache.retain_fingerprints(&live);
            if aged > 0 {
                crate::info!(
                    "aged {aged} cached plan(s) for unreachable \
                     memberships (> {} ranks) out of the plan cache",
                    self.max_live
                );
            }
            self.recoveries.push(RecoveryReport {
                hour,
                step: self.steps_run(),
                ranks: newly,
                gpus: self.current_size,
                detect_ms,
                replan_ms,
                migrate_ms,
                migration_bytes,
                moved_state_elems: moved,
            });
        }
        for ev in poll.rejoined.iter().filter(|e| !e.hit) {
            self.rejoins.push(RejoinReport {
                hour,
                step: self.steps_run(),
                rank: ev.rank,
                attempts: ev.attempts,
                hit: false,
                migrate_ms,
                moved_state_elems: moved,
            });
        }
        Ok(())
    }

    /// The re-plan half of a recovery and nothing else — the state the
    /// coordinator-crash chaos point leaves behind. Warms the workload
    /// memo and the plan cache exactly like the real recovery's
    /// re-plan; touches neither the engine nor
    /// `current_asg`/`current_size`.
    fn plan_only(&mut self, size: usize) -> Result<()> {
        let survivors: Vec<Option<usize>> = (0..size)
            .map(|i| if i < self.current_size { Some(i) } else { None })
            .collect();
        ensure_workload(
            &mut self.workloads,
            &self.base,
            &self.cfg.model,
            self.cfg.seed,
            size,
        )?;
        let old_w = &self.workloads[&self.current_size];
        let new_w = &self.workloads[&size];
        elastic::replan(
            &self.current_asg,
            &old_w.profile,
            &new_w.ctx(self.cfg.batch),
            &survivors,
            &*self.planner,
            Some(&self.cache),
        )
        .map_err(|e| anyhow!(e.to_string()))?;
        Ok(())
    }

    /// One full churn event: re-plan for `size` GPUs, migrate the live
    /// training state onto the new layout, resume for
    /// `steps_per_event` steps. In ft mode the failure detector is
    /// polled before the migration and before every step, so a crash
    /// surfaces as a synthesized departure at the next step boundary.
    pub fn step_event(&mut self, hour: usize, size: usize)
        -> Result<EventReport> {
        self.recover_failures(hour)?;
        let size = size.clamp(1, self.max_live);
        let st = self.replan_and_migrate(size)?;

        // Resume training on the migrated state.
        let mut loss_acc = 0f64;
        let mut secs_model = 0f64;
        let mut secs_measured = 0f64;
        let mut steps = 0usize;
        for _ in 0..self.cfg.steps_per_event {
            self.recover_failures(hour)?;
            let stats = self.step_once(self.steps_run())?;
            steps += 1;
            loss_acc += stats.mean_loss;
            secs_model += stats.wall_seconds;
            secs_measured += stats.measured_seconds;
        }
        let report = EventReport {
            event: self.reports.len(),
            hour,
            gpus: self.current_size,
            from_cache: st.from_cache,
            solve_seconds: st.solve_seconds,
            migration_bytes: st.migration_bytes,
            moved_state_elems: st.moved,
            steps,
            mean_loss: if steps > 0 { loss_acc / steps as f64 } else { 0.0 },
            steps_per_sec: if secs_model > 0.0 {
                steps as f64 / secs_model
            } else {
                0.0
            },
            measured_steps_per_sec: if secs_measured > 0.0 {
                steps as f64 / secs_measured
            } else {
                0.0
            },
        };
        self.reports.push(report.clone());
        Ok(report)
    }

    fn step_once(&mut self, step_idx: usize) -> Result<StepStats> {
        match &mut self.engine {
            Engine::InProcess(t) => t.step(step_idx),
            Engine::Dist(d) => d.step(step_idx),
        }
    }

    /// Drive `events` churn events straight off the availability trace.
    pub fn run(&mut self, events: usize) -> Result<Vec<EventReport>> {
        let sizes = self.churn_sizes(events);
        for (hour, size) in sizes.into_iter().enumerate() {
            self.step_event(hour, size)?;
        }
        Ok(self.reports.clone())
    }

    /// The in-process trainer. Only meaningful for `fabric: None`
    /// sessions; distributed sessions have no leader-resident trainer
    /// (use [`Session::params`] / [`Session::steps_run`] /
    /// [`Session::backend_label`]).
    pub fn trainer(&self) -> &Trainer {
        match &self.engine {
            Engine::InProcess(t) => t.as_ref(),
            Engine::Dist(_) => panic!(
                "trainer() on a distributed session; use params() / \
                 steps_run() / backend_label()"
            ),
        }
    }

    /// The canonical full parameters, assembled on demand — an explicit
    /// export in every mode (leader copy, in-process shard
    /// concatenation, or the distributed COLLECT broadcast), bitwise
    /// identical across all of them.
    pub fn params(&mut self) -> Result<Vec<Vec<f32>>> {
        match &mut self.engine {
            Engine::InProcess(t) => Ok(t.gather_params()),
            Engine::Dist(d) => d.gather_params(),
        }
    }

    /// Total training steps executed so far.
    pub fn steps_run(&self) -> usize {
        match &self.engine {
            Engine::InProcess(t) => t.history.len(),
            Engine::Dist(d) => d.history.len(),
        }
    }

    /// The engine's current shard layout over the flat state.
    pub fn layout(&self) -> &ShardLayout {
        match &self.engine {
            Engine::InProcess(t) => t.layout(),
            Engine::Dist(d) => d.layout(),
        }
    }

    /// Human label of the execution substrate, e.g. "native+inproc",
    /// "native+local", "native+tcp".
    pub fn backend_label(&self) -> String {
        match &self.engine {
            Engine::InProcess(t) => {
                format!("{}+{}", t.executor_name(), t.comm_name())
            }
            Engine::Dist(d) => format!("native+{}", d.backend_label()),
        }
    }

    /// Persist the plan cache to `cfg.plan_cache_path` (no-op when the
    /// session was configured without one).
    pub fn save_plan_cache(&self) -> Result<()> {
        if let Some(p) = &self.cfg.plan_cache_path {
            self.cache
                .save(p)
                .map_err(|e| anyhow!("saving plan cache: {e}"))?;
        }
        Ok(())
    }

    /// The session's shared plan cache (hit/miss counters included).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Current membership size (ranks actively training).
    pub fn current_size(&self) -> usize {
        self.current_size
    }

    /// Largest membership still admissible (shrinks as ranks die).
    pub fn max_live(&self) -> usize {
        self.max_live
    }

    /// The generated chaos schedule, when fault injection is on.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault_plan.as_ref()
    }

    /// Per-rank measured timing folded by the distributed driver —
    /// the measured side of the skew report. `None` for in-process
    /// engines (one address space has no cross-rank skew to report).
    pub fn rank_timings(&self) -> Option<Vec<crate::transport::RankTiming>> {
        match &self.engine {
            Engine::Dist(d) => Some(d.rank_timings()),
            Engine::InProcess(_) => None,
        }
    }

    /// Modeled per-rank step seconds for the CURRENT membership — the
    /// planned side of the skew report. `None` for in-process engines
    /// or drivers without a [`StepTimeModel`].
    pub fn planned_rank_seconds(&self) -> Option<Vec<f64>> {
        match &self.engine {
            Engine::Dist(d) => d.planned_rank_seconds(),
            Engine::InProcess(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::CephaloPlanner;
    use crate::testkit::tiny_cluster;

    #[test]
    fn prefix_cluster_takes_canonical_order() {
        let base = Cluster::cluster_a();
        let p3 = prefix_cluster(&base, 3);
        assert_eq!(p3.num_gpus(), 3);
        assert_eq!(p3.nodes.len(), 1);
        let names: Vec<String> =
            p3.gpus().iter().map(|g| g.spec.name.clone()).collect();
        assert_eq!(names, vec!["L4", "L4", "A6000"]);
        // Crossing the node boundary keeps both nodes.
        let p5 = prefix_cluster(&base, 5);
        assert_eq!(p5.nodes.len(), 2);
        assert_eq!(p5.nodes[1].gpus.len(), 1);
        // Deterministic (fingerprint-stable for the plan cache).
        assert_eq!(format!("{:?}", prefix_cluster(&base, 3).nodes),
                   format!("{:?}", p3.nodes));
    }

    #[test]
    fn session_runs_trace_driven_events() {
        let cfg = SessionConfig {
            batch: 8,
            steps_per_event: 2,
            seed: 7,
            min_gpus: 1,
            ..Default::default()
        };
        let mut s = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            cfg,
        )
        .unwrap();
        let sizes = s.churn_sizes(4);
        assert_eq!(sizes.len(), 4);
        assert!(sizes.iter().all(|&k| (1..=2).contains(&k)));
        let reports = s.run(4).unwrap();
        assert_eq!(reports.len(), 4);
        assert_eq!(s.trainer().history.len(), 8);
        assert_eq!(s.steps_run(), 8);
        assert_eq!(s.backend_label(), "native+inproc");
        // 4 events over at most 2 memberships: the cache must hit.
        assert!(
            s.cache().hits() >= 1,
            "recurring memberships should be cache hits"
        );
        for r in &reports {
            assert!(r.mean_loss.is_finite() && r.mean_loss > 0.0);
            assert!(r.steps_per_sec > 0.0);
            assert!(r.measured_steps_per_sec > 0.0);
        }
    }

    #[test]
    fn shrink_then_regrow_migrates_state_both_ways() {
        let cfg = SessionConfig {
            batch: 8,
            steps_per_event: 1,
            seed: 3,
            min_gpus: 1,
            ..Default::default()
        };
        let mut s = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            cfg,
        )
        .unwrap();
        assert_eq!(s.current_size(), 2);
        let down = s.step_event(0, 1).unwrap();
        assert_eq!(down.gpus, 1);
        assert_eq!(s.trainer().layout().num_ranks(), 1);
        // The survivor inherits everything it did not already hold.
        assert!(down.moved_state_elems > 0);
        let up = s.step_event(1, 2).unwrap();
        assert_eq!(up.gpus, 2);
        assert_eq!(s.trainer().layout().num_ranks(), 2);
        assert!(up.moved_state_elems > 0);
        // Re-entering a seen membership is a cache hit.
        assert!(up.from_cache);
    }

    #[test]
    fn chaos_session_recovers_and_stays_on_the_reference_trajectory() {
        // Tentpole closure at the session level: a local-fabric ft
        // session with an injected rank-1 crash turns the death into a
        // synthesized shrink (mirror-backed wire migration), clamps
        // future memberships below the corpse, and stays bitwise on
        // the in-process session's trajectory (invariants 10 + 12).
        let cfg = |fabric, chaos: Option<&str>| SessionConfig {
            batch: 8,
            steps_per_event: 2,
            seed: 7,
            min_gpus: 1,
            fabric,
            chaos: chaos.map(|s| s.into()),
            ..Default::default()
        };
        let mut chaotic = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            cfg(
                Some(FabricSpec::Local),
                Some("seed=5,crash=1,first=1,delay=0,dup=0"),
            ),
        )
        .unwrap();
        let mut reference = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            cfg(None, None),
        )
        .unwrap();
        for hour in 0..3 {
            chaotic.step_event(hour, 2).unwrap();
            reference.step_event(hour, 2).unwrap();
        }
        // Rank 1 crashed after completing global step 1; the next
        // boundary poll caught it.
        assert_eq!(chaotic.recoveries.len(), 1);
        assert_eq!(chaotic.recoveries[0].ranks, vec![1]);
        assert_eq!(chaotic.recoveries[0].step, 2);
        assert_eq!(chaotic.max_live(), 1);
        assert_eq!(chaotic.current_size(), 1, "corpse never re-admitted");
        assert!(chaotic.fault_plan().is_some());
        assert_eq!(chaotic.steps_run(), reference.steps_run());
        assert_eq!(
            chaotic.params().unwrap(),
            reference.params().unwrap(),
            "crash-recovered session left the reference trajectory"
        );
    }

    #[test]
    fn dropped_ping_heals_by_rejoin_without_migration() {
        // Rejoin tentpole at the session level: coordinator-side chaos
        // drops a healthy rank's PING echo once, raising a false
        // suspicion. Inside the rejoin window the rank answers the
        // REJOIN handshake with a fingerprint matching the ledger, so
        // it resumes in place: no recovery migration, no membership
        // clamp, and the trajectory stays bitwise on the in-process
        // reference (invariants 10 + 15).
        let mut chaotic = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            SessionConfig {
                batch: 8,
                steps_per_event: 2,
                seed: 7,
                min_gpus: 1,
                fabric: Some(FabricSpec::Local),
                chaos: Some(
                    "seed=5,crash=0,delay=0,dup=0,drop_ping=1,\
                     drop_first=1"
                        .into(),
                ),
                rejoin_window_ms: 5000,
                ping_timeout_ms: 200,
                ..Default::default()
            },
        )
        .unwrap();
        let mut reference = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            SessionConfig {
                batch: 8,
                steps_per_event: 2,
                seed: 7,
                min_gpus: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for hour in 0..2 {
            chaotic.step_event(hour, 2).unwrap();
            reference.step_event(hour, 2).unwrap();
        }
        assert!(
            chaotic.recoveries.is_empty(),
            "a healed partition must not migrate"
        );
        assert_eq!(chaotic.rejoins.len(), 1);
        let rj = &chaotic.rejoins[0];
        assert_eq!(rj.rank, 1);
        assert!(rj.hit, "matching fingerprint must resume in place");
        assert_eq!(rj.moved_state_elems, 0);
        assert_eq!(chaotic.max_live(), 2, "rejoined rank stays live");
        assert_eq!(chaotic.current_size(), 2);
        assert_eq!(chaotic.steps_run(), reference.steps_run());
        assert_eq!(
            chaotic.params().unwrap(),
            reference.params().unwrap(),
            "rejoin perturbed the trajectory"
        );
    }

    #[test]
    fn chaos_without_a_fabric_is_rejected() {
        let cfg = SessionConfig {
            chaos: Some("seed=1".into()),
            ..Default::default()
        };
        assert!(Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            cfg
        )
        .is_err());
    }

    #[test]
    fn event_reports_quote_modeled_time_not_measured_wall() {
        // Satellite regression: the per-event steps/sec must come from
        // the executor's `step_seconds` hook (modeled durations when a
        // StepTimeModel is attached), with measured wall kept in its
        // own field. Modeled BERT-Large steps on simulated T4/V100
        // hardware take ~seconds; real surrogate steps take
        // microseconds — conflating them is off by orders of
        // magnitude.
        let cfg = SessionConfig {
            batch: 8,
            steps_per_event: 2,
            seed: 11,
            min_gpus: 1,
            ..Default::default()
        };
        let mut s = Session::new(
            tiny_cluster(),
            Arc::new(CephaloPlanner::default()),
            cfg,
        )
        .unwrap();
        let r = s.step_event(0, 2).unwrap();
        assert!(
            r.measured_steps_per_sec > r.steps_per_sec * 10.0,
            "modeled rate {} should be far below executed rate {}",
            r.steps_per_sec,
            r.measured_steps_per_sec
        );
    }
}
