//! The `cephalo` CLI: plan / optimize / simulate / elastic / profile /
//! train / trace.

use std::sync::Arc;

use crate::cli::{opt, parse, switch, usage, OptSpec};
use crate::cluster::Cluster;
use crate::coordinator::session::{prefix_cluster, Session, SessionConfig};
use crate::coordinator::{elastic, Workload};
use crate::exec::{NativeExecutor, StepTimeModel, SurrogateSpec};
use crate::optimizer::PlanError;
use crate::plan::{self, PlanCache, Planner, PlannerRegistry};
use crate::trainer::{TrainConfig, Trainer, WorkerSpec};
use crate::transport::{
    self, ChaosConfig, ChaosTransport, CrashMode, DistConfig, DistDriver,
    FabricSpec, FaultPlan, HostTopology, HybridTransport, ShmTransport,
    Transport,
};
use crate::util::tablefmt::{fmt_throughput, Table};

/// CLI entry point: dispatch `argv` (without the binary name) to a
/// subcommand and return the process exit code.
pub fn main_with_args(argv: Vec<String>) -> i32 {
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return 2;
    };
    let rest = argv[1..].to_vec();
    let code = match cmd.as_str() {
        "plan" => cmd_plan(&rest),
        "optimize" => cmd_optimize(&rest),
        "simulate" => cmd_simulate(&rest),
        "elastic" => cmd_elastic(&rest),
        "profile" => cmd_profile(&rest),
        "train" => cmd_train(&rest),
        "trace" => cmd_trace(&rest),
        "worker" => cmd_worker(&rest),
        "bench-gate" => cmd_bench_gate(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'; try `cephalo help`")),
    };
    match code {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn print_help() {
    println!(
        "cephalo — heterogeneous-cluster transformer training\n\n\
         commands:\n  \
         plan      compare planners (--system <name|all>) via a \
         parallel sweep\n  \
         optimize  solve the compute/state division for a workload\n  \
         simulate  throughput of cephalo and/or baselines on a cluster\n  \
         elastic   membership churn with cached re-planning; --live \
         runs real\n            migration + training on the native \
         backend\n  \
         profile   fit or measure performance models\n  \
         train     real numeric training (--backend native | pjrt,\n            \
         --transport inproc | local | tcp | shm | hybrid)\n  \
         trace     generate the AWS availability trace (Fig. 1)\n  \
         worker    one distributed training rank (spawned by the\n            \
         coordinator for --transport tcp | shm | hybrid)\n  \
         bench-gate  compare two BENCH_*.json runs; non-zero exit on\n            \
         perf regression beyond the noise band\n  \
         help      this message\n\n\
         run `cephalo <command> --help` for options"
    );
}

fn common_specs() -> Vec<OptSpec> {
    vec![
        opt("cluster", "preset cluster: a | b | 16xv100 | 32xa10g, or a \
                        TOML config path", Some("a")),
        opt("model", "Table-2 model name", Some("BERT-Large")),
        opt("batch", "global batch size", Some("128")),
        opt("seed", "PRNG seed", Some("42")),
        opt("log-level", "log threshold: error | warn | info | debug | \
                          trace (defaults to the CEPHALO_LOG env var, \
                          then info)", None),
        switch("help", "show usage"),
    ]
}

/// Apply `--log-level` (hard error if invalid) or the `CEPHALO_LOG`
/// fallback before the command body runs.
fn apply_log_level(a: &crate::cli::Args) -> Result<(), String> {
    crate::logging::init_level(a.get("log-level")).map(|_| ())
}

/// `--trace-out`: switch the process-global span tracer on. The
/// coordinator records as rank 0; spawned worker ranks get their own
/// per-rank trace path forwarded by the driver.
fn start_trace(a: &crate::cli::Args) -> Option<String> {
    let path = a.get("trace-out").map(String::from)?;
    crate::telemetry::enable();
    crate::telemetry::set_rank(0);
    Some(path)
}

/// Flush the tracer and write the Chrome trace-event JSON (Perfetto
/// loads it directly), attaching fabric counters plus `extra` context
/// to the trace metadata.
fn finish_trace(
    path: &str,
    extra: &[(&str, crate::util::json::Json)],
) -> Result<(), String> {
    crate::telemetry::drain();
    crate::telemetry::write_chrome_trace(std::path::Path::new(path), extra)
        .map_err(|e| e.to_string())?;
    crate::info!("trace written to {path}");
    Ok(())
}

/// Session-report tail shared by `train --transport ...` and
/// `elastic --live`: the planned-vs-measured skew table plus the
/// non-zero fabric counters.
fn print_skew_report(
    planned: Option<&[f64]>,
    timings: &[transport::RankTiming],
) {
    if timings.iter().any(|t| t.steps > 0) {
        println!(
            "{}",
            crate::coordinator::report::skew_table(
                planned.unwrap_or(&[]),
                timings,
            )
        );
    }
    let counts = crate::telemetry::counters().snapshot();
    let nonzero: Vec<String> = counts
        .iter()
        .filter(|(_, v)| **v > 0)
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    if !nonzero.is_empty() {
        println!("fabric counters: {}", nonzero.join(" "));
    }
}

fn resolve_cluster(name: &str) -> Result<Cluster, String> {
    if let Some(c) = Cluster::preset(name) {
        return Ok(c);
    }
    if std::path::Path::new(name).exists() {
        let cfg = crate::configfmt::Config::load(name)
            .map_err(|e| e.to_string())?;
        return Cluster::from_config(&cfg);
    }
    Err(format!("unknown cluster '{name}' (not a preset or config file)"))
}

fn plan_err(e: PlanError) -> String {
    e.to_string()
}

/// Fully-sharded parameters are the DEFAULT for training commands:
/// `--leader-params` opts back into the historical leader-resident
/// engine, and `--shard-params` is kept as an accepted no-op for
/// scripts written against the old default. Safe to flip because the
/// sharded trajectory is bitwise-identical either way (DESIGN.md
/// invariants 11 and 13).
fn shard_params_flag(a: &crate::cli::Args) -> Result<bool, String> {
    if a.has("leader-params") && a.has("shard-params") {
        return Err(
            "--leader-params and --shard-params are mutually exclusive"
                .into(),
        );
    }
    Ok(!a.has("leader-params"))
}

/// Parse `--hosts` (comma-separated host ids, one per rank) against
/// the fabric's world size. `None` when the flag is absent — every
/// rank on one host.
fn parse_hosts(
    a: &crate::cli::Args,
    world: usize,
) -> Result<Option<Vec<u64>>, String> {
    match a.get("hosts") {
        Some(spec) => {
            Ok(Some(HostTopology::parse(spec, world)?.hosts().to_vec()))
        }
        None => Ok(None),
    }
}

/// The `--fsdp-units` / `--leader-params` / `--shard-params` trio
/// shared by `train` and `elastic --live`.
fn sharding_specs(specs: &mut Vec<OptSpec>) {
    specs.push(opt("fsdp-units", "cut the per-step parameter gather \
                                  into this many per-layer FSDP units \
                                  (prefetched + freed unit-by-unit; \
                                  1 = whole-model gather)", Some("1")));
    specs.push(switch("shard-params", "fully-sharded parameters \
                                       (the default; accepted for \
                                       compatibility)"));
    specs.push(switch("leader-params", "opt out of fully-sharded \
                                        parameters: keep the historical \
                                        leader-resident weight copy"));
}

fn cmd_optimize(argv: &[String]) -> Result<(), String> {
    let specs = common_specs();
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo optimize", "solve a workload", &specs));
        return Ok(());
    }
    apply_log_level(&a)?;
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;
    let (asg, stats) = w.optimize(batch).map_err(plan_err)?;

    let mut t = Table::new(
        &format!(
            "Optimized configuration: {} on cluster {} @ batch {batch}",
            w.model.name, w.cluster.name
        ),
        &["gpu", "type", "batch b_i", "micro m_i", "count l_i",
          "state r_i"],
    );
    for (i, (g, slot)) in
        asg.per_gpu.iter().zip(w.cluster.gpus()).enumerate()
    {
        t.add_row(vec![
            i.to_string(),
            slot.spec.name.clone(),
            g.batch().to_string(),
            g.microbatch.to_string(),
            g.num_micro.to_string(),
            format!("{:.3}", g.state_ratio),
        ]);
    }
    println!("{}", t.render());
    println!(
        "predicted iter latency {:.4}s  throughput {:.2} samples/s  \
         (DP: {} states, {} transitions, {:.2}s solve)",
        asg.iter_latency,
        asg.throughput(),
        stats.states_visited,
        stats.transitions,
        stats.solve_seconds
    );
    Ok(())
}

/// The six table systems (ablation variants are reachable via `plan`).
const TABLE_SYSTEMS: [&str; 6] = [
    "Cephalo", "Megatron-Het", "FlashFlex", "Whale", "HAP", "FSDP",
];

/// Resolve a single `--planner <name>` against the registry.
fn lookup_planner(
    registry: &PlannerRegistry,
    name: &str,
) -> Result<Arc<dyn Planner>, String> {
    registry.get(name).ok_or_else(|| {
        format!(
            "unknown planner '{name}'; known: {}",
            registry.names().join(", ")
        )
    })
}

/// Resolve `--system <name|all>` against the registry.
fn resolve_planners(
    registry: &PlannerRegistry,
    system: &str,
    all: &[&str],
) -> Result<Vec<Arc<dyn Planner>>, String> {
    if system.eq_ignore_ascii_case("all") {
        return Ok(all
            .iter()
            .map(|n| registry.get(n).expect("default registry entry"))
            .collect());
    }
    registry.get(system).map(|p| vec![p]).ok_or_else(|| {
        format!(
            "unknown system '{system}'; known: {}",
            registry.names().join(", ")
        )
    })
}

fn cmd_simulate(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(opt("system", "cephalo | megatron | flashflex | whale | \
                              hap | fsdp | all", Some("all")));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo simulate", "simulate throughput",
                             &specs));
        return Ok(());
    }
    apply_log_level(&a)?;
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;

    let registry = PlannerRegistry::with_defaults();
    let planners = resolve_planners(
        &registry,
        a.get("system").unwrap(),
        &TABLE_SYSTEMS,
    )?;
    let mut t = Table::new(
        &format!(
            "Simulated throughput (samples/s): {} on cluster {} @ {batch}",
            w.model.name, w.cluster.name
        ),
        &["system", "throughput", "config"],
    );
    for cell in plan::sweep(&w.ctx(0), &planners, &[batch], None) {
        match cell.result {
            Ok(out) => t.add_row(vec![
                out.planner,
                fmt_throughput(out.throughput),
                out.config,
            ]),
            Err(e) => t.add_row(vec![
                cell.planner,
                if e.is_oom() { "OOM".into() } else { "-".into() },
                e.to_string(),
            ]),
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_plan(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(opt("system", "planner name (see `plan --system all`) or \
                              'all'", Some("all")));
    specs.push(opt("batches", "comma-separated batch sizes (overrides \
                               --batch)", None));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo plan",
                             "compare planning strategies", &specs));
        return Ok(());
    }
    apply_log_level(&a)?;
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let batches: Vec<usize> = match a.get("batches") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<usize>()
                    .map_err(|_| format!("bad batch '{x}'"))
            })
            .collect::<Result<_, _>>()?,
        None => vec![batch],
    };
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;

    let registry = PlannerRegistry::with_defaults();
    let all = registry.names();
    let planners =
        resolve_planners(&registry, a.get("system").unwrap(), &all)?;
    let cells = plan::sweep(&w.ctx(0), &planners, &batches, None);

    let mut t = Table::new(
        &format!(
            "Planner comparison: {} on cluster {} ({} solves, parallel)",
            w.model.name,
            w.cluster.name,
            cells.len()
        ),
        &["system", "batch", "samples/s", "iter (s)", "solve (s)",
          "configuration"],
    );
    for c in &cells {
        match &c.result {
            Ok(o) => t.add_row(vec![
                c.planner.clone(),
                c.batch.to_string(),
                fmt_throughput(o.throughput),
                format!("{:.4}", o.iter_latency),
                format!("{:.3}", o.diagnostics.solve_seconds),
                o.config.clone(),
            ]),
            Err(e) => t.add_row(vec![
                c.planner.clone(),
                c.batch.to_string(),
                if e.is_oom() { "OOM".into() } else { "-".into() },
                "-".into(),
                "-".into(),
                e.to_string(),
            ]),
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_elastic(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(opt("events", "membership-change events to simulate",
                   Some("6")));
    specs.push(opt("planner", "registry planner used for re-planning",
                   Some("cephalo")));
    specs.push(switch("live", "run a LIVE session: churn from the AWS \
                               trace, real migration + training on the \
                               native backend"));
    specs.push(opt("steps", "training steps per event (--live)",
                   Some("5")));
    specs.push(opt("min-gpus", "smallest live membership (0 = auto)",
                   Some("0")));
    specs.push(opt("transport", "live-session substrate: inproc | \
                                 local (channel ranks) | tcp (worker \
                                 processes) | shm (worker processes \
                                 over /dev/shm rings) | hybrid \
                                 (tcp mesh + shm same-host lanes)",
                   Some("inproc")));
    specs.push(opt("hosts", "rank → host-id map for --transport \
                             hybrid, comma-separated (e.g. 0,0,1,1); \
                             same-host lanes ride shm and rings walk \
                             a locality-sorted order", None));
    sharding_specs(&mut specs);
    specs.push(opt("plan-cache", "JSON file to warm the plan cache \
                                  from and persist it to (--live)",
                   None));
    specs.push(switch("ft", "fault tolerance: heartbeat liveness \
                             polling + optimizer-state mirroring, \
                             sharded across survivor ranks (--live, \
                             distributed fabrics)"));
    specs.push(switch("mirror-leader", "legacy ft mirror placement: \
                             one flat copy on rank 0 instead of the \
                             sharded survivor spread (recovery is \
                             bitwise identical either way)"));
    specs.push(opt("rejoin-window", "milliseconds a suspected rank is \
                             courted with REJOIN handshakes before \
                             being declared dead; 0 = suspicion is \
                             death (--live, implies nothing else)",
                   Some("0")));
    specs.push(opt("chaos", "deterministic fault injection (--live): \
                             seed=N[,crash=K][,first=S][,stride=D]\
                             [,delay=P][,delay_ms=M][,dup=P]\
                             [,drop_ping=R][,drop_first=N]\
                             [,drop_count=K][,poll_delay_ms=M]\
                             [,taint=R][,coord_crash=N]; \
                             implies --ft", None));
    specs.push(opt("chaos-log", "write the fault plan and recovery \
                                 timings as JSON here (--live)", None));
    specs.push(opt("trace-out", "write a Chrome/Perfetto span trace of \
                                 the session here; spawned worker ranks \
                                 write <stem>.rankN.<ext> (--live)",
                   None));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage(
            "cephalo elastic",
            "membership churn with cached re-planning; --live executes \
             the migrations against a running native trainer",
            &specs,
        ));
        return Ok(());
    }
    apply_log_level(&a)?;
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    if cluster.num_gpus() < 2 {
        return Err("elastic demo needs at least 2 GPUs".into());
    }
    if !a.has("live")
        && (a.has("ft")
            || a.has("mirror-leader")
            || a.get_u64("rejoin-window").unwrap_or(0) > 0
            || a.get("chaos").is_some()
            || a.get("chaos-log").is_some()
            || a.get("trace-out").is_some())
    {
        return Err("--ft / --mirror-leader / --rejoin-window / --chaos \
                    / --chaos-log / --trace-out apply to --live \
                    sessions only"
            .into());
    }
    if a.has("live") {
        return cmd_elastic_live(&a, cluster);
    }
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let events = a.get_usize("events").ok_or("bad --events")?;
    let model = a.get("model").unwrap();
    let seed = a.get_u64("seed").unwrap_or(42);

    let registry = PlannerRegistry::with_defaults();
    let planner = lookup_planner(&registry, a.get("planner").unwrap())?;
    let cache = PlanCache::new();

    // Two recurring membership states: the full cluster, and the
    // cluster with its last GPU preempted (Fig.-1 churn at demo scale).
    let full = Workload::prepare(cluster.clone(), model, seed)
        .map_err(plan_err)?;
    let mut degraded_cluster = cluster.clone();
    let last = degraded_cluster.nodes.len() - 1;
    degraded_cluster.nodes[last].gpus.pop();
    if degraded_cluster.nodes[last].gpus.is_empty() {
        degraded_cluster.nodes.pop();
    }
    let degraded = Workload::prepare(degraded_cluster, model, seed)
        .map_err(plan_err)?;

    let n = full.cluster.num_gpus();
    let to_degraded: Vec<Option<usize>> = (0..n - 1).map(Some).collect();
    let mut to_full: Vec<Option<usize>> = (0..n - 1).map(Some).collect();
    to_full.push(None); // the returning GPU restores from checkpoint

    let (mut current, _) = full.optimize(batch).map_err(plan_err)?;
    let mut t = Table::new(
        &format!(
            "Elastic re-planning: {model} @ {batch}, planner \
             {}, cluster {}",
            planner.name(),
            full.cluster.name
        ),
        &["event", "membership", "gpus", "state moved (GB)", "solve (s)",
          "plan cache"],
    );
    for e in 0..events {
        let losing = e % 2 == 0;
        let (w, survivors, old_profile) = if losing {
            (&degraded, &to_degraded, &full.profile)
        } else {
            (&full, &to_full, &degraded.profile)
        };
        let re = elastic::replan(
            &current,
            old_profile,
            &w.ctx(batch),
            survivors,
            &*planner,
            Some(&cache),
        )
        .map_err(plan_err)?;
        t.add_row(vec![
            e.to_string(),
            String::from(if losing { "gpu lost" } else { "gpu restored" }),
            w.cluster.num_gpus().to_string(),
            format!("{:.2}", re.migration_bytes() / 1e9),
            format!("{:.3}", re.solve_seconds),
            String::from(if re.from_cache { "hit" } else { "miss" }),
        ]);
        current = re.assignment;
    }
    println!("{}", t.render());
    println!(
        "plan cache: {} hits / {} misses across {} events over 2 \
         recurring memberships",
        cache.hits(),
        cache.misses(),
        events
    );
    Ok(())
}

/// `elastic --live`: a real end-to-end session — AWS-trace churn,
/// registry+cache re-planning, state migration applied to resident
/// shards, training resumed on the native backend.
fn cmd_elastic_live(
    a: &crate::cli::Args,
    cluster: Cluster,
) -> Result<(), String> {
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let events = a.get_usize("events").ok_or("bad --events")?;
    let steps = a.get_usize("steps").ok_or("bad --steps")?;
    let registry = PlannerRegistry::with_defaults();
    let planner = lookup_planner(&registry, a.get("planner").unwrap())?;
    let fabric = FabricSpec::parse(a.get("transport").unwrap())
        .map_err(|e| e.to_string())?;
    let trace_out = start_trace(a);
    let cfg = SessionConfig {
        model: a.get("model").unwrap().to_string(),
        batch,
        steps_per_event: steps,
        seed: a.get_u64("seed").unwrap_or(42),
        min_gpus: a.get_usize("min-gpus").unwrap_or(0),
        fabric,
        shard_params: shard_params_flag(a)?,
        fsdp_units: a.get_usize("fsdp-units").unwrap_or(1),
        plan_cache_path: a.get("plan-cache").map(std::path::PathBuf::from),
        ft: a.has("ft"),
        mirror_leader: a.has("mirror-leader"),
        rejoin_window_ms: a.get_u64("rejoin-window").unwrap_or(0),
        chaos: a.get("chaos").map(String::from),
        hosts: parse_hosts(&a, cluster.num_gpus())?,
        trace_out: trace_out.clone(),
        ..Default::default()
    };
    let cluster_name = cluster.name.clone();
    let mut session = Session::new(cluster, planner, cfg)
        .map_err(|e| e.to_string())?;
    let reports =
        session.run(events).map_err(|e| e.to_string())?;

    let mut t = Table::new(
        &format!(
            "Live elastic session: {} @ {batch} on cluster \
             {cluster_name}, {steps} steps/event, backend {}",
            a.get("model").unwrap(),
            session.backend_label()
        ),
        &["event", "gpus", "plan", "solve (s)", "state moved (GB)",
          "loss", "steps/s (model)", "steps/s (wall)"],
    );
    for r in &reports {
        t.add_row(vec![
            r.event.to_string(),
            r.gpus.to_string(),
            String::from(if r.from_cache { "cache hit" } else { "solve" }),
            format!("{:.3}", r.solve_seconds),
            format!("{:.2}", r.migration_bytes / 1e9),
            format!("{:.4}", r.mean_loss),
            format!("{:.2}", r.steps_per_sec),
            format!("{:.2}", r.measured_steps_per_sec),
        ]);
    }
    println!("{}", t.render());
    println!(
        "plan cache: {} hits / {} misses ({} evictions); {} training \
         steps survived {} membership changes",
        session.cache().hits(),
        session.cache().misses(),
        session.cache().evictions(),
        session.steps_run(),
        reports.len()
    );
    if !session.recoveries.is_empty() {
        let mut rt = Table::new(
            "Fault recoveries (heartbeat detection, cached re-plan, \
             wire migration)",
            &["hour", "step", "dead ranks", "gpus after", "detect (ms)",
              "replan (ms)", "migrate (ms)"],
        );
        for r in &session.recoveries {
            rt.add_row(vec![
                r.hour.to_string(),
                r.step.to_string(),
                format!("{:?}", r.ranks),
                r.gpus.to_string(),
                format!("{:.2}", r.detect_ms),
                format!("{:.2}", r.replan_ms),
                format!("{:.2}", r.migrate_ms),
            ]);
        }
        println!("{}", rt.render());
    }
    if !session.rejoins.is_empty() {
        let mut jt = Table::new(
            "Rejoins (partitioned ranks re-admitted inside the rejoin \
             window)",
            &["hour", "step", "rank", "probes", "path", "migrate (ms)",
              "state moved (elems)"],
        );
        for r in &session.rejoins {
            jt.add_row(vec![
                r.hour.to_string(),
                r.step.to_string(),
                r.rank.to_string(),
                r.attempts.to_string(),
                String::from(if r.hit { "in place" } else { "re-stream" }),
                format!("{:.2}", r.migrate_ms),
                r.moved_state_elems.to_string(),
            ]);
        }
        println!("{}", jt.render());
    }
    if let Some(timings) = session.rank_timings() {
        print_skew_report(
            session.planned_rank_seconds().as_deref(),
            &timings,
        );
    }
    if let Some(path) = a.get("chaos-log") {
        write_chaos_log(path, &session)?;
        println!("chaos log written to {path}");
    }
    if let Some(path) = &trace_out {
        use crate::util::json::Json;
        finish_trace(path, &[
            ("command", Json::Str("elastic --live".into())),
            ("backend", Json::Str(session.backend_label())),
            ("events", Json::Num(reports.len() as f64)),
        ])?;
    }
    session.save_plan_cache().map_err(|e| e.to_string())?;
    if let Some(p) = a.get("plan-cache") {
        println!("plan cache persisted to {p}");
    }
    Ok(())
}

/// `--chaos-log`: the generated fault plan plus per-recovery timings,
/// serialized as one JSON object (the CI chaos-smoke artifact).
fn write_chaos_log(path: &str, session: &Session) -> Result<(), String> {
    use std::collections::BTreeMap;

    use crate::util::json::Json;

    let mut obj = BTreeMap::new();
    obj.insert(
        "fault_plan".to_string(),
        session.fault_plan().map_or(Json::Null, FaultPlan::to_json),
    );
    let recoveries: Vec<Json> = session
        .recoveries
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("hour".to_string(), Json::Num(r.hour as f64));
            o.insert("step".to_string(), Json::Num(r.step as f64));
            o.insert(
                "dead_ranks".to_string(),
                Json::Arr(
                    r.ranks.iter().map(|x| Json::Num(*x as f64)).collect(),
                ),
            );
            o.insert("gpus_after".to_string(), Json::Num(r.gpus as f64));
            o.insert("detect_ms".to_string(), Json::Num(r.detect_ms));
            o.insert("replan_ms".to_string(), Json::Num(r.replan_ms));
            o.insert("migrate_ms".to_string(), Json::Num(r.migrate_ms));
            Json::Obj(o)
        })
        .collect();
    obj.insert("recoveries".to_string(), Json::Arr(recoveries));
    let rejoins: Vec<Json> = session
        .rejoins
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("hour".to_string(), Json::Num(r.hour as f64));
            o.insert("step".to_string(), Json::Num(r.step as f64));
            o.insert("rank".to_string(), Json::Num(r.rank as f64));
            o.insert(
                "attempts".to_string(),
                Json::Num(r.attempts as f64),
            );
            o.insert("hit".to_string(), Json::Bool(r.hit));
            o.insert("migrate_ms".to_string(), Json::Num(r.migrate_ms));
            o.insert(
                "moved_state_elems".to_string(),
                Json::Num(r.moved_state_elems as f64),
            );
            Json::Obj(o)
        })
        .collect();
    obj.insert("rejoins".to_string(), Json::Arr(rejoins));
    std::fs::write(path, Json::Obj(obj).render())
        .map_err(|e| e.to_string())
}

fn cmd_profile(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(switch("real", "profile the AOT layer_fwd via PJRT"));
    specs.push(opt("artifacts", "artifacts directory",
                   Some("artifacts")));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo profile", "fit performance models",
                             &specs));
        return Ok(());
    }
    apply_log_level(&a)?;
    if a.has("real") {
        return profile_real(&a);
    }
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;
    let mut t = Table::new(
        &format!("Fitted per-GPU models: {} on cluster {}", w.model.name,
                 w.cluster.name),
        &["gpu", "type", "fwd(m=1)", "fwd(m=8)", "bwd(m=8)",
          "mem(m=8) GB", "cap GB"],
    );
    for (i, (g, slot)) in
        w.profile.per_gpu.iter().zip(w.cluster.gpus()).enumerate()
    {
        t.add_row(vec![
            i.to_string(),
            slot.spec.name.clone(),
            crate::util::human_secs(g.fwd.predict(1)),
            crate::util::human_secs(g.fwd.predict(8)),
            crate::util::human_secs(g.bwd.predict(8)),
            format!("{:.2}", g.mem.predict(8) / 1e9),
            format!("{:.0}", g.capacity / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!(
        "unit AG {:.2} ms (uneven {:.2} ms), RS {:.2} ms",
        w.profile.unit_allgather() * 1e3,
        w.profile.unit_allgather_uneven() * 1e3,
        w.profile.unit_reduce_scatter() * 1e3
    );
    Ok(())
}

/// `profile --real`: time the AOT layer_fwd through PJRT.
#[cfg(feature = "xla")]
fn profile_real(a: &crate::cli::Args) -> Result<(), String> {
    let dir = std::path::PathBuf::from(a.get("artifacts").unwrap());
    let samples =
        crate::coordinator::real_profile::profile_layer_fwd(&dir, 5)
            .map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "Real layer_fwd latency via PJRT (CPU)",
        &["microbatch", "mean", "min"],
    );
    for s in samples {
        t.add_row(vec![
            s.microbatch.to_string(),
            crate::util::human_secs(s.mean_seconds),
            crate::util::human_secs(s.min_seconds),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn profile_real(_a: &crate::cli::Args) -> Result<(), String> {
    Err("this binary was built without the `xla` feature; rebuild with \
         `--features xla` for real PJRT profiling"
        .into())
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(opt("backend", "execution backend: native | pjrt",
                   Some("native")));
    specs.push(opt("transport", "collective substrate: inproc (one \
                                 address space) | local (channel ranks) \
                                 | tcp (worker processes over loopback \
                                 sockets) | shm (worker processes over \
                                 /dev/shm ring buffers) | hybrid (tcp \
                                 mesh + shm same-host fast path)",
                   Some("inproc")));
    specs.push(opt("hosts", "rank → host-id map for --transport \
                             hybrid, comma-separated (e.g. 0,0,1,1); \
                             same-host lanes ride shm and rings walk \
                             a locality-sorted order", None));
    specs.push(opt("workers", "distributed ranks; trains on the first N \
                               GPUs of the cluster (0 = all)", Some("0")));
    sharding_specs(&mut specs);
    specs.push(opt("steps", "training steps", Some("50")));
    specs.push(opt("lr", "Adam learning rate", Some("0.001")));
    specs.push(opt("artifacts", "artifacts directory (pjrt backend)",
                   Some("artifacts")));
    specs.push(opt("log-every", "log cadence", Some("10")));
    specs.push(opt("loss-csv", "write the loss curve CSV here", None));
    specs.push(opt("trace-out", "write a Chrome/Perfetto span trace of \
                                 this run here; spawned worker ranks \
                                 write <stem>.rankN.<ext>", None));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage(
            "cephalo train",
            "train for real: plan on the simulated cluster, execute the \
             numeric FSDP pipeline on the chosen backend and transport",
            &specs,
        ));
        return Ok(());
    }
    apply_log_level(&a)?;
    let trace_out = start_trace(&a);
    let mut cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let steps = a.get_usize("steps").ok_or("bad --steps")?;
    let fabric = FabricSpec::parse(a.get("transport").unwrap())
        .map_err(|e| e.to_string())?;
    let workers_flag = a.get_usize("workers").ok_or("bad --workers")?;
    if workers_flag > 0 {
        if workers_flag > cluster.num_gpus() {
            return Err(format!(
                "--workers {workers_flag} exceeds the cluster's {} GPUs",
                cluster.num_gpus()
            ));
        }
        cluster = prefix_cluster(&cluster, workers_flag);
    }
    if let Some(spec) = fabric {
        return train_distributed(&a, cluster, batch, steps, spec);
    }

    // Plan compute/state division on the simulated heterogeneous
    // cluster, then execute the REAL numerics on this host.
    let names: Vec<String> =
        cluster.gpus().iter().map(|g| g.spec.name.clone()).collect();
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;
    let (asg, _) = w.optimize(batch).map_err(plan_err)?;
    let workers: Vec<WorkerSpec> =
        Trainer::workers_from_assignment(&asg, &names);
    crate::info!(
        "training plan: batches {:?}, state ratios {:?}",
        workers.iter().map(|w| w.batch).collect::<Vec<_>>(),
        workers
            .iter()
            .map(|w| (w.state_ratio * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    let cfg = TrainConfig {
        steps,
        seed: a.get_u64("seed").unwrap_or(42),
        adam: crate::trainer::adam::AdamConfig {
            lr: a.get_f64("lr").unwrap_or(1e-3) as f32,
            ..Default::default()
        },
        corpus_branch: 4,
        log_every: a.get_usize("log-every").unwrap_or(10),
        shard_params: shard_params_flag(&a)?,
        fsdp_units: a.get_usize("fsdp-units").unwrap_or(1),
    };
    let backend = a.get("backend").unwrap().to_string();
    let mut trainer = match backend.as_str() {
        "native" => {
            // Simulated per-step durations from the same oracle the
            // planner profiled, so logged steps/sec reflect the plan.
            let timer =
                StepTimeModel::from_oracle(&w.oracle, w.model.layers);
            let exec = NativeExecutor::new(SurrogateSpec::default())
                .with_timer(timer);
            Trainer::from_executor(Box::new(exec), workers, cfg)
                .map_err(|e| e.to_string())?
        }
        "pjrt" => {
            pjrt_trainer(a.get("artifacts").unwrap(), workers, cfg)?
        }
        other => {
            return Err(format!(
                "unknown backend '{other}' (native | pjrt)"
            ))
        }
    };
    println!(
        "backend {}: {} params ({} residency), corpus entropy {:.3} nats",
        trainer.executor_name(),
        trainer.num_params(),
        if trainer.is_sharded() { "fully-sharded" } else { "leader" },
        trainer.corpus_entropy()
    );
    if trainer.is_sharded() {
        let pb = trainer.param_bytes_per_worker();
        crate::info!(
            "per-rank resident weight bytes (scale with r_i): {:?}",
            pb
        );
    }
    let history = trainer.run().map_err(|e| e.to_string())?;
    let first = history.first().map(|s| s.mean_loss).unwrap_or(0.0);
    let last = history.last().map(|s| s.mean_loss).unwrap_or(0.0);
    println!(
        "loss {first:.4} -> {last:.4} over {} steps ({} samples/step)",
        history.len(),
        trainer.global_batch()
    );
    if let Some(path) = a.get("loss-csv") {
        let mut csv = String::from("step,loss,wall_seconds\n");
        for s in &history {
            csv.push_str(&format!("{},{},{}\n", s.step, s.mean_loss,
                                  s.wall_seconds));
        }
        std::fs::write(path, csv).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = &trace_out {
        use crate::util::json::Json;
        finish_trace(path, &[
            ("command", Json::Str("train".into())),
            ("backend", Json::Str(trainer.executor_name().to_string())),
        ])?;
    }
    Ok(())
}

/// `train --transport local|tcp|shm|hybrid`: plan on the simulated
/// cluster, then run one SPMD rank per cluster GPU over the chosen
/// fabric — worker threads over channels for `local`, spawned
/// `cephalo worker` processes over loopback sockets for `tcp`, over
/// `/dev/shm` ring buffers for `shm`, and locality-routed (shm lanes
/// within a host, TCP across, rings walked host-by-host) for `hybrid`.
fn train_distributed(
    a: &crate::cli::Args,
    cluster: Cluster,
    batch: usize,
    steps: usize,
    spec: FabricSpec,
) -> Result<(), String> {
    if a.get("backend").unwrap() != "native" {
        return Err("distributed transports (--transport local | tcp | \
                    shm | hybrid) run on the native backend only (the \
                    pjrt backend stays in-process)"
            .into());
    }
    let names: Vec<String> =
        cluster.gpus().iter().map(|g| g.spec.name.clone()).collect();
    let world = cluster.num_gpus();
    let seed = a.get_u64("seed").unwrap_or(42);
    let w = Workload::prepare(cluster, a.get("model").unwrap(), seed)
        .map_err(plan_err)?;
    let (asg, _) = w.optimize(batch).map_err(plan_err)?;
    let workers = Trainer::workers_from_assignment(&asg, &names);
    crate::info!(
        "distributed plan ({world} ranks over {}): batches {:?}, state \
         ratios {:?}",
        spec.label(),
        workers.iter().map(|w| w.batch).collect::<Vec<_>>(),
        workers
            .iter()
            .map(|w| (w.state_ratio * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    let dcfg = DistConfig {
        seed,
        adam: crate::trainer::adam::AdamConfig {
            lr: a.get_f64("lr").unwrap_or(1e-3) as f32,
            ..Default::default()
        },
        corpus_branch: 4,
        surrogate: SurrogateSpec::default(),
        shard_params: shard_params_flag(a)?,
        ft: false,
        mirror_leader: false,
        rejoin_window_ms: 0,
        ping_timeout_ms: 2000,
        fsdp_units: a.get_usize("fsdp-units").unwrap_or(1),
        hosts: parse_hosts(a, world)?,
        trace_out: a.get("trace-out").map(String::from),
    };
    let timer = StepTimeModel::from_oracle(&w.oracle, w.model.layers);
    let mut driver = DistDriver::launch(spec, world, dcfg, workers)
        .map_err(|e| e.to_string())?
        .with_timer(timer);
    let log_every = a.get_usize("log-every").unwrap_or(10);
    for s in 0..steps {
        let st = driver.step(s).map_err(|e| e.to_string())?;
        if log_every > 0 && s % log_every == 0 {
            crate::info!(
                "step {:>5}  loss {:.4}  ({:.2}s modeled / {:.4}s wall, \
                 {} tokens)",
                s,
                st.mean_loss,
                st.wall_seconds,
                st.measured_seconds,
                st.tokens
            );
        }
    }
    let first =
        driver.history.first().map(|s| s.mean_loss).unwrap_or(0.0);
    let last = driver.history.last().map(|s| s.mean_loss).unwrap_or(0.0);
    println!(
        "transport {}: {world} ranks, loss {first:.4} -> {last:.4} over \
         {} steps",
        spec.label(),
        driver.history.len()
    );
    print_skew_report(
        driver.planned_rank_seconds().as_deref(),
        &driver.rank_timings(),
    );
    if let Some(path) = a.get("loss-csv") {
        let mut csv = String::from("step,loss,wall_seconds\n");
        for s in &driver.history {
            csv.push_str(&format!(
                "{},{},{}\n",
                s.step, s.mean_loss, s.wall_seconds
            ));
        }
        std::fs::write(path, csv).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    driver.shutdown();
    if let Some(path) = a.get("trace-out") {
        use crate::util::json::Json;
        finish_trace(path, &[
            ("command", Json::Str("train".into())),
            ("transport", Json::Str(spec.label().to_string())),
            ("world", Json::Num(world as f64)),
        ])?;
    }
    Ok(())
}

/// `cephalo worker --rank i [--connect addr] [--shm-dir d] --world n`:
/// one distributed training rank. Normally spawned by the coordinator
/// (`train` / `elastic --live` with `--transport tcp | shm | hybrid`),
/// but any rendezvous address works — including another host's. The
/// fabric follows from which endpoints are given: `--connect` alone is
/// TCP, `--shm-dir` alone is shared memory, both together form the
/// hybrid fabric (shm lanes to the peers `--hosts` marks as same-host,
/// TCP to the rest).
fn cmd_worker(argv: &[String]) -> Result<(), String> {
    let specs = vec![
        opt("rank", "this rank (1..world; rank 0 is the coordinator)",
            None),
        opt("connect", "coordinator rendezvous address (host:port); \
                        required unless --shm-dir is given alone", None),
        opt("shm-dir", "shared-memory lane directory (same-host ranks \
                        only); with --connect, forms the hybrid fabric",
            None),
        opt("hosts", "rank → host-id map for the hybrid fabric, \
                      comma-separated; defaults to all-same-host", None),
        opt("world", "total rank count including the coordinator", None),
        opt("chaos", "deterministic fault injection spec (forwarded by \
                      the coordinator; an injected crash aborts this \
                      process)", None),
        opt("trace-out", "write this rank's Chrome/Perfetto span trace \
                          here (forwarded per-rank by the coordinator's \
                          --trace-out)", None),
        opt("log-level", "log threshold: error | warn | info | debug | \
                          trace (CEPHALO_LOG fallback)", None),
        switch("help", "show usage"),
    ];
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage(
            "cephalo worker",
            "serve one distributed training rank until shutdown",
            &specs,
        ));
        return Ok(());
    }
    apply_log_level(&a)?;
    let trace_out = a.get("trace-out").map(String::from);
    if trace_out.is_some() {
        crate::telemetry::enable();
    }
    let rank = a.get_usize("rank").ok_or("--rank is required")?;
    let world = a.get_usize("world").ok_or("--world is required")?;
    let t: Box<dyn Transport> = match (a.get("connect"), a.get("shm-dir"))
    {
        (Some(addr), None) => Box::new(
            transport::tcp::connect(addr, rank, world)
                .map_err(|e| e.to_string())?,
        ),
        (None, Some(dir)) => Box::new(
            ShmTransport::attach(std::path::Path::new(dir), rank, world)
                .map_err(|e| e.to_string())?,
        ),
        (Some(addr), Some(dir)) => {
            let topo = match a.get("hosts") {
                Some(spec) => HostTopology::parse(spec, world)?,
                None => HostTopology::single_host(world),
            };
            let tcp = transport::tcp::connect(addr, rank, world)
                .map_err(|e| e.to_string())?;
            Box::new(
                HybridTransport::wrap(
                    Box::new(tcp),
                    std::path::Path::new(dir),
                    topo,
                )
                .map_err(|e| e.to_string())?,
            )
        }
        (None, None) => {
            return Err(
                "one of --connect / --shm-dir is required (both for \
                 the hybrid fabric)"
                    .into(),
            )
        }
    };
    let result = match a.get("chaos") {
        Some(spec) => {
            let (seed, ccfg) =
                ChaosConfig::parse(spec).map_err(|e| e.to_string())?;
            let plan = FaultPlan::generate(seed, world, &ccfg);
            // Abort mode: an injected crash is a real process death
            // (exit 137, as if kill -9), so the coordinator exercises
            // the same detection path a preempted instance would.
            let t = ChaosTransport::new(t, &plan, CrashMode::Abort);
            transport::worker_loop(Box::new(t)).map_err(|e| e.to_string())
        }
        None => transport::worker_loop(t).map_err(|e| e.to_string()),
    };
    if let Some(path) = &trace_out {
        // Written on clean shutdown only; an Abort-mode chaos crash
        // exits without flushing, exactly like a real kill -9.
        use crate::util::json::Json;
        finish_trace(path, &[
            ("command", Json::Str("worker".into())),
            ("rank", Json::Num(rank as f64)),
        ])?;
    }
    result
}

/// `bench-gate --baseline <json> --current <json> [--out <verdict>]`:
/// the perf-trajectory gate. Deterministic metrics (bytes/elems/peak/
/// ratio keys) must match exactly; the aggregate of rate metrics may
/// not regress beyond `benchkit::RATE_NOISE_BAND`. Non-zero exit on
/// regression, so CI can wire it directly after a bench run.
fn cmd_bench_gate(argv: &[String]) -> Result<(), String> {
    let specs = vec![
        opt("baseline", "BENCH_*.json from the reference run", None),
        opt("current", "BENCH_*.json from the candidate run", None),
        opt("out", "write the comparison verdict JSON here", None),
        switch("help", "show usage"),
    ];
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage(
            "cephalo bench-gate",
            "fail on perf regression between two bench artifacts",
            &specs,
        ));
        return Ok(());
    }
    let baseline = a.get("baseline").ok_or("--baseline is required")?;
    let current = a.get("current").ok_or("--current is required")?;
    let pass =
        crate::benchkit::gate_files(baseline, current, a.get("out"))?;
    if !pass {
        return Err(format!(
            "perf gate failed: {current} regressed against {baseline}"
        ));
    }
    Ok(())
}

/// Stand up the PJRT-backed trainer (`--backend pjrt`).
#[cfg(feature = "xla")]
fn pjrt_trainer(
    artifacts: &str,
    workers: Vec<WorkerSpec>,
    cfg: TrainConfig,
) -> Result<Trainer, String> {
    let dir = std::path::PathBuf::from(artifacts);
    if !dir.join("manifest.json").exists() {
        return Err(format!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        ));
    }
    Trainer::new(&dir, workers, cfg).map_err(|e| e.to_string())
}

#[cfg(not(feature = "xla"))]
fn pjrt_trainer(
    _artifacts: &str,
    _workers: Vec<WorkerSpec>,
    _cfg: TrainConfig,
) -> Result<Trainer, String> {
    Err("the pjrt backend needs a build with `--features xla`; \
         use --backend native"
        .into())
}

fn cmd_trace(argv: &[String]) -> Result<(), String> {
    let specs = vec![
        opt("hours", "trace length", Some("12")),
        opt("seed", "PRNG seed", Some("42")),
        switch("help", "show usage"),
    ];
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo trace", "AWS availability trace",
                             &specs));
        return Ok(());
    }
    let hours = a.get_usize("hours").unwrap_or(12);
    let profiles = crate::cluster::aws_trace::default_profiles();
    let trace = crate::cluster::aws_trace::generate(
        a.get_u64("seed").unwrap_or(42),
        hours,
        &profiles,
    );
    let mut headers = vec!["hour".to_string()];
    headers.extend(profiles.iter().map(|p| p.gpu.clone()));
    let mut t = Table::new(
        "AWS GPU availability (instances obtainable per hour)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for h in &trace {
        let mut row = vec![h.hour.to_string()];
        row.extend(h.available.iter().map(|(_, c)| c.to_string()));
        t.add_row(row);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_paths() {
        assert_eq!(main_with_args(sv(&["help"])), 0);
        assert_eq!(main_with_args(sv(&[])), 2);
        assert_eq!(main_with_args(sv(&["bogus"])), 1);
    }

    #[test]
    fn optimize_runs() {
        assert_eq!(
            main_with_args(sv(&["optimize", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "64"])),
            0
        );
    }

    #[test]
    fn simulate_single_system() {
        assert_eq!(
            main_with_args(sv(&["simulate", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "64",
                                "--system", "whale"])),
            0
        );
    }

    #[test]
    fn plan_all_systems_runs() {
        assert_eq!(
            main_with_args(sv(&["plan", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "64",
                                "--system", "all"])),
            0
        );
    }

    #[test]
    fn plan_single_system_and_batch_list() {
        assert_eq!(
            main_with_args(sv(&["plan", "--cluster", "a", "--model",
                                "BERT-Large", "--system", "cephalo-mb",
                                "--batches", "32,64"])),
            0
        );
        assert_eq!(
            main_with_args(sv(&["plan", "--cluster", "a", "--system",
                                "not-a-planner"])),
            1
        );
    }

    #[test]
    fn elastic_churn_runs() {
        assert_eq!(
            main_with_args(sv(&["elastic", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "64",
                                "--events", "4"])),
            0
        );
    }

    #[test]
    fn elastic_live_session_runs() {
        assert_eq!(
            main_with_args(sv(&["elastic", "--live", "--cluster", "a",
                                "--model", "BERT-Large", "--batch", "32",
                                "--events", "3", "--steps", "1"])),
            0
        );
    }

    #[test]
    fn train_distributed_local_transport_runs() {
        // Two SPMD ranks over the channel fabric, real message plane,
        // no processes (tcp-with-processes is exercised by the CI
        // smoke job — spawning the test binary would re-enter libtest).
        assert_eq!(
            main_with_args(sv(&["train", "--transport", "local",
                                "--workers", "2", "--cluster", "a",
                                "--model", "BERT-Large", "--batch", "16",
                                "--steps", "2", "--log-every", "0"])),
            0
        );
        assert_eq!(
            main_with_args(sv(&["train", "--transport", "bogus",
                                "--cluster", "a", "--batch", "16"])),
            1
        );
        assert_eq!(
            main_with_args(sv(&["train", "--transport", "local",
                                "--workers", "99", "--cluster", "a",
                                "--batch", "16"])),
            1
        );
    }

    #[test]
    fn train_sharded_params_runs_on_both_engines() {
        assert_eq!(
            main_with_args(sv(&["train", "--backend", "native",
                                "--shard-params", "--cluster", "a",
                                "--model", "BERT-Large", "--batch", "16",
                                "--steps", "2", "--log-every", "0"])),
            0
        );
        assert_eq!(
            main_with_args(sv(&["train", "--transport", "local",
                                "--workers", "2", "--shard-params",
                                "--cluster", "a", "--model", "BERT-Large",
                                "--batch", "16", "--steps", "2",
                                "--log-every", "0"])),
            0
        );
    }

    #[test]
    fn train_unit_sharded_runs_on_both_engines() {
        // Sharding is the default now; --fsdp-units cuts the gather.
        assert_eq!(
            main_with_args(sv(&["train", "--backend", "native",
                                "--fsdp-units", "4", "--cluster", "a",
                                "--model", "BERT-Large", "--batch", "16",
                                "--steps", "2", "--log-every", "0"])),
            0
        );
        assert_eq!(
            main_with_args(sv(&["train", "--transport", "local",
                                "--workers", "2", "--fsdp-units", "4",
                                "--cluster", "a", "--model", "BERT-Large",
                                "--batch", "16", "--steps", "2",
                                "--log-every", "0"])),
            0
        );
    }

    #[test]
    fn leader_params_opts_out_and_conflicts_with_shard_params() {
        assert_eq!(
            main_with_args(sv(&["train", "--backend", "native",
                                "--leader-params", "--cluster", "a",
                                "--model", "BERT-Large", "--batch", "16",
                                "--steps", "2", "--log-every", "0"])),
            0
        );
        assert_eq!(
            main_with_args(sv(&["train", "--backend", "native",
                                "--leader-params", "--shard-params",
                                "--cluster", "a", "--batch", "16"])),
            1
        );
    }

    #[test]
    fn elastic_live_sharded_params_runs() {
        assert_eq!(
            main_with_args(sv(&["elastic", "--live", "--shard-params",
                                "--cluster", "a", "--model", "BERT-Large",
                                "--batch", "32", "--events", "2",
                                "--steps", "1"])),
            0
        );
    }

    #[test]
    fn elastic_live_local_transport_runs() {
        assert_eq!(
            main_with_args(sv(&["elastic", "--live", "--transport",
                                "local", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "32",
                                "--events", "2", "--steps", "1"])),
            0
        );
    }

    #[test]
    fn elastic_live_chaos_session_recovers_and_logs() {
        let log = std::env::temp_dir().join("cephalo_chaos_cli.json");
        let log_s = log.to_str().unwrap().to_string();
        assert_eq!(
            main_with_args(sv(&["elastic", "--live", "--transport",
                                "local", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "32",
                                "--events", "3", "--steps", "2",
                                "--chaos",
                                "seed=5,crash=1,first=1,delay=0,dup=0",
                                "--chaos-log", &log_s])),
            0
        );
        let text = std::fs::read_to_string(&log).unwrap();
        std::fs::remove_file(&log).ok();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert!(j.get("fault_plan").is_some());
        let recs = j.get("recoveries").unwrap().as_arr().unwrap();
        assert!(!recs.is_empty(), "chaos crash must be recovered from");
        assert!(recs[0].get("detect_ms").is_some());
    }

    #[test]
    fn chaos_flags_require_a_live_distributed_session() {
        // Chaos on the offline churn demo is meaningless.
        assert_eq!(
            main_with_args(sv(&["elastic", "--cluster", "a", "--chaos",
                                "seed=1"])),
            1
        );
        // ... and the in-process fabric has no ranks to kill.
        assert_eq!(
            main_with_args(sv(&["elastic", "--live", "--cluster", "a",
                                "--batch", "32", "--events", "1",
                                "--steps", "1", "--chaos", "seed=1"])),
            1
        );
    }

    #[test]
    fn worker_requires_connection_args() {
        assert_eq!(main_with_args(sv(&["worker"])), 1);
        assert_eq!(
            main_with_args(sv(&["worker", "--rank", "0", "--connect",
                                "127.0.0.1:1", "--world", "4"])),
            1
        );
        assert_eq!(main_with_args(sv(&["worker", "--help"])), 0);
    }

    #[test]
    fn train_native_backend_runs_ungated() {
        assert_eq!(
            main_with_args(sv(&["train", "--backend", "native",
                                "--cluster", "a", "--model", "BERT-Large",
                                "--batch", "32", "--steps", "2",
                                "--log-every", "0"])),
            0
        );
        assert_eq!(
            main_with_args(sv(&["train", "--backend", "bogus",
                                "--cluster", "a", "--batch", "32"])),
            1
        );
    }

    #[test]
    fn profile_synthetic() {
        assert_eq!(
            main_with_args(sv(&["profile", "--cluster", "a", "--model",
                                "BERT-Large"])),
            0
        );
    }

    #[test]
    fn trace_runs() {
        assert_eq!(main_with_args(sv(&["trace", "--hours", "3"])), 0);
    }

    #[test]
    fn bench_gate_cli_passes_and_fails() {
        let dir = std::env::temp_dir();
        let bp = dir.join("cephalo_cli_gate_base.json");
        let cp = dir.join("cephalo_cli_gate_cur.json");
        let vp = dir.join("cephalo_cli_gate_verdict.json");
        let write = |p: &std::path::Path, bytes: f64| {
            std::fs::write(
                p,
                format!(
                    "{{\"bench\":\"t\",\"quick\":true,\"rows\":\
                     [{{\"elems\":64,\"bytes_per_round\":{bytes},\
                     \"ag_local_gbps\":2.0}}]}}"
                ),
            )
            .unwrap();
        };
        write(&bp, 256.0);
        write(&cp, 256.0);
        assert_eq!(
            main_with_args(sv(&["bench-gate",
                                "--baseline", bp.to_str().unwrap(),
                                "--current", cp.to_str().unwrap(),
                                "--out", vp.to_str().unwrap()])),
            0
        );
        assert!(vp.exists());
        // Deterministic accounting drifted -> gate fails loudly.
        write(&cp, 512.0);
        assert_eq!(
            main_with_args(sv(&["bench-gate",
                                "--baseline", bp.to_str().unwrap(),
                                "--current", cp.to_str().unwrap()])),
            1
        );
        assert_eq!(main_with_args(sv(&["bench-gate"])), 1);
        for p in [&bp, &cp, &vp] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn log_level_flag_validates_and_applies() {
        assert_eq!(
            main_with_args(sv(&["optimize", "--cluster", "a", "--batch",
                                "64", "--log-level", "bogus"])),
            1
        );
        assert_eq!(
            main_with_args(sv(&["optimize", "--cluster", "a", "--batch",
                                "64", "--log-level", "warn"])),
            0
        );
        assert_eq!(crate::logging::level(), crate::logging::Level::Warn);
        crate::logging::set_level(crate::logging::Level::Info);
    }

    #[test]
    fn trace_out_requires_a_live_elastic_session() {
        assert_eq!(
            main_with_args(sv(&["elastic", "--cluster", "a",
                                "--trace-out", "unused.json"])),
            1
        );
    }

    #[test]
    fn trace_out_writes_a_perfetto_trace() {
        let _g = crate::telemetry::test_lock();
        crate::telemetry::reset();
        let path = std::env::temp_dir().join(format!(
            "cephalo_cli_trace_{}.json",
            std::process::id()
        ));
        let p = path.to_str().unwrap().to_string();
        assert_eq!(
            main_with_args(sv(&["train", "--transport", "local",
                                "--workers", "2", "--cluster", "a",
                                "--model", "BERT-Large", "--batch", "16",
                                "--steps", "2", "--log-every", "0",
                                "--trace-out", &p])),
            0
        );
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        crate::telemetry::reset();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let evs = j.field("traceEvents").unwrap().as_arr().unwrap();
        assert!(
            evs.iter().any(|e| {
                e.get("ph").and_then(|ph| ph.as_str()) == Some("X")
            }),
            "trace must contain complete spans"
        );
        let meta = j.field("metadata").unwrap();
        assert!(meta.get("fabric_counters").is_some());
        assert_eq!(meta.get("transport").unwrap().as_str(), Some("local"));
    }

    #[test]
    fn bad_cluster_is_error() {
        assert_eq!(
            main_with_args(sv(&["optimize", "--cluster", "nope"])),
            1
        );
    }
}
