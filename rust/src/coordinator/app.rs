//! The `cephalo` CLI: profile / optimize / simulate / train / trace.

use crate::baselines::{self, BaselinePlanner};
use crate::cli::{opt, parse, switch, usage, OptSpec};
use crate::cluster::Cluster;
use crate::coordinator::Workload;
use crate::optimizer::PlanError;
use crate::trainer::{TrainConfig, Trainer, WorkerSpec};
use crate::util::tablefmt::{fmt_throughput, Table};

pub fn main_with_args(argv: Vec<String>) -> i32 {
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return 2;
    };
    let rest = argv[1..].to_vec();
    let code = match cmd.as_str() {
        "optimize" => cmd_optimize(&rest),
        "simulate" => cmd_simulate(&rest),
        "profile" => cmd_profile(&rest),
        "train" => cmd_train(&rest),
        "trace" => cmd_trace(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'; try `cephalo help`")),
    };
    match code {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn print_help() {
    println!(
        "cephalo — heterogeneous-cluster transformer training\n\n\
         commands:\n  \
         optimize  solve the compute/state division for a workload\n  \
         simulate  throughput of cephalo and/or baselines on a cluster\n  \
         profile   fit or measure performance models\n  \
         train     run real training via the AOT artifacts (PJRT)\n  \
         trace     generate the AWS availability trace (Fig. 1)\n  \
         help      this message\n\n\
         run `cephalo <command> --help` for options"
    );
}

fn common_specs() -> Vec<OptSpec> {
    vec![
        opt("cluster", "preset cluster: a | b | 16xv100 | 32xa10g, or a \
                        TOML config path", Some("a")),
        opt("model", "Table-2 model name", Some("BERT-Large")),
        opt("batch", "global batch size", Some("128")),
        opt("seed", "PRNG seed", Some("42")),
        switch("help", "show usage"),
    ]
}

fn resolve_cluster(name: &str) -> Result<Cluster, String> {
    if let Some(c) = Cluster::preset(name) {
        return Ok(c);
    }
    if std::path::Path::new(name).exists() {
        let cfg = crate::configfmt::Config::load(name)
            .map_err(|e| e.to_string())?;
        return Cluster::from_config(&cfg);
    }
    Err(format!("unknown cluster '{name}' (not a preset or config file)"))
}

fn plan_err(e: PlanError) -> String {
    e.to_string()
}

fn cmd_optimize(argv: &[String]) -> Result<(), String> {
    let specs = common_specs();
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo optimize", "solve a workload", &specs));
        return Ok(());
    }
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;
    let (asg, stats) = w.optimize(batch).map_err(plan_err)?;

    let mut t = Table::new(
        &format!(
            "Optimized configuration: {} on cluster {} @ batch {batch}",
            w.model.name, w.cluster.name
        ),
        &["gpu", "type", "batch b_i", "micro m_i", "count l_i",
          "state r_i"],
    );
    for (i, (g, slot)) in
        asg.per_gpu.iter().zip(w.cluster.gpus()).enumerate()
    {
        t.add_row(vec![
            i.to_string(),
            slot.spec.name.clone(),
            g.batch().to_string(),
            g.microbatch.to_string(),
            g.num_micro.to_string(),
            format!("{:.3}", g.state_ratio),
        ]);
    }
    println!("{}", t.render());
    println!(
        "predicted iter latency {:.4}s  throughput {:.2} samples/s  \
         (DP: {} states, {} transitions, {:.2}s solve)",
        asg.iter_latency,
        asg.throughput(),
        stats.states_visited,
        stats.transitions,
        stats.solve_seconds
    );
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(opt("system", "cephalo | megatron | flashflex | whale | \
                              hap | fsdp | all", Some("all")));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo simulate", "simulate throughput",
                             &specs));
        return Ok(());
    }
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;

    let system = a.get("system").unwrap().to_ascii_lowercase();
    let mut t = Table::new(
        &format!(
            "Simulated throughput (samples/s): {} on cluster {} @ {batch}",
            w.model.name, w.cluster.name
        ),
        &["system", "throughput", "config"],
    );
    if system == "cephalo" || system == "all" {
        match w.cephalo_throughput(batch) {
            Ok((asg, stats)) => {
                let bs: Vec<usize> =
                    asg.per_gpu.iter().map(|g| g.batch()).collect();
                t.add_row(vec![
                    "Cephalo".into(),
                    fmt_throughput(stats.throughput),
                    format!("b={bs:?}"),
                ]);
            }
            Err(e) => t.add_row(vec!["Cephalo".into(), "OOM".into(),
                                     e.to_string()]),
        }
    }
    let planners: Vec<Box<dyn BaselinePlanner>> = vec![
        Box::new(baselines::megatron::MegatronHet),
        Box::new(baselines::flashflex::FlashFlex),
        Box::new(baselines::whale::Whale),
        Box::new(baselines::hap::Hap),
        Box::new(baselines::fsdp::FsdpBaseline),
    ];
    for p in planners {
        let key = p.name().to_ascii_lowercase();
        if system != "all" && !key.contains(&system) {
            continue;
        }
        match p.plan(&w.ctx(batch)) {
            Ok(out) => t.add_row(vec![
                out.system,
                fmt_throughput(out.throughput),
                out.config,
            ]),
            Err(e) => t.add_row(vec![p.name().into(), "OOM".into(),
                                     e.to_string()]),
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_profile(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(switch("real", "profile the AOT layer_fwd via PJRT"));
    specs.push(opt("artifacts", "artifacts directory",
                   Some("artifacts")));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo profile", "fit performance models",
                             &specs));
        return Ok(());
    }
    if a.has("real") {
        let dir = std::path::PathBuf::from(a.get("artifacts").unwrap());
        let samples =
            crate::coordinator::real_profile::profile_layer_fwd(&dir, 5)
                .map_err(|e| e.to_string())?;
        let mut t = Table::new(
            "Real layer_fwd latency via PJRT (CPU)",
            &["microbatch", "mean", "min"],
        );
        for s in samples {
            t.add_row(vec![
                s.microbatch.to_string(),
                crate::util::human_secs(s.mean_seconds),
                crate::util::human_secs(s.min_seconds),
            ]);
        }
        println!("{}", t.render());
        return Ok(());
    }
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;
    let mut t = Table::new(
        &format!("Fitted per-GPU models: {} on cluster {}", w.model.name,
                 w.cluster.name),
        &["gpu", "type", "fwd(m=1)", "fwd(m=8)", "bwd(m=8)",
          "mem(m=8) GB", "cap GB"],
    );
    for (i, (g, slot)) in
        w.profile.per_gpu.iter().zip(w.cluster.gpus()).enumerate()
    {
        t.add_row(vec![
            i.to_string(),
            slot.spec.name.clone(),
            crate::util::human_secs(g.fwd.predict(1)),
            crate::util::human_secs(g.fwd.predict(8)),
            crate::util::human_secs(g.bwd.predict(8)),
            format!("{:.2}", g.mem.predict(8) / 1e9),
            format!("{:.0}", g.capacity / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!(
        "unit AG {:.2} ms (uneven {:.2} ms), RS {:.2} ms",
        w.profile.unit_allgather() * 1e3,
        w.profile.unit_allgather_uneven() * 1e3,
        w.profile.unit_reduce_scatter() * 1e3
    );
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let mut specs = common_specs();
    specs.push(opt("steps", "training steps", Some("50")));
    specs.push(opt("lr", "Adam learning rate", Some("0.001")));
    specs.push(opt("artifacts", "artifacts directory", Some("artifacts")));
    specs.push(opt("log-every", "log cadence", Some("10")));
    specs.push(opt("loss-csv", "write the loss curve CSV here", None));
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo train",
                             "real training over PJRT artifacts", &specs));
        return Ok(());
    }
    let cluster = resolve_cluster(a.get("cluster").unwrap())?;
    let batch = a.get_usize("batch").ok_or("bad --batch")?;
    let steps = a.get_usize("steps").ok_or("bad --steps")?;
    let dir = std::path::PathBuf::from(a.get("artifacts").unwrap());
    if !dir.join("manifest.json").exists() {
        return Err(format!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        ));
    }

    // Plan compute/state division on the simulated heterogeneous
    // cluster, then execute the REAL numerics on this host.
    let names: Vec<String> =
        cluster.gpus().iter().map(|g| g.spec.name.clone()).collect();
    let w = Workload::prepare(
        cluster,
        a.get("model").unwrap(),
        a.get_u64("seed").unwrap_or(42),
    )
    .map_err(plan_err)?;
    let (asg, _) = w.optimize(batch).map_err(plan_err)?;
    let workers: Vec<WorkerSpec> =
        Trainer::workers_from_assignment(&asg, &names);
    crate::info!(
        "training plan: batches {:?}, state ratios {:?}",
        workers.iter().map(|w| w.batch).collect::<Vec<_>>(),
        workers
            .iter()
            .map(|w| (w.state_ratio * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    let cfg = TrainConfig {
        steps,
        seed: a.get_u64("seed").unwrap_or(42),
        adam: crate::trainer::adam::AdamConfig {
            lr: a.get_f64("lr").unwrap_or(1e-3) as f32,
            ..Default::default()
        },
        corpus_branch: 4,
        log_every: a.get_usize("log-every").unwrap_or(10),
    };
    let mut trainer =
        Trainer::new(&dir, workers, cfg).map_err(|e| e.to_string())?;
    println!(
        "model: {} params, corpus entropy {:.3} nats, ln(V) = {:.3}",
        trainer.manifest().model.num_params,
        trainer.corpus_entropy(),
        (trainer.manifest().model.vocab as f64).ln()
    );
    let history = trainer.run().map_err(|e| e.to_string())?;
    let first = history.first().map(|s| s.mean_loss).unwrap_or(0.0);
    let last = history.last().map(|s| s.mean_loss).unwrap_or(0.0);
    println!(
        "loss {first:.4} -> {last:.4} over {} steps ({} samples/step)",
        history.len(),
        trainer.global_batch()
    );
    if let Some(path) = a.get("loss-csv") {
        let mut csv = String::from("step,loss,wall_seconds\n");
        for s in &history {
            csv.push_str(&format!("{},{},{}\n", s.step, s.mean_loss,
                                  s.wall_seconds));
        }
        std::fs::write(path, csv).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_trace(argv: &[String]) -> Result<(), String> {
    let specs = vec![
        opt("hours", "trace length", Some("12")),
        opt("seed", "PRNG seed", Some("42")),
        switch("help", "show usage"),
    ];
    let a = parse(argv, &specs)?;
    if a.has("help") {
        println!("{}", usage("cephalo trace", "AWS availability trace",
                             &specs));
        return Ok(());
    }
    let hours = a.get_usize("hours").unwrap_or(12);
    let profiles = crate::cluster::aws_trace::default_profiles();
    let trace = crate::cluster::aws_trace::generate(
        a.get_u64("seed").unwrap_or(42),
        hours,
        &profiles,
    );
    let mut headers = vec!["hour".to_string()];
    headers.extend(profiles.iter().map(|p| p.gpu.clone()));
    let mut t = Table::new(
        "AWS GPU availability (instances obtainable per hour)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for h in &trace {
        let mut row = vec![h.hour.to_string()];
        row.extend(h.available.iter().map(|(_, c)| c.to_string()));
        t.add_row(row);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_paths() {
        assert_eq!(main_with_args(sv(&["help"])), 0);
        assert_eq!(main_with_args(sv(&[])), 2);
        assert_eq!(main_with_args(sv(&["bogus"])), 1);
    }

    #[test]
    fn optimize_runs() {
        assert_eq!(
            main_with_args(sv(&["optimize", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "64"])),
            0
        );
    }

    #[test]
    fn simulate_single_system() {
        assert_eq!(
            main_with_args(sv(&["simulate", "--cluster", "a", "--model",
                                "BERT-Large", "--batch", "64",
                                "--system", "whale"])),
            0
        );
    }

    #[test]
    fn profile_synthetic() {
        assert_eq!(
            main_with_args(sv(&["profile", "--cluster", "a", "--model",
                                "BERT-Large"])),
            0
        );
    }

    #[test]
    fn trace_runs() {
        assert_eq!(main_with_args(sv(&["trace", "--hours", "3"])), 0);
    }

    #[test]
    fn bad_cluster_is_error() {
        assert_eq!(
            main_with_args(sv(&["optimize", "--cluster", "nope"])),
            1
        );
    }
}
