//! Shared helpers for the benchmark binaries (`rust/benches/*.rs`):
//! uniform "system -> throughput" evaluation, now routed through the
//! unified `plan::PlannerRegistry` instead of per-system match arms.

use crate::coordinator::Workload;
use crate::optimizer::PlanError;
use crate::plan::{PlanOutcome, PlannerRegistry, SweepCell};

/// The systems compared across the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    Cephalo,
    MegatronHet,
    FlashFlex,
    Whale,
    Hap,
    Fsdp,
}

impl SystemKind {
    /// Display name == registry name (`PlannerRegistry::get` input).
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Cephalo => "Cephalo",
            SystemKind::MegatronHet => "Megatron-Het",
            SystemKind::FlashFlex => "FlashFlex",
            SystemKind::Whale => "Whale",
            SystemKind::Hap => "HAP",
            SystemKind::Fsdp => "FSDP",
        }
    }
}

/// Samples/s of `system` on the workload, or the planning error (OOM).
/// Cephalo's number comes from the event simulator (the planner
/// simulates its solved assignment), baselines from their own search —
/// identical semantics to the pre-registry per-system match.
///
/// One-off convenience (builds a registry per call) for tests and
/// spot checks; anything looping over a grid should run ONE
/// `Workload::sweep` and read cells via [`find_cell`]/[`outcome_cell`],
/// as the table benches do.
pub fn throughput(w: &Workload, batch: usize, system: SystemKind)
    -> Result<f64, PlanError> {
    let registry = PlannerRegistry::with_defaults();
    w.plan_with(&registry, system.name(), batch, None)
        .map(|o| o.throughput)
}

/// "6.38" or "OOM" — the paper's table cell format.
pub fn cell(w: &Workload, batch: usize, system: SystemKind) -> String {
    match throughput(w, batch, system) {
        Ok(t) => format!("{t:.2}"),
        Err(e) if e.is_oom() => "OOM".to_string(),
        Err(_) => "-".to_string(),
    }
}

/// The same cell format for a sweep result (lets benches run ONE
/// parallel `Workload::sweep` and format all cells from it).
pub fn outcome_cell(result: &Result<PlanOutcome, PlanError>) -> String {
    match result {
        Ok(o) => format!("{:.2}", o.throughput),
        Err(e) if e.is_oom() => "OOM".to_string(),
        Err(_) => "-".to_string(),
    }
}

/// Find one sweep cell by (planner, batch).
pub fn find_cell<'a>(
    cells: &'a [SweepCell],
    system: SystemKind,
    batch: usize,
) -> &'a SweepCell {
    cells
        .iter()
        .find(|c| c.planner == system.name() && c.batch == batch)
        .unwrap_or_else(|| {
            panic!("no sweep cell for {} @{batch}", system.name())
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn cells_format() {
        let w = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
            .unwrap();
        assert_eq!(cell(&w, 128, SystemKind::Whale), "OOM");
        let c = cell(&w, 128, SystemKind::Cephalo);
        assert!(c.parse::<f64>().is_ok(), "{c}");
    }

    #[test]
    fn sweep_cells_match_direct_throughput() {
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let registry = PlannerRegistry::with_defaults();
        let cells = w.sweep(&registry, &[128], None);
        let direct = throughput(&w, 128, SystemKind::FlashFlex).unwrap();
        let from_sweep = find_cell(&cells, SystemKind::FlashFlex, 128)
            .throughput()
            .unwrap();
        assert_eq!(direct, from_sweep);
        assert_eq!(
            outcome_cell(&find_cell(&cells, SystemKind::FlashFlex, 128)
                .result),
            format!("{direct:.2}")
        );
    }
}
