//! Shared helpers for the benchmark binaries (`rust/benches/*.rs`):
//! uniform "system -> throughput" evaluation used by every table bench.

use crate::baselines::{self, BaselinePlanner};
use crate::coordinator::Workload;
use crate::optimizer::PlanError;
use crate::sim::GaVariant;

/// The systems compared across the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    Cephalo,
    MegatronHet,
    FlashFlex,
    Whale,
    Hap,
    Fsdp,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Cephalo => "Cephalo",
            SystemKind::MegatronHet => "Megatron-Het",
            SystemKind::FlashFlex => "FlashFlex",
            SystemKind::Whale => "Whale",
            SystemKind::Hap => "HAP",
            SystemKind::Fsdp => "FSDP",
        }
    }
}

/// Samples/s of `system` on the workload, or the planning error (OOM).
pub fn throughput(w: &Workload, batch: usize, system: SystemKind)
    -> Result<f64, PlanError> {
    match system {
        SystemKind::Cephalo => {
            let (asg, _) = w.optimize(batch)?;
            let stats = w.simulate(&asg, GaVariant::LGA_CO_S_O);
            Ok(stats.throughput)
        }
        SystemKind::MegatronHet => baselines::megatron::MegatronHet
            .plan(&w.ctx(batch))
            .map(|o| o.throughput),
        SystemKind::FlashFlex => baselines::flashflex::FlashFlex
            .plan(&w.ctx(batch))
            .map(|o| o.throughput),
        SystemKind::Whale => {
            baselines::whale::Whale.plan(&w.ctx(batch)).map(|o| o.throughput)
        }
        SystemKind::Hap => {
            baselines::hap::Hap.plan(&w.ctx(batch)).map(|o| o.throughput)
        }
        SystemKind::Fsdp => baselines::fsdp::FsdpBaseline
            .plan(&w.ctx(batch))
            .map(|o| o.throughput),
    }
}

/// "6.38" or "OOM" — the paper's table cell format.
pub fn cell(w: &Workload, batch: usize, system: SystemKind) -> String {
    match throughput(w, batch, system) {
        Ok(t) => format!("{t:.2}"),
        Err(PlanError::OutOfMemory { .. }) => "OOM".to_string(),
        Err(_) => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn cells_format() {
        let w = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
            .unwrap();
        assert_eq!(cell(&w, 128, SystemKind::Whale), "OOM");
        let c = cell(&w, 128, SystemKind::Cephalo);
        assert!(c.parse::<f64>().is_ok(), "{c}");
    }
}
