//! Shared helpers for the benchmark binaries (`rust/benches/*.rs`):
//! uniform "system -> throughput" evaluation, now routed through the
//! unified `plan::PlannerRegistry` instead of per-system match arms.

use crate::coordinator::Workload;
use crate::optimizer::PlanError;
use crate::plan::{PlanOutcome, PlannerRegistry, SweepCell};

/// The systems compared across the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// This repo's planner (the paper's system).
    Cephalo,
    /// Megatron-LM with heterogeneity-aware uniform stages.
    MegatronHet,
    /// FlashFlex-style asymmetric pipeline planning.
    FlashFlex,
    /// Whale-style hardware-aware operator placement.
    Whale,
    /// HAP-style hybrid automatic parallelism.
    Hap,
    /// Homogeneous fully-sharded data parallelism.
    Fsdp,
}

impl SystemKind {
    /// Display name == registry name (`PlannerRegistry::get` input).
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Cephalo => "Cephalo",
            SystemKind::MegatronHet => "Megatron-Het",
            SystemKind::FlashFlex => "FlashFlex",
            SystemKind::Whale => "Whale",
            SystemKind::Hap => "HAP",
            SystemKind::Fsdp => "FSDP",
        }
    }
}

/// Samples/s of `system` on the workload, or the planning error (OOM).
/// Cephalo's number comes from the event simulator (the planner
/// simulates its solved assignment), baselines from their own search —
/// identical semantics to the pre-registry per-system match.
///
/// One-off convenience (builds a registry per call) for tests and
/// spot checks; anything looping over a grid should run ONE
/// `Workload::sweep` and read cells via [`find_cell`]/[`outcome_cell`],
/// as the table benches do.
pub fn throughput(w: &Workload, batch: usize, system: SystemKind)
    -> Result<f64, PlanError> {
    let registry = PlannerRegistry::with_defaults();
    w.plan_with(&registry, system.name(), batch, None)
        .map(|o| o.throughput)
}

/// "6.38" or "OOM" — the paper's table cell format.
pub fn cell(w: &Workload, batch: usize, system: SystemKind) -> String {
    match throughput(w, batch, system) {
        Ok(t) => format!("{t:.2}"),
        Err(e) if e.is_oom() => "OOM".to_string(),
        Err(_) => "-".to_string(),
    }
}

/// The same cell format for a sweep result (lets benches run ONE
/// parallel `Workload::sweep` and format all cells from it).
pub fn outcome_cell(result: &Result<PlanOutcome, PlanError>) -> String {
    match result {
        Ok(o) => format!("{:.2}", o.throughput),
        Err(e) if e.is_oom() => "OOM".to_string(),
        Err(_) => "-".to_string(),
    }
}

/// Planned-vs-measured per-rank skew table for a finished session.
///
/// `planned` is the cost model's per-step estimate for each rank (from
/// `StepTimeModel::per_rank_seconds` over the final batch assignment);
/// `timings` are the accumulated wire-reported measurements
/// (`DistDriver::rank_timings`). Ranks with zero timed steps (standby
/// or dead) print "-" in the measured columns. The slowest measured
/// rank — the straggler the balancer should have flattened — is
/// flagged with `*`.
pub fn skew_table(
    planned: &[f64],
    timings: &[crate::transport::RankTiming],
) -> String {
    let mut t = crate::util::tablefmt::Table::new(
        "planned vs measured step time (per rank)",
        &[
            "rank", "steps", "planned s", "measured s", "skew %",
            "gather s", "compute s", "rs s", "wait s",
        ],
    );
    let measured_mean = |rt: &crate::transport::RankTiming| {
        if rt.steps == 0 {
            None
        } else {
            Some(rt.measured_seconds / rt.steps as f64)
        }
    };
    let straggler = timings
        .iter()
        .filter_map(|rt| measured_mean(rt).map(|m| (rt.rank, m)))
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(r, _)| r);
    for rt in timings {
        let plan = planned.get(rt.rank).copied();
        let (measured, skew, gather, compute, rs, wait) = match measured_mean(rt)
        {
            Some(m) => {
                let n = rt.steps as f64;
                (
                    format!("{m:.4}"),
                    match plan {
                        Some(p) if p > 0.0 => {
                            format!("{:+.1}", 100.0 * (m - p) / p)
                        }
                        _ => "-".to_string(),
                    },
                    format!("{:.4}", rt.phases.gather_s / n),
                    format!("{:.4}", rt.phases.compute_s / n),
                    format!("{:.4}", rt.phases.reduce_scatter_s / n),
                    format!("{:.4}", rt.phases.overlap_wait_s / n),
                )
            }
            None => (
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ),
        };
        let mark = if straggler == Some(rt.rank) { "*" } else { "" };
        t.add_row(vec![
            format!("{}{mark}", rt.rank),
            rt.steps.to_string(),
            plan.map(|p| format!("{p:.4}")).unwrap_or_else(|| "-".into()),
            measured,
            skew,
            gather,
            compute,
            rs,
            wait,
        ]);
    }
    t.render()
}

/// Find one sweep cell by (planner, batch).
pub fn find_cell<'a>(
    cells: &'a [SweepCell],
    system: SystemKind,
    batch: usize,
) -> &'a SweepCell {
    cells
        .iter()
        .find(|c| c.planner == system.name() && c.batch == batch)
        .unwrap_or_else(|| {
            panic!("no sweep cell for {} @{batch}", system.name())
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn cells_format() {
        let w = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
            .unwrap();
        assert_eq!(cell(&w, 128, SystemKind::Whale), "OOM");
        let c = cell(&w, 128, SystemKind::Cephalo);
        assert!(c.parse::<f64>().is_ok(), "{c}");
    }

    #[test]
    fn skew_table_flags_straggler_and_handles_idle_ranks() {
        use crate::telemetry::PhaseBreakdown;
        use crate::transport::RankTiming;
        let phases = PhaseBreakdown {
            gather_s: 0.2,
            compute_s: 0.6,
            reduce_scatter_s: 0.2,
            overlap_wait_s: 0.1,
            optimizer_s: 0.05,
        };
        let timings = vec![
            RankTiming { rank: 0, steps: 2, phases, measured_seconds: 2.0 },
            RankTiming { rank: 1, steps: 2, phases, measured_seconds: 3.0 },
            RankTiming {
                rank: 2,
                steps: 0,
                phases: PhaseBreakdown::default(),
                measured_seconds: 0.0,
            },
        ];
        let table = skew_table(&[0.9, 1.0], &timings);
        // Rank 1 is the slowest measured rank -> starred straggler.
        assert!(table.contains("1*"), "{table}");
        assert!(!table.contains("0*"), "{table}");
        // Rank 0: measured mean 1.0 vs planned 0.9 -> +11.1% skew.
        assert!(table.contains("+11.1"), "{table}");
        // Rank 2 never stepped and has no planned entry -> dashes.
        assert!(table.lines().any(|l| {
            l.trim_start().starts_with('2') && l.matches('-').count() >= 6
        }), "{table}");
    }

    #[test]
    fn sweep_cells_match_direct_throughput() {
        let w = Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
            .unwrap();
        let registry = PlannerRegistry::with_defaults();
        let cells = w.sweep(&registry, &[128], None);
        let direct = throughput(&w, 128, SystemKind::FlashFlex).unwrap();
        let from_sweep = find_cell(&cells, SystemKind::FlashFlex, 128)
            .throughput()
            .unwrap();
        assert_eq!(direct, from_sweep);
        assert_eq!(
            outcome_cell(&find_cell(&cells, SystemKind::FlashFlex, 128)
                .result),
            format!("{direct:.2}")
        );
    }
}
