//! Performance modeling (§2.3): per-GPU compute latency and memory
//! models, the NCCL-style collective model with the +15% uneven-input
//! adjustment, the synthetic compute oracle standing in for real GPU
//! profiling, and the profiler that fits everything.

pub mod collective;
pub mod latency;
pub mod oracle;
pub mod profiler;

pub use collective::CollectiveModel;
pub use latency::LatencyModel;
pub use oracle::{ComputeOracle, SyntheticOracle};
pub use profiler::{ClusterPerfProfile, GpuModelSet, Profiler};
