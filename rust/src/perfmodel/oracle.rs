//! Compute oracles: the source of "measured" per-GPU latency/memory.
//!
//! * `SyntheticOracle` — the cluster-simulation stand-in for running
//!   real profiling iterations on the paper's GPUs (see DESIGN.md
//!   §Substitutions): an analytic roofline curve per GPU derived from
//!   the model's FLOPs and the GPU's peak TFLOPs, with a saturating
//!   small-batch efficiency term (reproducing Fig. 5's sublinear ->
//!   linear shape) and deterministic measurement noise.
//! * The trait is also implemented by the real PJRT-backed profiler in
//!   `coordinator::real_profile` for the CPU end-to-end path.
//!
//! The *profiler* samples an oracle at small m and fits linear models;
//! the *simulator* queries the oracle directly as ground truth. The gap
//! between the two is exactly what Fig. 10 (model ARE) measures.

use crate::cluster::Cluster;
use crate::model::TransformerSpec;
use crate::util::prng::Rng;

/// Ground-truth source of per-GPU compute latency and memory.
pub trait ComputeOracle {
    /// Forward latency of ONE transformer layer for a microbatch of m.
    fn fwd_latency(&self, gpu: usize, m: usize) -> f64;
    /// Backward (incl. recompute) latency of one layer for microbatch m.
    fn bwd_latency(&self, gpu: usize, m: usize) -> f64;
    /// Compute memory (bytes) at microbatch m — M_compute in §2.3.
    fn compute_mem(&self, gpu: usize, m: usize) -> f64;
    fn num_gpus(&self) -> usize;
}

/// Analytic per-GPU roofline with saturating efficiency + noise.
#[derive(Debug, Clone)]
pub struct SyntheticOracle {
    /// Peak FLOP/s per GPU slot.
    peak_flops: Vec<f64>,
    /// Microbatch size at which each GPU reaches half efficiency.
    m_half: Vec<f64>,
    /// Achievable fraction of peak at saturation (fp32 transformer).
    pub max_utilization: f64,
    /// Relative measurement noise amplitude.
    pub noise: f64,
    model: TransformerSpec,
    seed: u64,
    /// Fixed memory overhead per GPU (framework + one FSDP unit).
    mem_intercept: f64,
    /// Compute-memory bytes per sample.
    mem_slope: f64,
}

impl SyntheticOracle {
    pub fn new(cluster: &Cluster, model: &TransformerSpec, seed: u64)
        -> SyntheticOracle {
        let gpus = cluster.gpus();
        let peak_flops: Vec<f64> =
            gpus.iter().map(|g| g.spec.flops()).collect();
        // Faster GPUs need more work in flight to saturate: m_half scales
        // ~ sqrt of relative speed (empirically matches Fig. 5's shape).
        let m_half: Vec<f64> = gpus
            .iter()
            .map(|g| 1.5 * (g.spec.tflops_fp32 / 15.0).sqrt().max(0.4))
            .collect();
        // One FSDP unit materialized (params + grads) + framework state.
        let unit_bytes = model.params_per_layer() as f64 * 4.0;
        let mem_intercept = 0.9e9 + 2.0 * unit_bytes;
        // Live working set of one layer's intra-layer activations with
        // checkpointing (one layer live at a time) + margins.
        let mem_slope = model.intra_layer_activation_bytes() * 1.3;
        SyntheticOracle {
            peak_flops,
            m_half,
            max_utilization: 0.42,
            noise: 0.02,
            model: model.clone(),
            seed,
            mem_intercept,
            mem_slope,
        }
    }

    /// Saturating efficiency in (0, 1]: eff(m) = m / (m + m_half).
    fn efficiency(&self, gpu: usize, m: usize) -> f64 {
        let m = m as f64;
        m / (m + self.m_half[gpu])
    }

    /// Deterministic noise in [1-noise, 1+noise] keyed on all inputs.
    fn jitter(&self, gpu: usize, m: usize, salt: u64) -> f64 {
        let mut rng = Rng::new(
            self.seed
                ^ (gpu as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (m as u64).wrapping_mul(0xD1B54A32D192ED03)
                ^ salt,
        );
        1.0 + self.noise * (2.0 * rng.f64() - 1.0)
    }

    pub fn model(&self) -> &TransformerSpec {
        &self.model
    }
}

impl ComputeOracle for SyntheticOracle {
    fn fwd_latency(&self, gpu: usize, m: usize) -> f64 {
        let flops = self.model.layer_fwd_flops(m);
        let achievable = self.peak_flops[gpu]
            * self.max_utilization
            * self.efficiency(gpu, m);
        flops / achievable * self.jitter(gpu, m, 1)
    }

    fn bwd_latency(&self, gpu: usize, m: usize) -> f64 {
        // Backward (2x fwd) + activation recompute (1x fwd) — the paper
        // checkpoints activations at every layer boundary (§4.1).
        let flops = self.model.layer_bwd_flops(m) + self.model.layer_fwd_flops(m);
        let achievable = self.peak_flops[gpu]
            * self.max_utilization
            * self.efficiency(gpu, m);
        flops / achievable * self.jitter(gpu, m, 2)
    }

    fn compute_mem(&self, gpu: usize, m: usize) -> f64 {
        (self.mem_intercept + self.mem_slope * m as f64)
            * self.jitter(gpu, m, 3)
    }

    fn num_gpus(&self) -> usize {
        self.peak_flops.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::find_model;

    fn oracle() -> SyntheticOracle {
        let cluster = Cluster::cluster_a();
        let model = find_model("BERT-Large").unwrap();
        SyntheticOracle::new(&cluster, &model, 42)
    }

    #[test]
    fn deterministic() {
        let a = oracle();
        let b = oracle();
        for gpu in 0..8 {
            for m in 1..10 {
                assert_eq!(a.fwd_latency(gpu, m), b.fwd_latency(gpu, m));
                assert_eq!(a.compute_mem(gpu, m), b.compute_mem(gpu, m));
            }
        }
    }

    #[test]
    fn faster_gpu_is_faster_at_saturation() {
        let o = oracle();
        // GPU 2 is the A6000 (38.7 TF), GPU 7 a P100 (9.3 TF).
        let fast = o.fwd_latency(2, 32);
        let slow = o.fwd_latency(7, 32);
        assert!(
            slow / fast > 2.5,
            "A6000 {fast} vs P100 {slow}: ratio too small"
        );
    }

    #[test]
    fn sublinear_then_linear_shape() {
        // Fig. 5 left: per-sample latency at m=1 much worse than m=8;
        // beyond saturation, near-linear scaling.
        let o = oracle();
        let per1 = o.fwd_latency(0, 1);
        let per8 = o.fwd_latency(0, 8) / 8.0;
        assert!(per1 > 1.3 * per8);
        let t16 = o.fwd_latency(0, 16);
        let t32 = o.fwd_latency(0, 32);
        let ratio = t32 / t16;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bwd_costs_about_3x_fwd() {
        // bwd = 2x fwd + 1x recompute.
        let o = oracle();
        let f = o.fwd_latency(3, 8);
        let b = o.bwd_latency(3, 8);
        let r = b / f;
        assert!((2.7..3.3).contains(&r), "bwd/fwd {r}");
    }

    #[test]
    fn memory_grows_linearly_and_same_across_gpus_modulo_noise() {
        let o = oracle();
        let m1 = o.compute_mem(0, 1);
        let m5 = o.compute_mem(0, 5);
        let m9 = o.compute_mem(0, 9);
        // Differences approximate slope * 4 each.
        let d1 = m5 - m1;
        let d2 = m9 - m5;
        assert!((d1 / d2 - 1.0).abs() < 0.2);
        // Memory is a property of the model, not the GPU (±noise).
        let other = o.compute_mem(5, 5);
        assert!((other / o.compute_mem(0, 5) - 1.0).abs() < 0.1);
    }
}
