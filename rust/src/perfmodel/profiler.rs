//! The profiler (§3.1): samples an oracle at small microbatch sizes
//! (m = 1..=8 suffices per the paper), fits per-GPU latency and memory
//! models, and measures collective latencies — producing the
//! `ClusterPerfProfile` the optimizer plans against.

use crate::cluster::Cluster;
use crate::memory::MemoryModel;
use crate::model::TransformerSpec;
use crate::perfmodel::collective::CollectiveModel;
use crate::perfmodel::latency::LatencyModel;
use crate::perfmodel::oracle::ComputeOracle;

/// Fitted models for one GPU slot.
#[derive(Debug, Clone)]
pub struct GpuModelSet {
    pub fwd: LatencyModel,
    pub bwd: LatencyModel,
    pub mem: MemoryModel,
    /// Physical memory capacity in bytes.
    pub capacity: f64,
}

/// Everything the optimizer needs about a (cluster, model) pair.
#[derive(Debug, Clone)]
pub struct ClusterPerfProfile {
    pub per_gpu: Vec<GpuModelSet>,
    pub collective: CollectiveModel,
    /// Parameters per FSDP unit (one transformer layer).
    pub unit_params: f64,
    /// Total model parameters (incl. embeddings, divided across units
    /// for state accounting).
    pub total_params: f64,
    pub layers: usize,
    pub model_name: String,
    pub seq_len: usize,
}

impl ClusterPerfProfile {
    /// AllGather latency for one FSDP unit's parameters (fp32).
    pub fn unit_allgather(&self) -> f64 {
        self.collective.allgather(self.unit_params * 4.0)
    }

    /// ReduceScatter latency for one unit's gradients (fp32).
    pub fn unit_reduce_scatter(&self) -> f64 {
        self.collective.reduce_scatter(self.unit_params * 4.0)
    }

    pub fn unit_allgather_uneven(&self) -> f64 {
        self.collective.allgather_uneven(self.unit_params * 4.0)
    }

    pub fn unit_reduce_scatter_uneven(&self) -> f64 {
        self.collective.reduce_scatter_uneven(self.unit_params * 4.0)
    }

    /// Per-unit collectives priced for the LOCALITY-ORDERED ring the
    /// runtime actually walks (`transport::collectives::RingOrder`):
    /// one cross-host chunk per NIC per step. Bitwise equal to the
    /// classic bottleneck price — the scattered variants below are the
    /// counterfactual an unordered ring would pay.
    pub fn unit_allgather_ordered(&self) -> f64 {
        self.collective.allgather_ordered(self.unit_params * 4.0)
    }

    pub fn unit_reduce_scatter_ordered(&self) -> f64 {
        self.collective.reduce_scatter_ordered(self.unit_params * 4.0)
    }

    pub fn unit_allgather_scattered(&self) -> f64 {
        self.collective.allgather_scattered(self.unit_params * 4.0)
    }

    pub fn unit_reduce_scatter_scattered(&self) -> f64 {
        self.collective.reduce_scatter_scattered(self.unit_params * 4.0)
    }

    /// Even training-state share per GPU in bytes.
    pub fn even_state_share(&self) -> f64 {
        crate::memory::state_bytes(self.total_params)
            / self.per_gpu.len() as f64
    }

    pub fn num_gpus(&self) -> usize {
        self.per_gpu.len()
    }
}

/// Profiler configuration (§3.1: "B = 8 suffices").
#[derive(Debug, Clone)]
pub struct Profiler {
    pub max_profile_m: usize,
}

impl Default for Profiler {
    fn default() -> Self {
        Self { max_profile_m: 8 }
    }
}

impl Profiler {
    /// Profile a (cluster, model) pair through `oracle`.
    pub fn profile(
        &self,
        cluster: &Cluster,
        model: &TransformerSpec,
        oracle: &dyn ComputeOracle,
    ) -> ClusterPerfProfile {
        assert_eq!(oracle.num_gpus(), cluster.num_gpus());
        let gpus = cluster.gpus();
        let per_gpu = gpus
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                let fwd_samples: Vec<(usize, f64)> = (1..=self.max_profile_m)
                    .map(|m| (m, oracle.fwd_latency(i, m)))
                    .collect();
                let bwd_samples: Vec<(usize, f64)> = (1..=self.max_profile_m)
                    .map(|m| (m, oracle.bwd_latency(i, m)))
                    .collect();
                let mem_samples: Vec<(usize, f64)> = (1..=self.max_profile_m)
                    .map(|m| (m, oracle.compute_mem(i, m)))
                    .collect();
                GpuModelSet {
                    fwd: LatencyModel::fit(&fwd_samples),
                    bwd: LatencyModel::fit(&bwd_samples),
                    mem: MemoryModel::fit(&mem_samples),
                    capacity: slot.spec.mem_bytes(),
                }
            })
            .collect();
        ClusterPerfProfile {
            per_gpu,
            collective: CollectiveModel::from_cluster(cluster),
            unit_params: model.params_per_layer() as f64,
            total_params: model.total_params() as f64,
            layers: model.layers,
            model_name: model.name.clone(),
            seq_len: model.seq_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::find_model;
    use crate::perfmodel::oracle::SyntheticOracle;

    fn profile() -> ClusterPerfProfile {
        let cluster = Cluster::cluster_a();
        let model = find_model("BERT-Large").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &model, 42);
        Profiler::default().profile(&cluster, &model, &oracle)
    }

    #[test]
    fn one_model_set_per_gpu() {
        let p = profile();
        assert_eq!(p.per_gpu.len(), 8);
        assert_eq!(p.layers, 24);
        assert!(p.unit_params > 0.0);
        assert!(p.total_params > p.unit_params * p.layers as f64 * 0.9);
    }

    #[test]
    fn fitted_models_track_oracle_within_noise() {
        let cluster = Cluster::cluster_a();
        let model = find_model("BERT-Large").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &model, 42);
        let p = Profiler::default().profile(&cluster, &model, &oracle);
        // Within the profiled range, exact; beyond it, within ~10%
        // (paper Fig. 10: error < 10%).
        for gpu in [0usize, 2, 5] {
            for m in [12usize, 16, 24, 32] {
                let pred = p.per_gpu[gpu].fwd.predict(m);
                let actual = oracle.fwd_latency(gpu, m);
                let err = ((pred - actual) / actual).abs();
                assert!(
                    err < 0.10,
                    "gpu {gpu} m {m}: pred {pred}, actual {actual}, err {err}"
                );
            }
        }
    }

    #[test]
    fn memory_model_extrapolates() {
        let cluster = Cluster::cluster_a();
        let model = find_model("BERT-Large").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &model, 42);
        let p = Profiler::default().profile(&cluster, &model, &oracle);
        for m in [16usize, 32] {
            let pred = p.per_gpu[0].mem.predict(m);
            let actual = oracle.compute_mem(0, m);
            assert!(((pred - actual) / actual).abs() < 0.08);
        }
    }

    #[test]
    fn capacities_match_specs() {
        let p = profile();
        // GPU 2 in cluster A is the 48 GB A6000.
        assert!((p.per_gpu[2].capacity - 48e9).abs() < 1e6);
        // GPU 6/7 are 12 GB P100s.
        assert!((p.per_gpu[7].capacity - 12e9).abs() < 1e6);
    }

    #[test]
    fn collective_latencies_positive_and_uneven_costlier() {
        let p = profile();
        assert!(p.unit_allgather() > 0.0);
        assert!(p.unit_allgather_uneven() > p.unit_allgather());
        assert!(p.unit_reduce_scatter_uneven() > p.unit_reduce_scatter());
    }

    #[test]
    fn even_state_share() {
        let p = profile();
        let expect = p.total_params * 16.0 / 8.0;
        assert!((p.even_state_share() - expect).abs() < 1.0);
    }
}
