//! NCCL-style collective latency model (§2.3 Communication Latency,
//! Supplementary C / Fig. 12).
//!
//! Ring AllGather / ReduceScatter over N ranks moves (N-1)/N of the
//! collective size through the bottleneck link per rank, plus a per-step
//! latency term. Uneven input sizes (uneven training-state sharding) add
//! a conservative +15% (paper's measured bound), uncorrelated with the
//! skew degree — exactly the model the optimizer assumes.

use crate::cluster::{gbps_to_bytes_per_sec, Cluster};

/// Paper's conservative uneven-input overhead (Supplementary C).
pub const UNEVEN_OVERHEAD: f64 = 0.15;

#[derive(Debug, Clone)]
pub struct CollectiveModel {
    pub ranks: usize,
    /// Bottleneck bus bandwidth in bytes/s for the ring.
    pub bus_bytes_per_sec: f64,
    /// Per-ring-step latency (link latency + kernel launch), seconds.
    pub step_latency_s: f64,
    /// Intra-host link bandwidth in bytes/s (the fabric the runtime's
    /// shm fast path rides); equals `bus_bytes_per_sec` on one host.
    pub intra_bytes_per_sec: f64,
    /// Cross-host link bandwidth in bytes/s; equals `bus_bytes_per_sec`
    /// when the ring spans hosts.
    pub inter_bytes_per_sec: f64,
    /// Distinct hosts on the ring (1 = everything local).
    pub hosts: usize,
}

impl CollectiveModel {
    /// Build from a cluster: the DP ring spans all GPUs, so the
    /// bottleneck is the slowest link on the ring (inter-node if the
    /// cluster has >1 node).
    pub fn from_cluster(cluster: &Cluster) -> CollectiveModel {
        let ranks = cluster.num_gpus();
        let bw = gbps_to_bytes_per_sec(cluster.ring_bw_gbps());
        // Multi-node rings pay NIC/switch latency per step; intra-node
        // rings only kernel-launch + PCIe latency.
        let step = if cluster.nodes.len() > 1 { 20e-6 } else { 6e-6 };
        CollectiveModel {
            ranks,
            bus_bytes_per_sec: bw,
            step_latency_s: step,
            intra_bytes_per_sec: gbps_to_bytes_per_sec(
                cluster.intra_bw_min_gbps(),
            ),
            inter_bytes_per_sec: gbps_to_bytes_per_sec(
                cluster.inter_bw_gbps,
            ),
            hosts: cluster.nodes.len(),
        }
    }

    /// Ring AllGather latency for a collective of `bytes` total
    /// (sum of all input shards).
    pub fn allgather(&self, bytes: f64) -> f64 {
        self.ring_time(bytes)
    }

    /// Ring ReduceScatter latency — same data movement as AllGather.
    pub fn reduce_scatter(&self, bytes: f64) -> f64 {
        self.ring_time(bytes)
    }

    /// AllReduce = ReduceScatter + AllGather.
    pub fn allreduce(&self, bytes: f64) -> f64 {
        self.reduce_scatter(bytes) + self.allgather(bytes)
    }

    /// Uneven-sharding variants (§2.3: +15%).
    pub fn allgather_uneven(&self, bytes: f64) -> f64 {
        self.allgather(bytes) * (1.0 + UNEVEN_OVERHEAD)
    }

    pub fn reduce_scatter_uneven(&self, bytes: f64) -> f64 {
        self.reduce_scatter(bytes) * (1.0 + UNEVEN_OVERHEAD)
    }

    /// Ring time for a LOCALITY-ORDERED ring (the schedule the runtime
    /// walks via `transport::collectives::RingOrder`): hosts are
    /// traversed contiguously, so each host's NIC carries exactly one
    /// outbound chunk per ring step and the bottleneck is the plain
    /// inter-host link — numerically the classic bottleneck model.
    pub fn allgather_ordered(&self, bytes: f64) -> f64 {
        self.ring_time_classed(bytes, 1.0)
    }

    pub fn reduce_scatter_ordered(&self, bytes: f64) -> f64 {
        self.ring_time_classed(bytes, 1.0)
    }

    /// Ring time for a locality-OBLIVIOUS ring in its worst
    /// interleaving: every hop crosses hosts, so each host's NIC is
    /// shared by all `ranks/hosts` of its members' outbound chunks per
    /// step. The ordered/scattered gap is what topology-sorted rings
    /// buy (ISSUE 8); on one host both collapse to the same time.
    pub fn allgather_scattered(&self, bytes: f64) -> f64 {
        self.ring_time_classed(bytes, self.cross_per_host())
    }

    pub fn reduce_scatter_scattered(&self, bytes: f64) -> f64 {
        self.ring_time_classed(bytes, self.cross_per_host())
    }

    /// Outbound cross-host chunks per NIC per step in the worst
    /// (alternating-host) ring order.
    fn cross_per_host(&self) -> f64 {
        (self.ranks as f64 / self.hosts.max(1) as f64).ceil().max(1.0)
    }

    fn ring_time(&self, bytes: f64) -> f64 {
        if self.ranks <= 1 {
            return 0.0;
        }
        let n = self.ranks as f64;
        let steps = n - 1.0;
        steps * self.step_latency_s
            + bytes * (steps / n) / self.bus_bytes_per_sec
    }

    /// Ring time charged by edge class: cross-host hops share each
    /// host's NIC among `cross_per_host` concurrent chunks; intra-host
    /// hops are never the bottleneck (same stance as the classic
    /// model, which prices multi-node rings off the inter-node link
    /// alone). With `cross_per_host` = 1 this is EXACTLY the classic
    /// bottleneck time; with one host there are no cross edges at all.
    fn ring_time_classed(&self, bytes: f64, cross_per_host: f64) -> f64 {
        if self.ranks <= 1 {
            return 0.0;
        }
        let n = self.ranks as f64;
        let steps = n - 1.0;
        let link = if self.hosts > 1 {
            self.inter_bytes_per_sec / cross_per_host.max(1.0)
        } else {
            self.intra_bytes_per_sec
        };
        steps * self.step_latency_s + bytes * (steps / n) / link
    }

    /// Point-to-point transfer time over a link of `gbps`.
    pub fn p2p(bytes: f64, gbps: f64) -> f64 {
        10e-6 + bytes / gbps_to_bytes_per_sec(gbps)
    }
}

/// Input skew: largest input / total input (Fig. 12 bottom x-axis).
pub fn input_skew(shards: &[f64]) -> f64 {
    let total: f64 = shards.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    shards.iter().copied().fold(0.0, f64::max) / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn model() -> CollectiveModel {
        CollectiveModel {
            ranks: 8,
            bus_bytes_per_sec: 6.25e9, // 50 Gbps
            step_latency_s: 20e-6,
            intra_bytes_per_sec: 12.0e9, // 96 Gbps PCIe
            inter_bytes_per_sec: 6.25e9,
            hosts: 2,
        }
    }

    #[test]
    fn latency_scales_with_bytes() {
        let m = model();
        let t1 = m.allgather(100e6);
        let t2 = m.allgather(200e6);
        assert!(t2 > 1.8 * t1 && t2 < 2.2 * t1);
    }

    #[test]
    fn single_rank_is_free() {
        let m = CollectiveModel {
            ranks: 1,
            bus_bytes_per_sec: 1e9,
            step_latency_s: 1e-5,
            intra_bytes_per_sec: 1e9,
            inter_bytes_per_sec: 1e9,
            hosts: 1,
        };
        assert_eq!(m.allgather(1e9), 0.0);
        assert_eq!(m.allreduce(1e9), 0.0);
        assert_eq!(m.allgather_ordered(1e9), 0.0);
        assert_eq!(m.allgather_scattered(1e9), 0.0);
    }

    #[test]
    fn uneven_is_exactly_15_percent_worse() {
        let m = model();
        let even = m.allgather(500e6);
        let uneven = m.allgather_uneven(500e6);
        assert!((uneven / even - 1.15).abs() < 1e-12);
        let rs = m.reduce_scatter(500e6);
        assert!((m.reduce_scatter_uneven(500e6) / rs - 1.15).abs() < 1e-12);
    }

    #[test]
    fn allreduce_is_rs_plus_ag() {
        let m = model();
        let x = 123e6;
        assert!(
            (m.allreduce(x) - m.reduce_scatter(x) - m.allgather(x)).abs()
                < 1e-15
        );
    }

    #[test]
    fn from_cluster_uses_bottleneck_link() {
        let a = Cluster::cluster_a();
        let m = CollectiveModel::from_cluster(&a);
        assert_eq!(m.ranks, 8);
        // Cluster A bottleneck is the 50 Gbps inter-node link.
        assert!((m.bus_bytes_per_sec - 6.25e9).abs() < 1.0);
    }

    #[test]
    fn ring_bandwidth_term_dominates_large_messages() {
        let m = model();
        // 1 GB AllGather: bw term = 1e9 * (7/8) / 6.25e9 = 0.14 s.
        let t = m.allgather(1e9);
        assert!((t - 0.14).abs() / 0.14 < 0.01);
    }

    #[test]
    fn ordered_ring_matches_the_classic_bottleneck_bitwise() {
        // The invariant the DP relies on: charging the locality-ordered
        // schedule changes NO existing number — one cross chunk per NIC
        // per step leaves the plain inter-host link as the bottleneck.
        let m = model();
        for bytes in [1e3, 100e6, 1e9] {
            assert_eq!(
                m.allgather_ordered(bytes).to_bits(),
                m.allgather(bytes).to_bits()
            );
            assert_eq!(
                m.reduce_scatter_ordered(bytes).to_bits(),
                m.reduce_scatter(bytes).to_bits()
            );
        }
        let a = CollectiveModel::from_cluster(&Cluster::cluster_a());
        assert_eq!(a.hosts, 2);
        assert_eq!(
            a.allgather_ordered(500e6).to_bits(),
            a.allgather(500e6).to_bits()
        );
        // Single host: ordered also collapses to the classic time.
        let one = crate::testkit::tiny_cluster();
        let m1 = CollectiveModel::from_cluster(&one);
        assert_eq!(
            m1.allgather_ordered(500e6).to_bits(),
            m1.allgather(500e6).to_bits()
        );
    }

    #[test]
    fn scattered_ring_pays_for_nic_sharing() {
        // 8 ranks on 2 hosts, worst interleaving: 4 outbound cross
        // chunks share each NIC, so the bandwidth term is 4x ordered.
        let m = model();
        let bytes = 1e9;
        let lat = 7.0 * m.step_latency_s;
        let ordered = m.allgather_ordered(bytes) - lat;
        let scattered = m.allgather_scattered(bytes) - lat;
        assert!((scattered / ordered - 4.0).abs() < 1e-9);
        assert!(
            m.reduce_scatter_scattered(bytes)
                > m.reduce_scatter_ordered(bytes)
        );
        // One host: no cross edges, no penalty.
        let local = CollectiveModel { hosts: 1, ..model() };
        assert_eq!(
            local.allgather_scattered(bytes).to_bits(),
            local.allgather_ordered(bytes).to_bits()
        );
    }

    #[test]
    fn from_cluster_splits_edge_classes() {
        let a = CollectiveModel::from_cluster(&Cluster::cluster_a());
        // Cluster A: 96 Gbps slowest PCIe, 50 Gbps inter-node link.
        assert!((a.intra_bytes_per_sec - 12e9).abs() < 1.0);
        assert!((a.inter_bytes_per_sec - 6.25e9).abs() < 1.0);
        assert_eq!(a.inter_bytes_per_sec, a.bus_bytes_per_sec);
    }

    #[test]
    fn skew_metric() {
        assert!((input_skew(&[1.0, 1.0, 1.0, 1.0]) - 0.25).abs() < 1e-12);
        assert!((input_skew(&[4.0, 0.0, 0.0, 0.0]) - 1.0).abs() < 1e-12);
        assert_eq!(input_skew(&[]), 0.0);
    }
}
