//! NCCL-style collective latency model (§2.3 Communication Latency,
//! Supplementary C / Fig. 12).
//!
//! Ring AllGather / ReduceScatter over N ranks moves (N-1)/N of the
//! collective size through the bottleneck link per rank, plus a per-step
//! latency term. Uneven input sizes (uneven training-state sharding) add
//! a conservative +15% (paper's measured bound), uncorrelated with the
//! skew degree — exactly the model the optimizer assumes.

use crate::cluster::{gbps_to_bytes_per_sec, Cluster};

/// Paper's conservative uneven-input overhead (Supplementary C).
pub const UNEVEN_OVERHEAD: f64 = 0.15;

#[derive(Debug, Clone)]
pub struct CollectiveModel {
    pub ranks: usize,
    /// Bottleneck bus bandwidth in bytes/s for the ring.
    pub bus_bytes_per_sec: f64,
    /// Per-ring-step latency (link latency + kernel launch), seconds.
    pub step_latency_s: f64,
}

impl CollectiveModel {
    /// Build from a cluster: the DP ring spans all GPUs, so the
    /// bottleneck is the slowest link on the ring (inter-node if the
    /// cluster has >1 node).
    pub fn from_cluster(cluster: &Cluster) -> CollectiveModel {
        let ranks = cluster.num_gpus();
        let bw = gbps_to_bytes_per_sec(cluster.ring_bw_gbps());
        // Multi-node rings pay NIC/switch latency per step; intra-node
        // rings only kernel-launch + PCIe latency.
        let step = if cluster.nodes.len() > 1 { 20e-6 } else { 6e-6 };
        CollectiveModel { ranks, bus_bytes_per_sec: bw, step_latency_s: step }
    }

    /// Ring AllGather latency for a collective of `bytes` total
    /// (sum of all input shards).
    pub fn allgather(&self, bytes: f64) -> f64 {
        self.ring_time(bytes)
    }

    /// Ring ReduceScatter latency — same data movement as AllGather.
    pub fn reduce_scatter(&self, bytes: f64) -> f64 {
        self.ring_time(bytes)
    }

    /// AllReduce = ReduceScatter + AllGather.
    pub fn allreduce(&self, bytes: f64) -> f64 {
        self.reduce_scatter(bytes) + self.allgather(bytes)
    }

    /// Uneven-sharding variants (§2.3: +15%).
    pub fn allgather_uneven(&self, bytes: f64) -> f64 {
        self.allgather(bytes) * (1.0 + UNEVEN_OVERHEAD)
    }

    pub fn reduce_scatter_uneven(&self, bytes: f64) -> f64 {
        self.reduce_scatter(bytes) * (1.0 + UNEVEN_OVERHEAD)
    }

    fn ring_time(&self, bytes: f64) -> f64 {
        if self.ranks <= 1 {
            return 0.0;
        }
        let n = self.ranks as f64;
        let steps = n - 1.0;
        steps * self.step_latency_s
            + bytes * (steps / n) / self.bus_bytes_per_sec
    }

    /// Point-to-point transfer time over a link of `gbps`.
    pub fn p2p(bytes: f64, gbps: f64) -> f64 {
        10e-6 + bytes / gbps_to_bytes_per_sec(gbps)
    }
}

/// Input skew: largest input / total input (Fig. 12 bottom x-axis).
pub fn input_skew(shards: &[f64]) -> f64 {
    let total: f64 = shards.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    shards.iter().copied().fold(0.0, f64::max) / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn model() -> CollectiveModel {
        CollectiveModel {
            ranks: 8,
            bus_bytes_per_sec: 6.25e9, // 50 Gbps
            step_latency_s: 20e-6,
        }
    }

    #[test]
    fn latency_scales_with_bytes() {
        let m = model();
        let t1 = m.allgather(100e6);
        let t2 = m.allgather(200e6);
        assert!(t2 > 1.8 * t1 && t2 < 2.2 * t1);
    }

    #[test]
    fn single_rank_is_free() {
        let m = CollectiveModel {
            ranks: 1,
            bus_bytes_per_sec: 1e9,
            step_latency_s: 1e-5,
        };
        assert_eq!(m.allgather(1e9), 0.0);
        assert_eq!(m.allreduce(1e9), 0.0);
    }

    #[test]
    fn uneven_is_exactly_15_percent_worse() {
        let m = model();
        let even = m.allgather(500e6);
        let uneven = m.allgather_uneven(500e6);
        assert!((uneven / even - 1.15).abs() < 1e-12);
        let rs = m.reduce_scatter(500e6);
        assert!((m.reduce_scatter_uneven(500e6) / rs - 1.15).abs() < 1e-12);
    }

    #[test]
    fn allreduce_is_rs_plus_ag() {
        let m = model();
        let x = 123e6;
        assert!(
            (m.allreduce(x) - m.reduce_scatter(x) - m.allgather(x)).abs()
                < 1e-15
        );
    }

    #[test]
    fn from_cluster_uses_bottleneck_link() {
        let a = Cluster::cluster_a();
        let m = CollectiveModel::from_cluster(&a);
        assert_eq!(m.ranks, 8);
        // Cluster A bottleneck is the 50 Gbps inter-node link.
        assert!((m.bus_bytes_per_sec - 6.25e9).abs() < 1.0);
    }

    #[test]
    fn ring_bandwidth_term_dominates_large_messages() {
        let m = model();
        // 1 GB AllGather: bw term = 1e9 * (7/8) / 6.25e9 = 0.14 s.
        let t = m.allgather(1e9);
        assert!((t - 0.14).abs() / 0.14 < 0.01);
    }

    #[test]
    fn skew_metric() {
        assert!((input_skew(&[1.0, 1.0, 1.0, 1.0]) - 0.25).abs() < 1e-12);
        assert!((input_skew(&[4.0, 0.0, 0.0, 0.0]) - 1.0).abs() < 1e-12);
        assert_eq!(input_skew(&[]), 0.0);
    }
}
