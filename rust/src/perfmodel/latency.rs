//! Compute latency model (Fig. 5 left): profiled small-batch points to
//! capture sublinear warm-up, linear extrapolation beyond the profiled
//! range once the GPU is saturated.

/// Latency (seconds) of one microbatch as a function of microbatch size.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Profiled latencies for m = 1..=profiled.len().
    profiled: Vec<f64>,
    /// Linear tail fitted on the largest profiled points.
    slope: f64,
    intercept: f64,
}

impl LatencyModel {
    /// Fit from (microbatch, seconds) samples; microbatches must be the
    /// contiguous range 1..=P (the profiler guarantees this).
    pub fn fit(samples: &[(usize, f64)]) -> LatencyModel {
        assert!(samples.len() >= 2, "need >= 2 latency samples");
        for (i, (m, _)) in samples.iter().enumerate() {
            assert_eq!(*m, i + 1, "samples must cover 1..=P contiguously");
        }
        let profiled: Vec<f64> = samples.iter().map(|s| s.1).collect();
        // Fit the tail on the last half of the points, where the GPU is
        // closest to saturation (strongest linear regime).
        let tail_start = samples.len() / 2;
        let tail: Vec<(f64, f64)> = samples[tail_start..]
            .iter()
            .map(|(m, t)| (*m as f64, *t))
            .collect();
        let (slope, intercept) = if tail.len() >= 2 {
            crate::util::stats::linear_fit(&tail)
        } else {
            let (m, t) = samples[samples.len() - 1];
            (t / m as f64, 0.0)
        };
        LatencyModel { profiled, slope: slope.max(0.0), intercept }
    }

    /// Construct directly (tests, analytic baselines).
    pub fn from_line(slope: f64, intercept: f64) -> LatencyModel {
        LatencyModel { profiled: Vec::new(), slope, intercept }
    }

    /// Latency of one microbatch of size m.
    pub fn predict(&self, m: usize) -> f64 {
        assert!(m >= 1, "microbatch must be >= 1");
        if m <= self.profiled.len() {
            self.profiled[m - 1]
        } else {
            (self.slope * m as f64 + self.intercept).max(0.0)
        }
    }

    /// Total latency of `l` microbatches of size m (§2.3: linear scale).
    pub fn total(&self, m: usize, l: usize) -> f64 {
        self.predict(m) * l as f64
    }

    pub fn profiled_range(&self) -> usize {
        self.profiled.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn saturating_curve(m: usize) -> f64 {
        // Latency = flops / (peak * eff(m)), eff = m / (m + 2)
        let work = 10.0 * m as f64;
        let eff = m as f64 / (m as f64 + 2.0);
        work / (100.0 * eff)
    }

    #[test]
    fn profiled_points_are_exact() {
        let samples: Vec<(usize, f64)> =
            (1..=8).map(|m| (m, saturating_curve(m))).collect();
        let model = LatencyModel::fit(&samples);
        for (m, t) in &samples {
            assert_eq!(model.predict(*m), *t);
        }
    }

    #[test]
    fn extrapolation_is_nearly_linear_and_monotonic() {
        let samples: Vec<(usize, f64)> =
            (1..=8).map(|m| (m, saturating_curve(m))).collect();
        let model = LatencyModel::fit(&samples);
        let mut prev = model.predict(8);
        for m in 9..64 {
            let t = model.predict(m);
            assert!(t > prev, "latency must grow with m");
            prev = t;
        }
        // At large m the modeled throughput approaches saturation:
        // true saturated cost is 0.1 s/sample.
        let per_sample = model.predict(256) / 256.0;
        assert!((per_sample - 0.1).abs() / 0.1 < 0.1, "{per_sample}");
    }

    #[test]
    fn sublinearity_captured_at_small_m() {
        let samples: Vec<(usize, f64)> =
            (1..=8).map(|m| (m, saturating_curve(m))).collect();
        let model = LatencyModel::fit(&samples);
        // Latency per sample at m=1 is much worse than at m=8.
        assert!(model.predict(1) / 1.0 > 1.5 * (model.predict(8) / 8.0));
    }

    #[test]
    fn total_scales_by_microbatch_count() {
        let model = LatencyModel::from_line(0.01, 0.005);
        let one = model.predict(4);
        assert!((model.total(4, 8) - 8.0 * one).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_non_contiguous_samples() {
        LatencyModel::fit(&[(1, 0.1), (3, 0.3)]);
    }
}
