//! Execution service: a dedicated thread owning the (non-`Send`) PJRT
//! engine, fronted by cloneable channel-based handles so the trainer's
//! worker threads can submit grad-step requests concurrently.
//!
//! This mirrors the paper's process topology at single-box scale: the
//! leader and N workers coordinate over channels; the "GPU" work funnels
//! through the PJRT device queue (the CPU client parallelizes internally
//! across cores).

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::error::{anyhow, Result};

use super::artifacts::Manifest;
use super::engine::{GradOut, XlaEngine};

enum Request {
    /// Upload parameters to device buffers (once per training step).
    SetParams {
        params: Arc<Vec<Vec<f32>>>,
        reply: mpsc::Sender<Result<()>>,
    },
    GradStep {
        tokens: Vec<i32>,
        targets: Vec<i32>,
        m: usize,
        reply: mpsc::Sender<Result<GradOut>>,
    },
    Loss {
        tokens: Vec<i32>,
        targets: Vec<i32>,
        m: usize,
        reply: mpsc::Sender<Result<(f32, f32)>>,
    },
    Shutdown,
}

/// Owner side: spawns the engine thread; dropping shuts it down.
pub struct ExecService {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
    manifest: Manifest,
    platform: String,
}

/// Cloneable submit handle for worker threads.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Request>,
}

impl ExecService {
    /// Load artifacts from `dir`, compiling `kinds` (e.g. ["grad_step",
    /// "loss"]).
    pub fn start(dir: &Path, kinds: &[&str]) -> Result<ExecService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) =
            mpsc::channel::<Result<(Manifest, String)>>();
        let dir = dir.to_path_buf();
        let kinds: Vec<String> = kinds.iter().map(|s| s.to_string()).collect();
        let join = std::thread::Builder::new()
            .name("xla-exec".into())
            .spawn(move || {
                let kind_refs: Vec<&str> =
                    kinds.iter().map(String::as_str).collect();
                let engine = match XlaEngine::load(&dir, &kind_refs) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok((
                            e.manifest().clone(),
                            e.platform(),
                        )));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::SetParams { params, reply } => {
                            let _ = reply.send(engine.set_params(&params));
                        }
                        Request::GradStep { tokens, targets, m, reply } => {
                            let out = engine.grad_step(&tokens, &targets, m);
                            let _ = reply.send(out);
                        }
                        Request::Loss { tokens, targets, m, reply } => {
                            let out = engine.loss(&tokens, &targets, m);
                            let _ = reply.send(out);
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        let (manifest, platform) = ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(ExecService { tx, join: Some(join), manifest, platform })
    }

    pub fn handle(&self) -> ExecHandle {
        ExecHandle { tx: self.tx.clone() }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecHandle {
    /// Upload parameters to the device (blocking; once per step).
    pub fn set_params(&self, params: Arc<Vec<Vec<f32>>>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::SetParams { params, reply })
            .map_err(|_| anyhow!("exec service gone"))?;
        rx.recv().map_err(|_| anyhow!("exec service dropped reply"))?
    }

    /// Blocking gradient step on the device-resident parameters.
    pub fn grad_step(
        &self,
        tokens: Vec<i32>,
        targets: Vec<i32>,
        m: usize,
    ) -> Result<GradOut> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::GradStep { tokens, targets, m, reply })
            .map_err(|_| anyhow!("exec service gone"))?;
        rx.recv().map_err(|_| anyhow!("exec service dropped reply"))?
    }

    pub fn loss(
        &self,
        tokens: Vec<i32>,
        targets: Vec<i32>,
        m: usize,
    ) -> Result<(f32, f32)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Loss { tokens, targets, m, reply })
            .map_err(|_| anyhow!("exec service gone"))?;
        rx.recv().map_err(|_| anyhow!("exec service dropped reply"))?
    }
}
