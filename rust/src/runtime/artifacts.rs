//! Artifact manifest: the ABI between `python/compile/aot.py` and the
//! Rust runtime. Reads `artifacts/manifest.json` (parameter order,
//! shapes, model config, available entry points).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Model hyperparameters baked into the artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub d_ff: usize,
    pub use_pallas: bool,
    pub num_params: usize,
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub kind: String,
    pub microbatch: usize,
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    /// Parameter names in ABI order.
    pub param_order: Vec<String>,
    /// Shapes per parameter, same order.
    pub param_shapes: Vec<Vec<usize>>,
    pub microbatches: Vec<usize>,
    pub entries: Vec<Entry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let model = j.field("model").map_err(|e| e.to_string())?;
        let get = |k: &str| -> Result<usize, String> {
            model
                .get(k)
                .and_then(Json::as_usize)
                .ok_or(format!("manifest: bad model.{k}"))
        };
        let minfo = ModelInfo {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            seq_len: get("seq_len")?,
            d_ff: get("d_ff")?,
            use_pallas: model
                .get("use_pallas")
                .and_then(Json::as_bool)
                .unwrap_or(true),
            num_params: get("num_params")?,
        };
        let param_order: Vec<String> = j
            .field("param_order")
            .map_err(|e| e.to_string())?
            .as_arr()
            .ok_or("param_order not an array")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let shapes_obj = j.field("param_shapes").map_err(|e| e.to_string())?;
        let mut param_shapes = Vec::with_capacity(param_order.len());
        for name in &param_order {
            let shape: Vec<usize> = shapes_obj
                .get(name)
                .and_then(Json::as_arr)
                .ok_or(format!("missing shape for {name}"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            param_shapes.push(shape);
        }
        let microbatches: Vec<usize> = j
            .field("microbatches")
            .map_err(|e| e.to_string())?
            .as_arr()
            .ok_or("microbatches not an array")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let entries: Vec<Entry> = j
            .field("entries")
            .map_err(|e| e.to_string())?
            .as_arr()
            .ok_or("entries not an array")?
            .iter()
            .map(|e| Entry {
                kind: e
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                microbatch: e
                    .get("microbatch")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
            })
            .collect();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model: minfo,
            param_order,
            param_shapes,
            microbatches,
            entries,
        })
    }

    /// Total parameter count from the shapes (cross-check vs model).
    pub fn param_count(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product::<usize>())
            .sum()
    }

    /// Element count per parameter tensor.
    pub fn param_sizes(&self) -> Vec<usize> {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product::<usize>())
            .collect()
    }

    /// Path to the HLO file for (kind, microbatch), if lowered.
    pub fn entry_path(&self, kind: &str, microbatch: usize)
        -> Option<PathBuf> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.microbatch == microbatch)
            .map(|e| self.dir.join(&e.file))
    }

    /// Greedy decomposition of a batch into available microbatch sizes
    /// (largest first) — used when an assignment's m_i has no compiled
    /// variant.
    pub fn decompose_batch(&self, batch: usize) -> Vec<usize> {
        let mut sizes: Vec<usize> = self.microbatches.clone();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        let mut rest = batch;
        let mut out = Vec::new();
        for &s in &sizes {
            while rest >= s {
                out.push(s);
                rest -= s;
            }
        }
        assert!(
            rest == 0,
            "batch {batch} not representable with microbatches {:?}",
            self.microbatches
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": {"vocab": 64, "d_model": 32, "n_layers": 2, "n_heads": 2,
                  "seq_len": 16, "d_ff": 128, "use_pallas": true,
                  "num_params": 10000},
        "param_order": ["embed", "wq"],
        "param_shapes": {"embed": [64, 32], "wq": [2, 32, 32]},
        "microbatches": [1, 2, 4],
        "entries": [
            {"kind": "grad_step", "microbatch": 1, "file": "grad_step_m1.hlo.txt"},
            {"kind": "loss", "microbatch": 2, "file": "loss_m2.hlo.txt"}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.model.d_model, 32);
        assert_eq!(m.param_order, vec!["embed", "wq"]);
        assert_eq!(m.param_shapes[1], vec![2, 32, 32]);
        assert_eq!(m.param_count(), 64 * 32 + 2 * 32 * 32);
        assert_eq!(
            m.entry_path("grad_step", 1).unwrap(),
            Path::new("/tmp/a/grad_step_m1.hlo.txt")
        );
        assert!(m.entry_path("grad_step", 8).is_none());
    }

    #[test]
    fn decompose_batches() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.decompose_batch(7), vec![4, 2, 1]);
        assert_eq!(m.decompose_batch(4), vec![4]);
        assert_eq!(m.decompose_batch(3), vec![2, 1]);
        assert_eq!(m.decompose_batch(0), Vec::<usize>::new());
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // Integration hook: when `make artifacts` has run, verify the
        // real manifest round-trips.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.param_order.len(), 16);
            assert_eq!(m.param_count(), m.model.num_params);
            for e in &m.entries {
                assert!(m.dir.join(&e.file).exists(), "{} missing", e.file);
            }
        }
    }
}
