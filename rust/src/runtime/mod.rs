//! PJRT runtime: load AOT-compiled JAX computations (HLO text) and
//! execute them from the coordinator's hot path. Python never runs at
//! training time — `make artifacts` is the only python invocation.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod service;

pub use artifacts::{Entry, Manifest, ModelInfo};
#[cfg(feature = "xla")]
pub use engine::{GradOut, XlaEngine};
#[cfg(feature = "xla")]
pub use service::{ExecHandle, ExecService};

use std::path::PathBuf;

/// Default artifacts directory: `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if AOT artifacts are present (tests skip gracefully otherwise,
/// with a loud marker in the output).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
