//! XLA/PJRT execution engine: loads AOT HLO-text artifacts, compiles
//! them on the CPU PJRT client, and executes grad/loss steps.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! `XlaEngine` is intentionally NOT `Send` (the underlying PJRT wrappers
//! hold raw pointers); `service::ExecService` owns one on a dedicated
//! thread and hands out cloneable handles.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{anyhow, Context, Result};

use super::artifacts::Manifest;

/// Outcome of one microbatch gradient step.
#[derive(Debug, Clone)]
pub struct GradOut {
    /// Sum-loss gradients, one flat vector per parameter tensor
    /// (manifest order).
    pub grads: Vec<Vec<f32>>,
    /// Sum of token losses over the microbatch.
    pub loss_sum: f32,
    /// Token count.
    pub token_count: f32,
}

pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Compiled executables keyed by (kind, microbatch).
    executables: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    /// Device-resident parameter buffers, uploaded once per step via
    /// `set_params`. Two birds: (a) the xla crate's literal-input
    /// `execute` path leaks the staged input buffers (~|params| bytes
    /// per call — measured in examples/leak_probe.rs), while
    /// `execute_b` over caller-owned `PjRtBuffer`s frees correctly;
    /// (b) parameters are uploaded once per step instead of once per
    /// microbatch.
    params_device: RefCell<Option<Vec<xla::PjRtBuffer>>>,
}

impl XlaEngine {
    /// Create the engine and eagerly compile the requested entry kinds
    /// for every available microbatch size.
    pub fn load(dir: &Path, kinds: &[&str]) -> Result<XlaEngine> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for entry in manifest.entries.clone() {
            if !kinds.contains(&entry.kind.as_str()) {
                continue;
            }
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.file))?;
            executables.insert((entry.kind.clone(), entry.microbatch), exe);
        }
        Ok(XlaEngine {
            client,
            manifest,
            executables,
            params_device: RefCell::new(None),
        })
    }

    /// Upload the parameter tensors to device buffers (once per step).
    pub fn set_params(&self, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != self.manifest.param_order.len() {
            return Err(anyhow!(
                "expected {} param tensors, got {}",
                self.manifest.param_order.len(),
                params.len()
            ));
        }
        let mut bufs = Vec::with_capacity(params.len());
        for (i, p) in params.iter().enumerate() {
            let shape = &self.manifest.param_shapes[i];
            let expect: usize = shape.iter().product();
            if p.len() != expect {
                return Err(anyhow!(
                    "param {} ({}): {} elements, shape {:?} needs {expect}",
                    i,
                    self.manifest.param_order[i],
                    p.len(),
                    shape
                ));
            }
            bufs.push(self.client.buffer_from_host_buffer(
                p, shape, None,
            )?);
        }
        *self.params_device.borrow_mut() = Some(bufs);
        Ok(())
    }

    fn token_buffer(&self, tokens: &[i32], m: usize)
        -> Result<xla::PjRtBuffer> {
        let seq = self.manifest.model.seq_len;
        if tokens.len() != m * seq {
            return Err(anyhow!(
                "tokens: {} elements, expected {m}x{seq}",
                tokens.len()
            ));
        }
        Ok(self.client.buffer_from_host_buffer(tokens, &[m, seq], None)?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn available(&self, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .keys()
            .filter(|(k, _)| k == kind)
            .map(|(_, m)| *m)
            .collect();
        v.sort_unstable();
        v
    }

    /// One gradient step on a microbatch of size `m` (must have a
    /// compiled variant), using the device-resident parameters from the
    /// last `set_params`. Returns sum-loss gradients.
    pub fn grad_step(
        &self,
        tokens: &[i32],
        targets: &[i32],
        m: usize,
    ) -> Result<GradOut> {
        let exe = self
            .executables
            .get(&("grad_step".to_string(), m))
            .ok_or_else(|| anyhow!("no grad_step variant for m={m}"))?;
        let guard = self.params_device.borrow();
        let pbufs = guard
            .as_ref()
            .ok_or_else(|| anyhow!("set_params not called"))?;
        let tok = self.token_buffer(tokens, m)?;
        let tgt = self.token_buffer(targets, m)?;
        let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
        args.push(&tok);
        args.push(&tgt);
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        let n_params = self.manifest.param_order.len();
        if outs.len() != n_params + 2 {
            return Err(anyhow!(
                "grad_step returned {} outputs, expected {}",
                outs.len(),
                n_params + 2
            ));
        }
        let token_count = outs.pop().unwrap().to_vec::<f32>()?[0];
        let loss_sum = outs.pop().unwrap().to_vec::<f32>()?[0];
        let grads = outs
            .into_iter()
            .map(|l| l.to_vec::<f32>())
            .collect::<std::result::Result<Vec<_>, _>>()?;
        Ok(GradOut { grads, loss_sum, token_count })
    }

    /// Forward-only loss on a microbatch of size `m` (device params).
    pub fn loss(
        &self,
        tokens: &[i32],
        targets: &[i32],
        m: usize,
    ) -> Result<(f32, f32)> {
        let exe = self
            .executables
            .get(&("loss".to_string(), m))
            .ok_or_else(|| anyhow!("no loss variant for m={m}"))?;
        let guard = self.params_device.borrow();
        let pbufs = guard
            .as_ref()
            .ok_or_else(|| anyhow!("set_params not called"))?;
        let tok = self.token_buffer(tokens, m)?;
        let tgt = self.token_buffer(targets, m)?;
        let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
        args.push(&tok);
        args.push(&tgt);
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        let loss_sum = outs[0].to_vec::<f32>()?[0];
        let count = outs[1].to_vec::<f32>()?[0];
        Ok((loss_sum, count))
    }

    /// Single transformer layer forward (the Fig.-5 profiling unit).
    /// `x` is [m, seq, d] flattened; `layer_params` are the 12 unstacked
    /// layer tensors.
    pub fn layer_fwd(
        &self,
        x: &[f32],
        layer_params: &[Vec<f32>],
        layer_shapes: &[Vec<usize>],
        m: usize,
    ) -> Result<Vec<f32>> {
        let exe = self
            .executables
            .get(&("layer_fwd".to_string(), m))
            .ok_or_else(|| anyhow!("no layer_fwd variant for m={m}"))?;
        let seq = self.manifest.model.seq_len;
        let d = self.manifest.model.d_model;
        let mut bufs = vec![self
            .client
            .buffer_from_host_buffer(x, &[m, seq, d], None)?];
        for (p, shape) in layer_params.iter().zip(layer_shapes) {
            bufs.push(self.client.buffer_from_host_buffer(
                p,
                shape.as_slice(),
                None,
            )?);
        }
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Initialize parameters (GPT-2-style) with the repo PRNG; matches
    /// python's shapes, not its exact values (initialization is a
    /// training detail, not part of the numeric-equivalence contract).
    pub fn init_params(&self, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::prng::Rng::new(seed);
        self.manifest
            .param_order
            .iter()
            .zip(&self.manifest.param_shapes)
            .map(|(name, shape)| {
                let nelem: usize = shape.iter().product();
                if name.contains("scale") {
                    vec![1.0; nelem]
                } else if name.contains("bias")
                    || name == "b1"
                    || name == "b2"
                {
                    vec![0.0; nelem]
                } else {
                    let mut v = vec![0f32; nelem];
                    rng.fill_normal(&mut v, 0.02);
                    v
                }
            })
            .collect()
    }
}
