//! GPU memory accounting (§2.3 Memory Utilization Model).
//!
//! Total usage M = M_state + M_compute:
//! * `M_state = 16 bytes/param * |P_i|` — fp32 Adam training state
//!   (4 param + 4 grad + 8 moments), scaled by the GPU's training-state
//!   ratio `r_i`.
//! * `M_compute(m)` — linear in microbatch size (Fig. 5 right): kernel
//!   workspace + live activations + framework overhead.
//!
//! The optimizer caps usable memory at 80% of capacity (§3.2) to avoid
//! allocator thrash near the limit.

/// Bytes of training state per parameter with fp32 Adam (§2.3).
pub const BYTES_PER_PARAM_STATE: f64 = 16.0;

/// The fp32 weight slice of the 16 B/param state (replicated on every
/// rank under leader-resident parameters).
pub const BYTES_PER_PARAM_WEIGHTS: f64 = 4.0;

/// Fraction of physical memory the optimizer will plan into (§3.2).
pub const MEM_UTIL_CAP: f64 = 0.80;

/// Training-state bytes for a parameter count.
pub fn state_bytes(params: f64) -> f64 {
    params * BYTES_PER_PARAM_STATE
}

/// How the fp32 weights are held across ranks — the accounting switch
/// behind the tentpole's "larger models" claim. The gradient + Adam
/// moments (12 B/param) are always sharded by `r_i`; the 4 B/param
/// weights either shard with them (ZeRO-3 style, the paper's §2.3
/// model) or sit replicated on every rank (the historical
/// leader-resident trainer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParamResidency {
    /// Weights shard with the rest of the state: per-GPU state is
    /// `r_i × 16 B/param` and shrinks with `r_i`. This is the paper's
    /// idealized §2.3 model — it does NOT charge the transient
    /// materialization buffer the executor needs while computing.
    #[default]
    FullySharded,
    /// A full fp32 weight copy is resident on every rank: per-GPU state
    /// is `4 B/param + r_i × 12 B/param` — the honest accounting of the
    /// pre-sharding trainer, kept for comparison sweeps.
    LeaderResident,
    /// Honest accounting of the whole-model-gather execution (PR 5):
    /// state shards with `r_i` but every step materializes ALL weights
    /// at once, so each rank transiently carries a full 4 B/param
    /// gather buffer on top of its share.
    WholeModelGather,
    /// FSDP-unit execution: the model is split into `units` parameter
    /// groups and at most two units (the computing one plus the
    /// prefetched one) are materialized at a time, so the transient
    /// peak is `2 × 4 B/param / units` instead of the full copy.
    UnitSharded { units: usize },
}

impl ParamResidency {
    /// Per-GPU bytes that do NOT shrink with `r_i`: the replicated
    /// weight copy under leader residency, the transient gather buffer
    /// under whole-model gather, the double-buffered unit pair under
    /// unit sharding; nothing for the idealized fully-sharded model.
    pub fn fixed_bytes(&self, total_params: f64) -> f64 {
        let weights = total_params * BYTES_PER_PARAM_WEIGHTS;
        match self {
            ParamResidency::FullySharded => 0.0,
            ParamResidency::LeaderResident => weights,
            ParamResidency::WholeModelGather => weights,
            ParamResidency::UnitSharded { units } => {
                2.0 * weights / (*units).max(1) as f64
            }
        }
    }

    /// Total bytes distributed across GPUs by the `r_i` vector.
    pub fn sharded_bytes(&self, total_params: f64) -> f64 {
        match self {
            ParamResidency::FullySharded
            | ParamResidency::WholeModelGather
            | ParamResidency::UnitSharded { .. } => {
                state_bytes(total_params)
            }
            ParamResidency::LeaderResident => {
                state_bytes(total_params)
                    - total_params * BYTES_PER_PARAM_WEIGHTS
            }
        }
    }

    /// Per-GPU training-state bytes for a rank holding ratio `r`.
    pub fn per_gpu_state_bytes(&self, total_params: f64, r: f64) -> f64 {
        self.fixed_bytes(total_params) + r * self.sharded_bytes(total_params)
    }

    /// Per-GPU PEAK parameter (weight) bytes — proportional to `r`
    /// when fully sharded, constant when leader-resident; the
    /// execution-honest modes add their transient materialization
    /// buffer on top of the resident shard.
    pub fn param_bytes(&self, total_params: f64, r: f64) -> f64 {
        let weights = total_params * BYTES_PER_PARAM_WEIGHTS;
        match self {
            ParamResidency::FullySharded => weights * r,
            ParamResidency::LeaderResident => weights,
            ParamResidency::WholeModelGather => weights * r + weights,
            ParamResidency::UnitSharded { units } => {
                weights * r + 2.0 * weights / (*units).max(1) as f64
            }
        }
    }

    /// The FSDP-unit count, when this residency has one.
    pub fn units(&self) -> Option<usize> {
        match self {
            ParamResidency::UnitSharded { units } => Some(*units),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ParamResidency::FullySharded => "sharded",
            ParamResidency::LeaderResident => "leader",
            ParamResidency::WholeModelGather => "gather",
            ParamResidency::UnitSharded { .. } => "unit",
        }
    }
}

/// Usable planning capacity for a GPU.
pub fn usable_capacity(mem_bytes: f64) -> f64 {
    mem_bytes * MEM_UTIL_CAP
}

/// Linear compute-memory model fitted from profiles (Fig. 5 right).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Bytes per sample of microbatch.
    pub slope: f64,
    /// Fixed overhead bytes (framework, one materialized FSDP unit, ...).
    pub intercept: f64,
}

impl MemoryModel {
    pub fn predict(&self, microbatch: usize) -> f64 {
        self.intercept + self.slope * microbatch as f64
    }

    /// Fit from (microbatch, bytes) samples by least squares.
    pub fn fit(samples: &[(usize, f64)]) -> MemoryModel {
        let pts: Vec<(f64, f64)> = samples
            .iter()
            .map(|(m, b)| (*m as f64, *b))
            .collect();
        let (slope, intercept) = crate::util::stats::linear_fit(&pts);
        MemoryModel { slope: slope.max(0.0), intercept: intercept.max(0.0) }
    }

    /// Largest microbatch that fits under `capacity_bytes` alongside
    /// `state` bytes of training state; None if even m=1 does not fit.
    pub fn max_microbatch(&self, capacity_bytes: f64, state: f64)
        -> Option<usize> {
        let budget = capacity_bytes - state - self.intercept;
        if budget < self.slope {
            return None;
        }
        if self.slope <= 0.0 {
            return Some(usize::MAX);
        }
        Some((budget / self.slope).floor() as usize)
    }
}

/// Full per-GPU memory ledger for reports and OOM checks.
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    pub capacity: f64,
    pub state: f64,
    pub compute: f64,
}

impl MemoryLedger {
    pub fn total(&self) -> f64 {
        self.state + self.compute
    }

    pub fn utilization(&self) -> f64 {
        self.total() / self.capacity
    }

    pub fn fits(&self) -> bool {
        self.total() <= usable_capacity(self.capacity)
    }

    pub fn fits_physical(&self) -> bool {
        self.total() <= self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_state_is_16_bytes_per_param() {
        assert_eq!(state_bytes(1e9), 16e9);
    }

    #[test]
    fn residency_accounting_splits_the_16_bytes() {
        let p = 1e9;
        let sh = ParamResidency::FullySharded;
        let ld = ParamResidency::LeaderResident;
        // Fully sharded: everything scales with r.
        assert_eq!(sh.per_gpu_state_bytes(p, 0.25), 4e9);
        assert_eq!(sh.param_bytes(p, 0.25), 1e9);
        assert_eq!(sh.fixed_bytes(p), 0.0);
        // Leader-resident: 4 B/param replicated + 12 B/param sharded.
        assert_eq!(ld.per_gpu_state_bytes(p, 0.25), 4e9 + 3e9);
        assert_eq!(ld.param_bytes(p, 0.25), 4e9);
        assert_eq!(ld.param_bytes(p, 0.0), 4e9);
        // Both modes account the same aggregate state.
        assert_eq!(
            sh.fixed_bytes(p) + sh.sharded_bytes(p),
            ld.fixed_bytes(p) + ld.sharded_bytes(p)
        );
        // A rank with r = 0 holds NOTHING when fully sharded.
        assert_eq!(sh.per_gpu_state_bytes(p, 0.0), 0.0);
        assert!(ld.per_gpu_state_bytes(p, 0.0) > 0.0);
    }

    #[test]
    fn execution_honest_residencies_charge_the_transient_peak() {
        let p = 1e9;
        let gather = ParamResidency::WholeModelGather;
        let unit = ParamResidency::UnitSharded { units: 8 };
        // Whole-model gather: a full 4 B/param buffer on every rank,
        // on top of the r-scaled 16 B/param state.
        assert_eq!(gather.fixed_bytes(p), 4e9);
        assert_eq!(gather.per_gpu_state_bytes(p, 0.25), 4e9 + 4e9);
        assert_eq!(gather.param_bytes(p, 0.25), 4e9 + 1e9);
        // Unit sharding: only a double-buffered unit pair is transient.
        assert_eq!(unit.fixed_bytes(p), 1e9);
        assert_eq!(unit.per_gpu_state_bytes(p, 0.25), 1e9 + 4e9);
        assert_eq!(unit.param_bytes(p, 0.25), 1e9 + 1e9);
        assert_eq!(unit.units(), Some(8));
        assert_eq!(gather.units(), None);
        // More units -> strictly smaller transient peak; the peak
        // approaches the idealized fully-sharded model from above.
        let fine = ParamResidency::UnitSharded { units: 64 };
        assert!(fine.fixed_bytes(p) < unit.fixed_bytes(p));
        assert!(fine.fixed_bytes(p) < gather.fixed_bytes(p));
        assert_eq!(unit.label(), "unit");
        assert_eq!(gather.label(), "gather");
    }

    #[test]
    fn memory_model_fit_recovers_line() {
        let truth = MemoryModel { slope: 50e6, intercept: 1.2e9 };
        let samples: Vec<(usize, f64)> =
            (1..=8).map(|m| (m, truth.predict(m))).collect();
        let fit = MemoryModel::fit(&samples);
        assert!((fit.slope - truth.slope).abs() / truth.slope < 1e-9);
        assert!(
            (fit.intercept - truth.intercept).abs() / truth.intercept < 1e-9
        );
    }

    #[test]
    fn max_microbatch_boundaries() {
        let m = MemoryModel { slope: 1e9, intercept: 2e9 };
        // 16 GB capacity, 6 GB state -> budget 8 GB -> m = 8.
        assert_eq!(m.max_microbatch(16e9, 6e9), Some(8));
        // Exactly one sample fits.
        assert_eq!(m.max_microbatch(3e9 + 1e9, 0.0), Some(2));
        // Nothing fits.
        assert_eq!(m.max_microbatch(2.5e9, 0.0), None);
        assert_eq!(m.max_microbatch(16e9, 15e9), None);
    }

    #[test]
    fn ledger_checks() {
        let l = MemoryLedger { capacity: 10e9, state: 4e9, compute: 3e9 };
        assert!(l.fits()); // 7 <= 8
        assert!((l.utilization() - 0.7).abs() < 1e-12);
        let l2 = MemoryLedger { capacity: 10e9, state: 5e9, compute: 4e9 };
        assert!(!l2.fits()); // 9 > 8
        assert!(l2.fits_physical());
        let l3 = MemoryLedger { capacity: 10e9, state: 8e9, compute: 3e9 };
        assert!(!l3.fits_physical());
    }

    #[test]
    fn fit_clamps_negative() {
        // Degenerate profile data must not produce negative slopes.
        let fit = MemoryModel::fit(&[(1, 5e9), (2, 4.9e9), (3, 5.1e9)]);
        assert!(fit.slope >= 0.0);
        assert!(fit.intercept >= 0.0);
    }
}
