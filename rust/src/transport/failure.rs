//! Heartbeat-fed failure detection for the TCP fabric.
//!
//! A [`FailureDetector`] is pure bookkeeping: reader threads call
//! [`FailureDetector::beat`] whenever any frame (heartbeat or data)
//! arrives from a peer, and [`FailureDetector::mark_closed`] when a
//! stream dies (EOF, reset, CRC failure). Liveness verdicts are then a
//! threshold query over the last-seen clock. The clock is INJECTED
//! (`now_ms` arguments) rather than read from the wall internally, so
//! the suspicion logic is unit-testable without sleeping — the unit
//! tests below are satellite 2 of the fault-model issue: no false
//! positive below the suspicion threshold, guaranteed detection above
//! it.
//!
//! All state is atomic; the detector is shared between the heartbeat
//! thread, the per-stream reader threads and the driver's probe loop
//! behind one `Arc` with no locks.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Default suspicion threshold: a peer silent for this long is
/// suspect. Heartbeats tick every [`crate::transport::tcp`] ~50 ms, so
/// this tolerates ~40 consecutive missed beats — far above scheduler
/// jitter on a loaded CI box.
pub const DEFAULT_SUSPECT_AFTER_MS: u64 = 2000;

struct PeerState {
    /// Milliseconds-clock of the last frame seen from this peer.
    last_seen_ms: AtomicU64,
    /// Hard evidence the peer is gone (EOF / reset / corrupt frame).
    closed: AtomicBool,
}

/// Per-peer liveness bookkeeping (see module docs).
pub struct FailureDetector {
    peers: Vec<PeerState>,
    suspect_after_ms: u64,
}

impl FailureDetector {
    /// A detector over `world` peers, all last-seen at clock 0.
    pub fn new(world: usize, suspect_after_ms: u64) -> Self {
        let peers = (0..world)
            .map(|_| PeerState {
                last_seen_ms: AtomicU64::new(0),
                closed: AtomicBool::new(false),
            })
            .collect();
        Self { peers, suspect_after_ms }
    }

    /// Number of peers being tracked.
    pub fn world(&self) -> usize {
        self.peers.len()
    }

    /// The configured silence threshold, in milliseconds.
    pub fn suspect_after_ms(&self) -> u64 {
        self.suspect_after_ms
    }

    /// Record evidence of life from `peer` at clock `now_ms`. The
    /// clock must be monotone per caller; concurrent beats race
    /// benignly (max of the two survives long enough to matter).
    pub fn beat(&self, peer: usize, now_ms: u64) {
        if let Some(p) = self.peers.get(peer) {
            p.last_seen_ms.fetch_max(now_ms, Ordering::Relaxed);
        }
    }

    /// Record hard evidence that `peer`'s connection is gone. Closed
    /// is sticky: no later beat resurrects the peer (a new incarnation
    /// would need a new mesh, which this fabric does not re-admit —
    /// see DESIGN.md §Fault model).
    pub fn mark_closed(&self, peer: usize) {
        if let Some(p) = self.peers.get(peer) {
            p.closed.store(true, Ordering::Release);
        }
    }

    /// Hard-closed verdict (EOF / reset / corrupt frame observed).
    pub fn is_closed(&self, peer: usize) -> bool {
        self.peers
            .get(peer)
            .map(|p| p.closed.load(Ordering::Acquire))
            .unwrap_or(false)
    }

    /// Suspicion verdict at clock `now_ms`: hard-closed, or silent for
    /// longer than the threshold.
    pub fn suspected(&self, peer: usize, now_ms: u64) -> bool {
        match self.peers.get(peer) {
            None => false,
            Some(p) => {
                p.closed.load(Ordering::Acquire)
                    || now_ms.saturating_sub(
                        p.last_seen_ms.load(Ordering::Relaxed),
                    ) > self.suspect_after_ms
            }
        }
    }

    /// All peers suspected at clock `now_ms`, ascending.
    pub fn suspects(&self, now_ms: u64) -> Vec<usize> {
        (0..self.peers.len())
            .filter(|&p| self.suspected(p, now_ms))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_false_positive_below_the_suspicion_threshold() {
        // Satellite 2a: delays strictly below the threshold never
        // trip the detector, however many of them occur.
        let d = FailureDetector::new(3, 100);
        let mut now = 0u64;
        for _ in 0..50 {
            // Every peer beats, then the clock advances by a delay
            // just inside the bound.
            for p in 0..3 {
                d.beat(p, now);
            }
            now += 100; // elapsed == threshold is NOT "> threshold"
            for p in 0..3 {
                assert!(!d.suspected(p, now), "false positive at {now}");
            }
        }
        assert!(d.suspects(now).is_empty());
    }

    #[test]
    fn silence_beyond_the_threshold_is_always_detected() {
        // Satellite 2b: a peer silent for threshold+1 is suspected no
        // matter how alive it was before.
        let d = FailureDetector::new(4, 100);
        for p in 0..4 {
            d.beat(p, 1000);
        }
        d.beat(2, 1050); // rank 2 stays chatty a little longer
        assert!(!d.suspected(1, 1100));
        assert!(d.suspected(1, 1101), "rank 1 silent 101ms > 100ms");
        assert!(!d.suspected(2, 1101), "rank 2 beat at 1050");
        assert!(d.suspected(2, 1151));
        assert_eq!(d.suspects(1101), vec![0, 1, 3]);
    }

    #[test]
    fn closed_is_sticky_and_immediate() {
        let d = FailureDetector::new(2, 1000);
        d.beat(1, 5);
        assert!(!d.suspected(1, 6));
        d.mark_closed(1);
        assert!(d.is_closed(1));
        assert!(d.suspected(1, 6), "closed trumps a fresh beat");
        d.beat(1, 7); // a late frame cannot resurrect the peer
        assert!(d.suspected(1, 8));
        assert!(!d.is_closed(0));
    }

    #[test]
    fn heartbeat_exactly_at_the_window_edge_is_not_suspect() {
        // Rejoin-issue satellite: the suspicion predicate is a STRICT
        // `elapsed > window`, so a beat landing exactly `window` ms ago
        // keeps the peer alive — the rejoin clock starts one
        // millisecond later, never early.
        let d = FailureDetector::new(2, 200);
        d.beat(1, 1000);
        assert!(!d.suspected(1, 1200), "elapsed == window is alive");
        assert!(d.suspected(1, 1201), "elapsed == window + 1 is suspect");
        // The edge also holds from a zero-clock start (no beat yet).
        assert!(!d.suspected(0, 200));
        assert!(d.suspected(0, 201));
    }

    #[test]
    fn suspicion_clears_when_a_partitioned_peer_beats_again() {
        // Rejoin-issue satellite: soft suspicion is NOT sticky. A peer
        // that went silent past the window (raised) and then resumes
        // beating inside the rejoin retry window drops back to alive —
        // the driver sees no suspect, so no migration is planned. Only
        // hard closure is permanent.
        let d = FailureDetector::new(2, 100);
        d.beat(1, 500);
        assert!(d.suspected(1, 700), "silent 200ms > 100ms window");
        assert_eq!(d.suspects(700), vec![0, 1]);
        d.beat(1, 710); // the partition heals; frames flow again
        assert!(!d.suspected(1, 750), "a resumed beat clears suspicion");
        d.mark_closed(1);
        d.beat(1, 760);
        assert!(d.suspected(1, 770), "closure is the one-way verdict");
    }

    #[test]
    fn beats_are_monotone_under_reordering() {
        // A stale beat (older clock) must not rewind last-seen.
        let d = FailureDetector::new(1, 10);
        d.beat(0, 100);
        d.beat(0, 40); // delivered out of order
        assert!(!d.suspected(0, 105));
        assert!(d.suspected(0, 111));
    }

    #[test]
    fn out_of_range_peers_are_inert() {
        let d = FailureDetector::new(1, 10);
        d.beat(9, 100);
        d.mark_closed(9);
        assert!(!d.is_closed(9));
        assert!(!d.suspected(9, 1000));
    }
}
