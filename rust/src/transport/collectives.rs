//! Segmented ring collectives as actual peer messages (§3.3 over the
//! wire).
//!
//! Same schedule as the in-process `crate::collectives::ring_*` — in
//! step `s` rank `r` forwards segment `(r − s) mod n` (AllGather) or
//! the partial sum of segment `(r − s − 1) mod n` (ReduceScatter) to
//! rank `r + 1` — but executed by each rank against its own
//! [`Transport`] endpoint, N−1 rounds of real sends and receives.
//! Empty segments (`r_i = 0` ranks) are skipped symmetrically on both
//! sides, exactly the zero-byte-chunk behavior of the in-process rings.
//!
//! **Non-blocking rounds.** Each collective is a round-stepped state
//! machine ([`AllGatherOp`], [`ReduceScatterOp`]): `start` captures the
//! inputs, every `step_round` drives exactly ONE ring round (one send +
//! one receive), and `finish` yields the result. Because the op does
//! not own the endpoint, several in-flight ops can interleave their
//! rounds on one endpoint — the FSDP-unit pipeline gathers unit k+1
//! while unit k computes by alternating their rounds. The only rule:
//! every participating rank must drive its in-flight ops in the SAME
//! program order (per-peer message delivery is FIFO, so identical
//! round interleavings match sends to receives; divergent orders would
//! cross-wire payloads). The blocking `ring_*` functions below are
//! start/step-to-completion/finish wrappers and behave exactly as
//! before.
//!
//! **Bitwise contract (DESIGN.md invariant 10).** The ReduceScatter
//! accumulation order around the ring is identical to the in-process
//! implementation's, and AllGather only copies, so for any input these
//! functions produce bit-identical results to `collectives::ring_*` —
//! property-tested over channel and socket fabrics in
//! `tests/transport_parity.rs`. That is what makes a transport backend
//! invisible to the training trajectory. The accumulate kernel runs in
//! fixed-size chunks (a known trip count the compiler can vectorize),
//! which is bitwise-free: the sum is elementwise, so chunking changes
//! no per-element addition order.
//!
//! Collectives are **group-scoped**: the group is
//! `layout.num_ranks()`, which may be smaller than the transport's
//! world (elastic memberships are prefixes of the process world);
//! ranks outside the group must simply not call in.
//!
//! **Ring order.** The ring need not walk rank order: every op takes a
//! [`RingOrder`] — a shared permutation of the group — and steps
//! position-wise around it (successor of the rank at position `p` is
//! the rank at `p + 1`). A locality-sorted order
//! ([`super::topology::HostTopology::ring_order`]) puts same-host
//! ranks adjacent, so only `num_hosts` of the N−1 hops per round cross
//! the slow fabric. The identity order reproduces the classic schedule
//! move for move. Reordering permutes WHICH peer each round talks to,
//! not segment ownership (rank `r` still owns `layout.range(r)`), and
//! it permutes the ReduceScatter accumulation order — bitwise-neutral
//! for training because the native backend's dyadic grid makes f32
//! summation exactly associative (invariant 10 extension, see
//! DESIGN.md §Transport).

use crate::sharding::ShardLayout;
use crate::util::error::{anyhow, Result};

use super::topology::HostTopology;
use super::Transport;

/// A ring traversal order: a permutation of the `n` group ranks,
/// shared by every participant (all ranks must construct the SAME
/// order — it is a pure function of the host map, so no coordination
/// is needed). Position `p`'s successor is position `p + 1 mod n`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RingOrder {
    order: Vec<usize>,
    pos_of: Vec<usize>,
}

impl RingOrder {
    /// Rank order itself — the classic ring.
    pub fn identity(n: usize) -> RingOrder {
        RingOrder::new((0..n).collect())
    }

    /// An explicit permutation of `0..order.len()`.
    pub fn new(order: Vec<usize>) -> RingOrder {
        let n = order.len();
        assert!(n > 0, "ring order must name at least one rank");
        let mut pos_of = vec![usize::MAX; n];
        for (p, &r) in order.iter().enumerate() {
            assert!(
                r < n && pos_of[r] == usize::MAX,
                "ring order {order:?} is not a permutation of 0..{n}"
            );
            pos_of[r] = p;
        }
        RingOrder { order, pos_of }
    }

    /// The locality-sorted order for the first `group` ranks of a
    /// topology: same-host ranks adjacent, `num_hosts` cross edges.
    pub fn from_topology(topo: &HostTopology, group: usize) -> RingOrder {
        RingOrder::new(topo.ring_order(group))
    }

    /// Number of ranks on the ring.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// Whether the ring has no ranks at all.
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Whether this is the classic rank-order ring.
    pub fn is_identity(&self) -> bool {
        self.order.iter().enumerate().all(|(p, &r)| p == r)
    }

    /// The rank sitting at ring position `p`.
    fn at(&self, p: usize) -> usize {
        self.order[p]
    }

    /// The ring position of rank `r`.
    fn pos(&self, r: usize) -> usize {
        self.pos_of[r]
    }
}

fn check_group(t: &dyn Transport, layout: &ShardLayout) -> Result<usize> {
    let n = layout.num_ranks();
    if n == 0 {
        return Err(anyhow!("empty shard layout"));
    }
    if n > t.world_size() {
        return Err(anyhow!(
            "layout wants {n} ranks but the fabric only has {}",
            t.world_size()
        ));
    }
    if t.rank() >= n {
        return Err(anyhow!(
            "rank {} is outside the {n}-rank collective group",
            t.rank()
        ));
    }
    Ok(n)
}

/// Chunk width for the ReduceScatter accumulate kernel: a fixed inner
/// trip count the compiler unrolls and vectorizes. Elementwise adds
/// have no cross-element order, so chunking is bitwise-invisible.
const ADD_CHUNK: usize = 1024;

/// `acc[i] += data[i]`, chunked for autovectorization.
pub(crate) fn add_assign(acc: &mut [f32], data: &[f32]) {
    debug_assert_eq!(acc.len(), data.len());
    let mut a = acc.chunks_exact_mut(ADD_CHUNK);
    let mut d = data.chunks_exact(ADD_CHUNK);
    for (ac, dc) in (&mut a).zip(&mut d) {
        for i in 0..ADD_CHUNK {
            ac[i] += dc[i];
        }
    }
    for (o, v) in a.into_remainder().iter_mut().zip(d.remainder()) {
        *o += v;
    }
}

/// An in-flight ring AllGather. See the module docs for the
/// interleaving contract.
pub struct AllGatherOp {
    layout: ShardLayout,
    buf: Vec<f32>,
    order: RingOrder,
    pos: usize,
    n: usize,
    round: usize,
}

impl AllGatherOp {
    /// Begin an AllGather of this rank's `shard` under `layout`,
    /// walking the classic rank-order ring.
    pub fn start(
        t: &dyn Transport,
        shard: &[f32],
        layout: &ShardLayout,
    ) -> Result<AllGatherOp> {
        AllGatherOp::start_into(t, shard, layout, Vec::new())
    }

    /// [`AllGatherOp::start`] reusing `scratch` as the gather buffer
    /// (resized to `layout.len()`; prior contents are irrelevant —
    /// every live segment is overwritten by the copy-in or a round).
    pub fn start_into(
        t: &dyn Transport,
        shard: &[f32],
        layout: &ShardLayout,
        scratch: Vec<f32>,
    ) -> Result<AllGatherOp> {
        let order = RingOrder::identity(layout.num_ranks().max(1));
        AllGatherOp::start_into_ordered(t, shard, layout, scratch, &order)
    }

    /// [`AllGatherOp::start_into`] walking `order` instead of rank
    /// order. Every participating rank must pass the same order.
    pub fn start_into_ordered(
        t: &dyn Transport,
        shard: &[f32],
        layout: &ShardLayout,
        mut scratch: Vec<f32>,
        order: &RingOrder,
    ) -> Result<AllGatherOp> {
        let n = check_group(t, layout)?;
        let me = t.rank();
        if order.len() != n {
            return Err(anyhow!(
                "ring order names {} ranks, layout has {n}",
                order.len()
            ));
        }
        if shard.len() != layout.size(me) {
            return Err(anyhow!(
                "rank {me} shard holds {} elems, layout wants {}",
                shard.len(),
                layout.size(me)
            ));
        }
        scratch.resize(layout.len(), 0.0);
        scratch[layout.range(me)].copy_from_slice(shard);
        Ok(AllGatherOp {
            layout: layout.clone(),
            buf: scratch,
            order: order.clone(),
            pos: order.pos(me),
            n,
            round: 0,
        })
    }

    /// All N−1 rounds driven?
    pub fn is_done(&self) -> bool {
        self.round + 1 >= self.n
    }

    /// Drive one ring round (one send + one receive). Returns whether
    /// the op is now complete; calling on a complete op is a no-op.
    pub fn step_round(&mut self, t: &mut dyn Transport) -> Result<bool> {
        if self.is_done() {
            return Ok(true);
        }
        let (n, p, s) = (self.n, self.pos, self.round);
        let next = self.order.at((p + 1) % n);
        let prev = self.order.at((p + n - 1) % n);
        // Send the segment received last round (own segment at s = 0)…
        let send_range = self.layout.range(self.order.at((p + n - s) % n));
        if !send_range.is_empty() {
            t.send_f32(next, &self.buf[send_range])?;
        }
        // …and take delivery of the predecessor's forward.
        let recv_range =
            self.layout.range(self.order.at((p + 2 * n - 1 - s) % n));
        if !recv_range.is_empty() {
            let data = t.recv_f32(prev)?;
            if data.len() != recv_range.len() {
                return Err(anyhow!(
                    "allgather step {s}: rank {prev} sent {} elems for a \
                     {}-elem segment",
                    data.len(),
                    recv_range.len()
                ));
            }
            self.buf[recv_range].copy_from_slice(&data);
        }
        self.round += 1;
        Ok(self.is_done())
    }

    /// The gathered full vector (identical on every participating
    /// rank). Errors if rounds are still outstanding.
    pub fn finish(self) -> Result<Vec<f32>> {
        if !self.is_done() {
            return Err(anyhow!(
                "allgather finished with {} of {} rounds undriven",
                self.n - 1 - self.round,
                self.n - 1
            ));
        }
        Ok(self.buf)
    }
}

/// An in-flight ring ReduceScatter. See the module docs for the
/// interleaving contract.
pub struct ReduceScatterOp {
    layout: ShardLayout,
    acc: Vec<f32>,
    me: usize,
    order: RingOrder,
    pos: usize,
    n: usize,
    round: usize,
}

impl ReduceScatterOp {
    /// Begin a ReduceScatter of this rank's full-length contribution,
    /// walking the classic rank-order ring.
    pub fn start(
        t: &dyn Transport,
        full: &[f32],
        layout: &ShardLayout,
    ) -> Result<ReduceScatterOp> {
        let order = RingOrder::identity(layout.num_ranks().max(1));
        ReduceScatterOp::start_ordered(t, full, layout, &order)
    }

    /// [`ReduceScatterOp::start`] walking `order` instead of rank
    /// order. NOTE: the accumulation order around the ring follows the
    /// traversal, so a non-identity order is only bitwise-neutral on
    /// exactly-associative data (the dyadic grid — see module docs).
    pub fn start_ordered(
        t: &dyn Transport,
        full: &[f32],
        layout: &ShardLayout,
        order: &RingOrder,
    ) -> Result<ReduceScatterOp> {
        let n = check_group(t, layout)?;
        let me = t.rank();
        if order.len() != n {
            return Err(anyhow!(
                "ring order names {} ranks, layout has {n}",
                order.len()
            ));
        }
        if full.len() != layout.len() {
            return Err(anyhow!(
                "rank {me} contribution holds {} elems, layout wants {}",
                full.len(),
                layout.len()
            ));
        }
        Ok(ReduceScatterOp {
            layout: layout.clone(),
            acc: full.to_vec(),
            me,
            order: order.clone(),
            pos: order.pos(me),
            n,
            round: 0,
        })
    }

    /// All N−1 rounds driven?
    pub fn is_done(&self) -> bool {
        self.round + 1 >= self.n
    }

    /// Drive one ring round (one send + one accumulate). Returns
    /// whether the op is now complete; calling on a complete op is a
    /// no-op.
    pub fn step_round(&mut self, t: &mut dyn Transport) -> Result<bool> {
        if self.is_done() {
            return Ok(true);
        }
        let (n, p, s) = (self.n, self.pos, self.round);
        let next = self.order.at((p + 1) % n);
        let prev = self.order.at((p + n - 1) % n);
        // Forward the partial sum accumulated so far for the segment
        // at ring position (p − s − 1); the one received at step s − 1.
        let send_range =
            self.layout.range(self.order.at((p + 2 * n - s - 1) % n));
        if !send_range.is_empty() {
            t.send_f32(next, &self.acc[send_range])?;
        }
        // Accumulate the predecessor's partial into ours — the SAME
        // `*o += v` order as the in-process ring, so sums are bitwise
        // identical (on an identity order; see `start_ordered`).
        let recv_range =
            self.layout.range(self.order.at((p + 2 * n - s - 2) % n));
        if !recv_range.is_empty() {
            let data = t.recv_f32(prev)?;
            if data.len() != recv_range.len() {
                return Err(anyhow!(
                    "reduce-scatter step {s}: rank {prev} sent {} elems \
                     for a {}-elem segment",
                    data.len(),
                    recv_range.len()
                ));
            }
            add_assign(&mut self.acc[recv_range], &data);
        }
        self.round += 1;
        Ok(self.is_done())
    }

    /// This rank's segment of the element-wise sum. Errors if rounds
    /// are still outstanding.
    pub fn finish(self) -> Result<Vec<f32>> {
        if !self.is_done() {
            return Err(anyhow!(
                "reduce-scatter finished with {} of {} rounds undriven",
                self.n - 1 - self.round,
                self.n - 1
            ));
        }
        Ok(self.acc[self.layout.range(self.me)].to_vec())
    }
}

/// Ring AllGather: `shard` is this rank's segment; returns the full
/// vector (identical on every participating rank). Blocking wrapper
/// over [`AllGatherOp`].
pub fn ring_allgather(
    t: &mut dyn Transport,
    shard: &[f32],
    layout: &ShardLayout,
) -> Result<Vec<f32>> {
    let mut op = AllGatherOp::start(t, shard, layout)?;
    while !op.step_round(t)? {}
    op.finish()
}

/// Ring ReduceScatter: `full` is this rank's full-length contribution;
/// returns this rank's segment of the element-wise sum. Blocking
/// wrapper over [`ReduceScatterOp`].
pub fn ring_reduce_scatter(
    t: &mut dyn Transport,
    full: &[f32],
    layout: &ShardLayout,
) -> Result<Vec<f32>> {
    let mut op = ReduceScatterOp::start(t, full, layout)?;
    while !op.step_round(t)? {}
    op.finish()
}

/// [`ring_allgather`] walking an explicit ring order (every rank must
/// pass the same one).
pub fn ring_allgather_ordered(
    t: &mut dyn Transport,
    shard: &[f32],
    layout: &ShardLayout,
    order: &RingOrder,
) -> Result<Vec<f32>> {
    let mut op =
        AllGatherOp::start_into_ordered(t, shard, layout, Vec::new(), order)?;
    while !op.step_round(t)? {}
    op.finish()
}

/// [`ring_reduce_scatter`] walking an explicit ring order (every rank
/// must pass the same one; see [`ReduceScatterOp::start_ordered`] for
/// the associativity caveat).
pub fn ring_reduce_scatter_ordered(
    t: &mut dyn Transport,
    full: &[f32],
    layout: &ShardLayout,
    order: &RingOrder,
) -> Result<Vec<f32>> {
    let mut op = ReduceScatterOp::start_ordered(t, full, layout, order)?;
    while !op.step_round(t)? {}
    op.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives as inproc;
    use crate::transport::LocalFabric;
    use crate::transport::Transport;

    /// Run a closure per rank over a fresh local fabric, returning the
    /// per-rank results in rank order.
    fn on_fabric<T: Send>(
        world: usize,
        f: impl Fn(&mut dyn Transport) -> T + Sync,
    ) -> Vec<T> {
        let eps = LocalFabric::new(world);
        std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    let f = &f;
                    s.spawn(move || f(&mut ep))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn allgather_matches_inprocess_on_uneven_layout() {
        let layout = ShardLayout::by_ratios(10, &[0.5, 0.0, 0.3, 0.2]);
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..layout.size(r)).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let expect = inproc::ring_allgather(&shards, &layout);
        let got = on_fabric(4, |t| {
            ring_allgather(t, &shards[t.rank()], &layout).unwrap()
        });
        for g in got {
            assert_eq!(g, expect);
        }
    }

    #[test]
    fn reduce_scatter_matches_inprocess_bitwise() {
        let layout = ShardLayout::by_ratios(9, &[0.2, 0.5, 0.3]);
        let full: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..9).map(|i| 0.1 * (r as f32 + 1.0) * i as f32).collect())
            .collect();
        let expect = inproc::ring_reduce_scatter(&full, &layout);
        let got = on_fabric(3, |t| {
            ring_reduce_scatter(t, &full[t.rank()], &layout).unwrap()
        });
        for (rank, (e, g)) in expect.iter().zip(&got).enumerate() {
            let eb: Vec<u32> = e.iter().map(|x| x.to_bits()).collect();
            let gb: Vec<u32> = g.iter().map(|x| x.to_bits()).collect();
            assert_eq!(eb, gb, "rank {rank} sum differs bitwise");
        }
    }

    #[test]
    fn single_rank_group_is_a_local_noop() {
        let layout = ShardLayout::by_ratios(5, &[1.0]);
        let shard: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let got = on_fabric(1, |t| {
            let ag = ring_allgather(t, &shard, &layout).unwrap();
            let rs = ring_reduce_scatter(t, &shard, &layout).unwrap();
            (ag, rs)
        });
        assert_eq!(got[0].0, shard);
        assert_eq!(got[0].1, shard);
    }

    #[test]
    fn group_can_be_smaller_than_the_world() {
        // 4-rank fabric, 2-rank collective group: ranks 2 and 3 sit
        // out; the group result matches the in-process reference.
        let layout = ShardLayout::by_ratios(6, &[0.5, 0.5]);
        let shards = [vec![1f32, 2., 3.], vec![4f32, 5., 6.]];
        let expect = inproc::ring_allgather(
            &[shards[0].clone(), shards[1].clone()],
            &layout,
        );
        let got = on_fabric(4, |t| {
            if t.rank() < 2 {
                Some(ring_allgather(t, &shards[t.rank()], &layout).unwrap())
            } else {
                // Outside the group: calling in is an error, not UB.
                assert!(ring_allgather(t, &[], &layout).is_err());
                None
            }
        });
        assert_eq!(got[0].as_ref().unwrap(), &expect);
        assert_eq!(got[1].as_ref().unwrap(), &expect);
    }

    #[test]
    fn size_mismatches_are_rejected() {
        let layout = ShardLayout::by_ratios(4, &[0.5, 0.5]);
        let got = on_fabric(2, |t| {
            let bad_shard = ring_allgather(t, &[1.0], &layout).is_err();
            let bad_full = ring_reduce_scatter(t, &[1.0], &layout).is_err();
            (bad_shard, bad_full)
        });
        assert!(got.iter().all(|&(a, b)| a && b));
    }

    #[test]
    fn identity_order_is_the_classic_schedule() {
        assert!(RingOrder::identity(4).is_identity());
        assert!(!RingOrder::new(vec![0, 2, 1]).is_identity());
        let layout = ShardLayout::by_ratios(10, &[0.5, 0.0, 0.3, 0.2]);
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..layout.size(r)).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let expect = inproc::ring_allgather(&shards, &layout);
        let order = RingOrder::identity(4);
        let got = on_fabric(4, |t| {
            ring_allgather_ordered(t, &shards[t.rank()], &layout, &order)
                .unwrap()
        });
        for g in got {
            assert_eq!(g, expect);
        }
    }

    #[test]
    fn reordered_ring_gathers_and_reduces_the_same_values() {
        // A locality-style permutation: the gathered vector is
        // identical bitwise (AllGather only copies), and the RS sums
        // match bitwise on exactly-summable (integer-valued) data —
        // the dyadic-grid argument for locality reordering.
        let layout = ShardLayout::by_ratios(11, &[0.3, 0.2, 0.0, 0.3, 0.2]);
        let order = RingOrder::new(vec![0, 2, 4, 1, 3]);
        let shards: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..layout.size(r)).map(|i| (r * 50 + i) as f32).collect())
            .collect();
        let fulls: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..11).map(|i| (r * 13 + i) as f32).collect())
            .collect();
        let expect_ag = inproc::ring_allgather(&shards, &layout);
        let expect_rs = inproc::ring_reduce_scatter(&fulls, &layout);
        let got = on_fabric(5, |t| {
            let ag = ring_allgather_ordered(
                t,
                &shards[t.rank()],
                &layout,
                &order,
            )
            .unwrap();
            let rs =
                ring_reduce_scatter_ordered(t, &fulls[t.rank()], &layout, &order)
                    .unwrap();
            (ag, rs)
        });
        for (rank, (ag, rs)) in got.iter().enumerate() {
            let ab: Vec<u32> = ag.iter().map(|x| x.to_bits()).collect();
            let eb: Vec<u32> = expect_ag.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, eb, "rank {rank} AG diverged under reorder");
            let rb: Vec<u32> = rs.iter().map(|x| x.to_bits()).collect();
            let xb: Vec<u32> =
                expect_rs[rank].iter().map(|x| x.to_bits()).collect();
            assert_eq!(rb, xb, "rank {rank} RS diverged under reorder");
        }
    }

    #[test]
    fn order_shape_mismatch_and_bad_permutations_are_rejected() {
        let layout = ShardLayout::by_ratios(4, &[0.5, 0.5]);
        let order = RingOrder::identity(3);
        let got = on_fabric(2, |t| {
            let shard = vec![0.0f32; layout.size(t.rank())];
            ring_allgather_ordered(t, &shard, &layout, &order).is_err()
        });
        assert!(got.iter().all(|&e| e));
        let dup = std::panic::catch_unwind(|| RingOrder::new(vec![0, 0, 2]));
        assert!(dup.is_err(), "duplicate ranks must be rejected");
    }

    #[test]
    fn chunked_add_matches_scalar_add_bitwise() {
        // Odd length crossing several chunk boundaries.
        let n = ADD_CHUNK * 3 + 37;
        let mut acc: Vec<f32> =
            (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let data: Vec<f32> =
            (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut scalar = acc.clone();
        for (o, v) in scalar.iter_mut().zip(&data) {
            *o += v;
        }
        add_assign(&mut acc, &data);
        let ab: Vec<u32> = acc.iter().map(|x| x.to_bits()).collect();
        let sb: Vec<u32> = scalar.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, sb);
    }

    #[test]
    fn two_ops_interleave_rounds_on_one_endpoint() {
        // The overlap substrate: an AllGather (next unit's weights)
        // and a ReduceScatter (previous unit's grads) run round-by-
        // round interleaved on the SAME endpoint, and both match the
        // in-process references bitwise. Every rank drives the two ops
        // in the same program order, which is the whole contract.
        let la = ShardLayout::by_ratios(10, &[0.5, 0.0, 0.3, 0.2]);
        let lb = ShardLayout::by_ratios(13, &[0.25, 0.25, 0.25, 0.25]);
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..la.size(r)).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let fulls: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..13).map(|i| 0.25 * (r * 7 + i) as f32).collect())
            .collect();
        let expect_ag = inproc::ring_allgather(&shards, &la);
        let expect_rs = inproc::ring_reduce_scatter(&fulls, &lb);
        let got = on_fabric(4, |t| {
            let mut ag = AllGatherOp::start(t, &shards[t.rank()], &la).unwrap();
            let mut rs =
                ReduceScatterOp::start(t, &fulls[t.rank()], &lb).unwrap();
            // Alternate single rounds until both complete.
            loop {
                let a = ag.step_round(t).unwrap();
                let b = rs.step_round(t).unwrap();
                if a && b {
                    break;
                }
            }
            (ag.finish().unwrap(), rs.finish().unwrap())
        });
        for (rank, (ag, rs)) in got.iter().enumerate() {
            assert_eq!(ag, &expect_ag, "rank {rank} AG diverged");
            assert_eq!(rs, &expect_rs[rank], "rank {rank} RS diverged");
        }
    }

    #[test]
    fn unfinished_ops_refuse_to_finish() {
        let layout = ShardLayout::by_ratios(6, &[0.5, 0.5]);
        let shards = [vec![1f32, 2., 3.], vec![4f32, 5., 6.]];
        let got = on_fabric(2, |t| {
            let op = AllGatherOp::start(t, &shards[t.rank()], &layout).unwrap();
            let premature = op.finish().is_err();
            // Drain the ring properly so the peer is not left hanging.
            let full =
                ring_allgather(t, &shards[t.rank()], &layout).unwrap();
            (premature, full)
        });
        assert!(got.iter().all(|(p, _)| *p));
        assert_eq!(got[0].1, vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn scratch_reuse_overwrites_stale_contents() {
        let layout = ShardLayout::by_ratios(6, &[0.5, 0.5]);
        let shards = [vec![1f32, 2., 3.], vec![4f32, 5., 6.]];
        let got = on_fabric(2, |t| {
            // Poisoned oversized scratch: result must not see it.
            let scratch = vec![f32::NAN; 64];
            let mut op = AllGatherOp::start_into(
                t,
                &shards[t.rank()],
                &layout,
                scratch,
            )
            .unwrap();
            while !op.step_round(t).unwrap() {}
            op.finish().unwrap()
        });
        assert_eq!(got[0], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(got[1], got[0]);
    }
}
