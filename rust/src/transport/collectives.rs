//! Segmented ring collectives as actual peer messages (§3.3 over the
//! wire).
//!
//! Same schedule as the in-process `crate::collectives::ring_*` — in
//! step `s` rank `r` forwards segment `(r − s) mod n` (AllGather) or
//! the partial sum of segment `(r − s − 1) mod n` (ReduceScatter) to
//! rank `r + 1` — but executed by each rank against its own
//! [`Transport`] endpoint, N−1 rounds of real sends and receives.
//! Empty segments (`r_i = 0` ranks) are skipped symmetrically on both
//! sides, exactly the zero-byte-chunk behavior of the in-process rings.
//!
//! **Bitwise contract (DESIGN.md invariant 10).** The ReduceScatter
//! accumulation order around the ring is identical to the in-process
//! implementation's, and AllGather only copies, so for any input these
//! functions produce bit-identical results to `collectives::ring_*` —
//! property-tested over channel and socket fabrics in
//! `tests/transport_parity.rs`. That is what makes a transport backend
//! invisible to the training trajectory.
//!
//! Collectives are **group-scoped**: the group is
//! `layout.num_ranks()`, which may be smaller than the transport's
//! world (elastic memberships are prefixes of the process world);
//! ranks outside the group must simply not call in.

use crate::sharding::ShardLayout;
use crate::util::error::{anyhow, Result};

use super::Transport;

fn check_group(t: &dyn Transport, layout: &ShardLayout) -> Result<usize> {
    let n = layout.num_ranks();
    if n == 0 {
        return Err(anyhow!("empty shard layout"));
    }
    if n > t.world_size() {
        return Err(anyhow!(
            "layout wants {n} ranks but the fabric only has {}",
            t.world_size()
        ));
    }
    if t.rank() >= n {
        return Err(anyhow!(
            "rank {} is outside the {n}-rank collective group",
            t.rank()
        ));
    }
    Ok(n)
}

/// Ring AllGather: `shard` is this rank's segment; returns the full
/// vector (identical on every participating rank).
pub fn ring_allgather(
    t: &mut dyn Transport,
    shard: &[f32],
    layout: &ShardLayout,
) -> Result<Vec<f32>> {
    let n = check_group(t, layout)?;
    let me = t.rank();
    if shard.len() != layout.size(me) {
        return Err(anyhow!(
            "rank {me} shard holds {} elems, layout wants {}",
            shard.len(),
            layout.size(me)
        ));
    }
    let mut buf = vec![0f32; layout.len()];
    buf[layout.range(me)].copy_from_slice(shard);
    if n == 1 {
        return Ok(buf);
    }
    let next = (me + 1) % n;
    let prev = (me + n - 1) % n;
    for s in 0..n - 1 {
        // Send the segment received last step (own segment at s = 0)…
        let seg_send = (me + n - s) % n;
        let send_range = layout.range(seg_send);
        if !send_range.is_empty() {
            t.send_f32(next, &buf[send_range])?;
        }
        // …and take delivery of the predecessor's forward.
        let seg_recv = (me + 2 * n - 1 - s) % n;
        let recv_range = layout.range(seg_recv);
        if !recv_range.is_empty() {
            let data = t.recv_f32(prev)?;
            if data.len() != recv_range.len() {
                return Err(anyhow!(
                    "allgather step {s}: rank {prev} sent {} elems for a \
                     {}-elem segment",
                    data.len(),
                    recv_range.len()
                ));
            }
            buf[recv_range].copy_from_slice(&data);
        }
    }
    Ok(buf)
}

/// Ring ReduceScatter: `full` is this rank's full-length contribution;
/// returns this rank's segment of the element-wise sum.
pub fn ring_reduce_scatter(
    t: &mut dyn Transport,
    full: &[f32],
    layout: &ShardLayout,
) -> Result<Vec<f32>> {
    let n = check_group(t, layout)?;
    let me = t.rank();
    if full.len() != layout.len() {
        return Err(anyhow!(
            "rank {me} contribution holds {} elems, layout wants {}",
            full.len(),
            layout.len()
        ));
    }
    let mut acc = full.to_vec();
    if n == 1 {
        return Ok(acc);
    }
    let next = (me + 1) % n;
    let prev = (me + n - 1) % n;
    for s in 0..n - 1 {
        // Forward the partial sum accumulated so far for segment
        // (me − s − 1) mod n; the segment received at step s − 1.
        let seg_send = (me + 2 * n - s - 1) % n;
        let send_range = layout.range(seg_send);
        if !send_range.is_empty() {
            t.send_f32(next, &acc[send_range])?;
        }
        // Accumulate the predecessor's partial into ours — the SAME
        // `*o += v` order as the in-process ring, so sums are bitwise
        // identical.
        let seg_recv = (me + 2 * n - s - 2) % n;
        let recv_range = layout.range(seg_recv);
        if !recv_range.is_empty() {
            let data = t.recv_f32(prev)?;
            if data.len() != recv_range.len() {
                return Err(anyhow!(
                    "reduce-scatter step {s}: rank {prev} sent {} elems \
                     for a {}-elem segment",
                    data.len(),
                    recv_range.len()
                ));
            }
            for (o, v) in acc[recv_range].iter_mut().zip(&data) {
                *o += v;
            }
        }
    }
    Ok(acc[layout.range(me)].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives as inproc;
    use crate::transport::LocalFabric;
    use crate::transport::Transport;

    /// Run a closure per rank over a fresh local fabric, returning the
    /// per-rank results in rank order.
    fn on_fabric<T: Send>(
        world: usize,
        f: impl Fn(&mut dyn Transport) -> T + Sync,
    ) -> Vec<T> {
        let eps = LocalFabric::new(world);
        std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    let f = &f;
                    s.spawn(move || f(&mut ep))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn allgather_matches_inprocess_on_uneven_layout() {
        let layout = ShardLayout::by_ratios(10, &[0.5, 0.0, 0.3, 0.2]);
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..layout.size(r)).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let expect = inproc::ring_allgather(&shards, &layout);
        let got = on_fabric(4, |t| {
            ring_allgather(t, &shards[t.rank()], &layout).unwrap()
        });
        for g in got {
            assert_eq!(g, expect);
        }
    }

    #[test]
    fn reduce_scatter_matches_inprocess_bitwise() {
        let layout = ShardLayout::by_ratios(9, &[0.2, 0.5, 0.3]);
        let full: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..9).map(|i| 0.1 * (r as f32 + 1.0) * i as f32).collect())
            .collect();
        let expect = inproc::ring_reduce_scatter(&full, &layout);
        let got = on_fabric(3, |t| {
            ring_reduce_scatter(t, &full[t.rank()], &layout).unwrap()
        });
        for (rank, (e, g)) in expect.iter().zip(&got).enumerate() {
            let eb: Vec<u32> = e.iter().map(|x| x.to_bits()).collect();
            let gb: Vec<u32> = g.iter().map(|x| x.to_bits()).collect();
            assert_eq!(eb, gb, "rank {rank} sum differs bitwise");
        }
    }

    #[test]
    fn single_rank_group_is_a_local_noop() {
        let layout = ShardLayout::by_ratios(5, &[1.0]);
        let shard: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let got = on_fabric(1, |t| {
            let ag = ring_allgather(t, &shard, &layout).unwrap();
            let rs = ring_reduce_scatter(t, &shard, &layout).unwrap();
            (ag, rs)
        });
        assert_eq!(got[0].0, shard);
        assert_eq!(got[0].1, shard);
    }

    #[test]
    fn group_can_be_smaller_than_the_world() {
        // 4-rank fabric, 2-rank collective group: ranks 2 and 3 sit
        // out; the group result matches the in-process reference.
        let layout = ShardLayout::by_ratios(6, &[0.5, 0.5]);
        let shards = [vec![1f32, 2., 3.], vec![4f32, 5., 6.]];
        let expect = inproc::ring_allgather(
            &[shards[0].clone(), shards[1].clone()],
            &layout,
        );
        let got = on_fabric(4, |t| {
            if t.rank() < 2 {
                Some(ring_allgather(t, &shards[t.rank()], &layout).unwrap())
            } else {
                // Outside the group: calling in is an error, not UB.
                assert!(ring_allgather(t, &[], &layout).is_err());
                None
            }
        });
        assert_eq!(got[0].as_ref().unwrap(), &expect);
        assert_eq!(got[1].as_ref().unwrap(), &expect);
    }

    #[test]
    fn size_mismatches_are_rejected() {
        let layout = ShardLayout::by_ratios(4, &[0.5, 0.5]);
        let got = on_fabric(2, |t| {
            let bad_shard = ring_allgather(t, &[1.0], &layout).is_err();
            let bad_full = ring_reduce_scatter(t, &[1.0], &layout).is_err();
            (bad_shard, bad_full)
        });
        assert!(got.iter().all(|&(a, b)| a && b));
    }
}
