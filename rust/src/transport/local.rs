//! In-process channel transport — the zero-dependency default fabric.
//!
//! A [`LocalFabric`] wires `world²` unbounded `std::sync::mpsc`
//! channels into per-(src, dst) FIFO lanes and hands back one
//! [`LocalTransport`] endpoint per rank. Endpoints are `Send`, so the
//! usual pattern is one endpoint per worker thread. Unbounded channels
//! mean sends never block, which is what makes the sequential
//! send-then-recv discipline of the ring collectives and migration
//! loops deadlock-free (see DESIGN.md §Transport).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::{expect_bytes, expect_f32, Frame, Transport};
use crate::util::error::{anyhow, Result};

/// Constructor for a fully connected in-process fabric.
pub struct LocalFabric;

impl LocalFabric {
    /// Build `world` connected endpoints; index == rank. Self-lanes are
    /// included, so `send_*(me, ..)` / `recv_*(me)` work.
    pub fn new(world: usize) -> Vec<LocalTransport> {
        assert!(world >= 1, "fabric needs at least one rank");
        // txs[src][dst] is the sender of the src->dst lane;
        // rxs[dst][src] the matching receiver.
        let mut txs: Vec<Vec<Sender<Frame>>> =
            (0..world).map(|_| Vec::with_capacity(world)).collect();
        let mut rxs: Vec<Vec<Receiver<Frame>>> =
            (0..world).map(|_| Vec::with_capacity(world)).collect();
        // dst outer / src inner: every txs[src] gains one entry per
        // dst (in dst order), every rxs[dst] one entry per src (in src
        // order), so both index by the peer rank.
        for dst in 0..world {
            for src in 0..world {
                let (tx, rx) = channel();
                txs[src].push(tx);
                rxs[dst].push(rx);
            }
        }
        let mut out = Vec::with_capacity(world);
        for (rank, (senders, inbox)) in
            txs.into_iter().zip(rxs).enumerate()
        {
            out.push(LocalTransport {
                rank,
                world,
                senders,
                inbox,
                closed: false,
            });
        }
        out
    }
}

/// One rank's endpoint in a [`LocalFabric`].
pub struct LocalTransport {
    rank: usize,
    world: usize,
    /// `senders[dst]` — this rank's lane to each destination.
    senders: Vec<Sender<Frame>>,
    /// `inbox[src]` — the receive side of each source's lane to us.
    inbox: Vec<Receiver<Frame>>,
    /// Set by [`Transport::close`]: sends fail, peers see hangups.
    closed: bool,
}

impl LocalTransport {
    fn check_peer(&self, peer: usize, verb: &str) -> Result<()> {
        if peer >= self.world {
            return Err(anyhow!(
                "{verb} rank {peer} out of range (world {})",
                self.world
            ));
        }
        Ok(())
    }

    fn push(&mut self, to: usize, frame: Frame) -> Result<()> {
        self.check_peer(to, "send to")?;
        if self.closed {
            return Err(anyhow!("rank {} endpoint is closed", self.rank));
        }
        self.senders[to]
            .send(frame)
            .map_err(|_| anyhow!("rank {to} hung up (channel closed)"))
    }

    fn pull(&mut self, from: usize) -> Result<Frame> {
        self.check_peer(from, "recv from")?;
        self.inbox[from]
            .recv()
            .map_err(|_| anyhow!("rank {from} hung up (channel closed)"))
    }
}

impl Transport for LocalTransport {
    fn backend(&self) -> &'static str {
        "local"
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        self.push(to, Frame::F32(data.to_vec()))
    }

    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        let f = self.pull(from)?;
        expect_f32(f, from)
    }

    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        self.push(to, Frame::Bytes(data.to_vec()))
    }

    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        let f = self.pull(from)?;
        expect_bytes(f, from)
    }

    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        self.check_peer(from, "recv from")?;
        match self.inbox[from].recv_timeout(Duration::from_millis(timeout_ms))
        {
            Ok(f) => expect_bytes(f, from).map(Some),
            // Timeout and a hung-up peer both mean "no answer" — the
            // probe loop treats either as silence.
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => Ok(None),
        }
    }

    fn close(&mut self) {
        // Dropping every sender disconnects all outgoing lanes, so any
        // peer blocked on `recv_*(self.rank)` wakes with a hangup
        // error instead of waiting forever. `push` guards on `closed`
        // before indexing the (now empty) sender list.
        self.closed = true;
        self.senders = Vec::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_route_between_ranks_and_self() {
        let mut eps = LocalFabric::new(3);
        let mut c = eps.pop().unwrap(); // rank 2
        let mut b = eps.pop().unwrap(); // rank 1
        let mut a = eps.pop().unwrap(); // rank 0
        assert_eq!((a.rank(), b.rank(), c.rank()), (0, 1, 2));
        assert_eq!(a.world_size(), 3);
        assert_eq!(a.backend(), "local");

        a.send_f32(1, &[1.0, -0.0]).unwrap();
        a.send_bytes(1, &[7]).unwrap();
        c.send_f32(1, &[9.0]).unwrap();
        // Per-source FIFO, demultiplexed by src.
        assert_eq!(b.recv_f32(2).unwrap(), vec![9.0]);
        let xs = b.recv_f32(0).unwrap();
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(b.recv_bytes(0).unwrap(), vec![7]);

        // Self-send round-trips.
        b.send_bytes(1, &[1, 2]).unwrap();
        assert_eq!(b.recv_bytes(1).unwrap(), vec![1, 2]);
    }

    #[test]
    fn type_mismatch_and_bad_rank_error() {
        let mut eps = LocalFabric::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[1]).unwrap();
        assert!(b.recv_f32(0).is_err());
        assert!(a.send_f32(5, &[1.0]).is_err());
        assert!(a.recv_bytes(9).is_err());
    }

    #[test]
    fn hung_up_peer_is_an_error_not_a_hang() {
        let mut eps = LocalFabric::new(2);
        let b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        drop(b);
        assert!(a.send_f32(1, &[1.0]).is_err());
        assert!(a.recv_f32(1).is_err());
    }

    #[test]
    fn recv_timeout_returns_none_on_silence_and_some_on_frames() {
        let mut eps = LocalFabric::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert_eq!(a.recv_bytes_timeout(1, 5).unwrap(), None);
        b.send_bytes(0, &[42]).unwrap();
        assert_eq!(a.recv_bytes_timeout(1, 1000).unwrap(), Some(vec![42]));
        // A hung-up peer is "no answer", not an error, on this path.
        drop(b);
        assert_eq!(a.recv_bytes_timeout(1, 5).unwrap(), None);
    }

    #[test]
    fn close_wakes_blocked_peers_and_fails_later_sends() {
        let mut eps = LocalFabric::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let waiter = std::thread::spawn(move || a.recv_bytes(1));
        std::thread::sleep(std::time::Duration::from_millis(10));
        b.close();
        assert!(waiter.join().unwrap().is_err(), "close must wake peers");
        assert!(b.send_bytes(0, &[1]).is_err());
        assert!(b.send_bytes(1, &[1]).is_err(), "self-sends fail too");
    }

    #[test]
    fn barrier_releases_all_ranks() {
        let eps = LocalFabric::new(4);
        std::thread::scope(|s| {
            for mut ep in eps {
                s.spawn(move || {
                    for _ in 0..3 {
                        ep.barrier().unwrap();
                    }
                });
            }
        });
    }
}
