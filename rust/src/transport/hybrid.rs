//! Locality-routed fabric: shm lanes inside a host, TCP across hosts.
//!
//! A [`HybridTransport`] owns two endpoints for the same rank — a
//! [`ShmTransport`] with lanes to its same-host peers (self included)
//! and any slower full-mesh fabric (in production the fault-tolerant
//! TCP transport) — plus the [`HostTopology`] that decides, per peer,
//! which one carries the traffic:
//!
//! > **Routing rule.** A frame to rank `p` travels shm iff
//! > `topology.same_host(self, p)`; otherwise it travels the slow
//! > fabric. Both sides derive the route from the same shared map, so
//! > sender and receiver always pick the same lane — the route is a
//! > pure function of (src, dst).
//!
//! FIFO holds per (src, dst) pair exactly as the [`Transport`]
//! contract demands, because ALL frames of a pair take one lane.
//! Liveness is the union of both fabrics: the slow fabric keeps its
//! full mesh (heartbeats cross host boundaries AND loop within a
//! host), so a SIGKILLed same-host peer — invisible to pure shm — is
//! still detected by TCP heartbeat expiry. Fault-injection hooks
//! forward to the lane that owns the peer: `resend_last` /
//! `corrupt_next_send` are real on TCP lanes and no-ops on shm lanes
//! (no wire dedup/CRC to exercise), which is exactly what keeps chaos
//! middleware bitwise-invisible over the hybrid fabric too.

use std::path::Path;
use std::time::{Duration, Instant};

use super::shm::ShmTransport;
use super::topology::HostTopology;
use super::{expect_bytes, expect_f32, Frame, Transport, TransportError};
use crate::util::error::{anyhow, Result};

/// Slice of the shm poll loop: how long a blocking same-host recv
/// waits on the fast lane before re-checking the slow fabric's
/// liveness verdict (a SIGKILLed peer never closes its shm lane).
const LIVENESS_SLICE_MS: u64 = 50;

/// Tally one outbound routing decision in the fabric counters.
fn count_route(via_shm: bool) {
    use std::sync::atomic::Ordering;
    let c = crate::telemetry::counters();
    if via_shm {
        c.hybrid_shm_routed.fetch_add(1, Ordering::Relaxed);
    } else {
        c.hybrid_tcp_routed.fetch_add(1, Ordering::Relaxed);
    }
}

/// One rank's endpoint over the two-tier fabric.
pub struct HybridTransport {
    topo: HostTopology,
    shm: ShmTransport,
    slow: Box<dyn Transport>,
}

impl HybridTransport {
    /// Compose an endpoint from its two lanes. The shm endpoint needs
    /// lanes to (at least) every same-host peer; the slow endpoint
    /// must cover the full mesh.
    pub fn new(
        topo: HostTopology,
        shm: ShmTransport,
        slow: Box<dyn Transport>,
    ) -> Result<HybridTransport> {
        let (rank, world) = (slow.rank(), slow.world_size());
        if topo.world_size() != world || shm.world_size() != world {
            return Err(anyhow!(
                "hybrid fabric shape mismatch: slow fabric world {world}, \
                 shm world {}, topology {} ranks",
                shm.world_size(),
                topo.world_size()
            ));
        }
        if shm.rank() != rank {
            return Err(anyhow!(
                "hybrid fabric rank mismatch: slow {rank}, shm {}",
                shm.rank()
            ));
        }
        for p in 0..world {
            if topo.same_host(rank, p) && !shm.has_lane(p) {
                return Err(anyhow!(
                    "rank {rank} shares a host with rank {p} but has no \
                     shm lane to it"
                ));
            }
        }
        Ok(HybridTransport { topo, shm, slow })
    }

    /// Wrap a full-mesh endpoint: attach shm lanes under `dir` for
    /// every peer on this rank's host and route by `topo`. This is the
    /// worker-side constructor (`--transport hybrid` + `--shm-dir`).
    pub fn wrap(
        slow: Box<dyn Transport>,
        dir: &Path,
        topo: HostTopology,
    ) -> Result<HybridTransport> {
        let (rank, world) = (slow.rank(), slow.world_size());
        if topo.world_size() != world {
            return Err(anyhow!(
                "host map names {} ranks, fabric has {world}",
                topo.world_size()
            ));
        }
        let peers: Vec<usize> =
            (0..world).filter(|&p| topo.same_host(rank, p)).collect();
        let shm = ShmTransport::attach_peers(dir, rank, world, &peers)?;
        HybridTransport::new(topo, shm, slow)
    }

    /// Whether traffic to `peer` takes the shm fast path.
    pub fn routes_via_shm(&self, peer: usize) -> bool {
        self.topo.same_host(self.slow.rank(), peer)
    }

    /// The topology this endpoint routes by.
    pub fn topology(&self) -> &HostTopology {
        &self.topo
    }

    /// Blocking recv on the shm route that stays failure-aware: poll
    /// the fast lane in slices, consulting the slow fabric's failure
    /// detector between slices, so a caller never parks forever on a
    /// same-host peer that died without closing its ring.
    fn recv_frame_shm(&mut self, from: usize) -> Result<Frame> {
        loop {
            let deadline = Instant::now()
                + Duration::from_millis(LIVENESS_SLICE_MS);
            if let Some(f) = self.shm.recv_frame(from, Some(deadline))? {
                return Ok(f);
            }
            if self.slow.peer_closed(from) {
                return Err(
                    TransportError::PeerClosed { rank: from }.into()
                );
            }
        }
    }
}

impl Transport for HybridTransport {
    fn backend(&self) -> &'static str {
        "hybrid"
    }

    fn rank(&self) -> usize {
        self.slow.rank()
    }

    fn world_size(&self) -> usize {
        self.slow.world_size()
    }

    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        if to >= self.world_size() {
            return Err(anyhow!(
                "send to rank {to} out of range (world {})",
                self.world_size()
            ));
        }
        if self.routes_via_shm(to) {
            count_route(true);
            self.shm.send_f32(to, data)
        } else {
            count_route(false);
            self.slow.send_f32(to, data)
        }
    }

    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        if from >= self.world_size() {
            return Err(anyhow!(
                "recv from rank {from} out of range (world {})",
                self.world_size()
            ));
        }
        if self.routes_via_shm(from) {
            let f = self.recv_frame_shm(from)?;
            expect_f32(f, from)
        } else {
            self.slow.recv_f32(from)
        }
    }

    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        if to >= self.world_size() {
            return Err(anyhow!(
                "send to rank {to} out of range (world {})",
                self.world_size()
            ));
        }
        if self.routes_via_shm(to) {
            count_route(true);
            self.shm.send_bytes(to, data)
        } else {
            count_route(false);
            self.slow.send_bytes(to, data)
        }
    }

    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        if from >= self.world_size() {
            return Err(anyhow!(
                "recv from rank {from} out of range (world {})",
                self.world_size()
            ));
        }
        if self.routes_via_shm(from) {
            let f = self.recv_frame_shm(from)?;
            expect_bytes(f, from)
        } else {
            self.slow.recv_bytes(from)
        }
    }

    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        if self.routes_via_shm(from) {
            self.shm.recv_bytes_timeout(from, timeout_ms)
        } else {
            self.slow.recv_bytes_timeout(from, timeout_ms)
        }
    }

    fn peer_closed(&self, rank: usize) -> bool {
        // Union of the evidence: a cooperative close flags the shm
        // lane, a crash trips the slow fabric's detector.
        self.shm.peer_closed(rank) || self.slow.peer_closed(rank)
    }

    fn peer_failed(&self, rank: usize) -> bool {
        self.shm.peer_failed(rank) || self.slow.peer_failed(rank)
    }

    fn close(&mut self) {
        self.shm.close();
        self.slow.close();
    }

    fn resend_last(&mut self, to: usize) -> Result<()> {
        if self.routes_via_shm(to) {
            self.shm.resend_last(to)
        } else {
            self.slow.resend_last(to)
        }
    }

    fn corrupt_next_send(&mut self, to: usize) {
        if self.routes_via_shm(to) {
            self.shm.corrupt_next_send(to)
        } else {
            self.slow.corrupt_next_send(to)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::local::LocalFabric;
    use super::super::shm::{fresh_dir, ShmTransport};
    use super::*;

    /// Hybrid endpoints over a Local slow fabric: hosts `[0,0,1,1]`.
    fn fabric(hosts: Vec<u64>) -> Vec<HybridTransport> {
        let world = hosts.len();
        let topo = HostTopology::new(hosts);
        let dir = fresh_dir();
        LocalFabric::new(world)
            .into_iter()
            .map(|slow| {
                HybridTransport::wrap(
                    Box::new(slow),
                    &dir,
                    topo.clone(),
                )
                .expect("hybrid wrap")
            })
            .collect()
    }

    #[test]
    fn routes_split_by_host_and_both_lanes_deliver() {
        let mut eps = fabric(vec![0, 0, 1, 1]);
        assert_eq!(eps[0].backend(), "hybrid");
        assert!(eps[0].routes_via_shm(0), "self is same-host");
        assert!(eps[0].routes_via_shm(1));
        assert!(!eps[0].routes_via_shm(2));

        // Same-host pair (0 → 1): shm lane.
        eps[0].send_f32(1, &[1.5, -0.0]).unwrap();
        let (a, rest) = eps.split_at_mut(1);
        let xs = rest[0].recv_f32(0).unwrap();
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());

        // Cross-host pair (0 → 2): slow lane.
        a[0].send_bytes(2, &[9, 9]).unwrap();
        assert_eq!(rest[1].recv_bytes(0).unwrap(), vec![9, 9]);

        // Self-send loops through shm.
        a[0].send_bytes(0, &[7]).unwrap();
        assert_eq!(a[0].recv_bytes(0).unwrap(), vec![7]);
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let topo = HostTopology::new(vec![0, 0]);
        let dir = fresh_dir();
        let mut slow = LocalFabric::new(2);
        let s1 = slow.pop().unwrap();
        let shm = ShmTransport::attach(&dir, 1, 2).unwrap();
        // Shm lane present for both same-host peers: fine.
        assert!(HybridTransport::new(topo.clone(), shm, Box::new(s1))
            .is_ok());
        // Missing same-host lane: rejected.
        let s0 = slow.pop().unwrap();
        let partial =
            ShmTransport::attach_peers(&fresh_dir(), 0, 2, &[0]).unwrap();
        assert!(HybridTransport::new(topo, partial, Box::new(s0)).is_err());
    }

    #[test]
    fn close_propagates_to_both_lanes() {
        let mut eps = fabric(vec![0, 0]);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.close();
        assert!(b.peer_closed(0), "shm closed flag visible to peer");
        assert!(a.send_bytes(1, &[1]).is_err());
        // Blocked same-host recv wakes via the shm closed flag.
        assert!(b.recv_bytes(0).is_err());
    }

    #[test]
    fn barrier_runs_over_mixed_routes() {
        let eps = fabric(vec![0, 1, 0, 1]);
        std::thread::scope(|s| {
            for mut ep in eps {
                s.spawn(move || {
                    for _ in 0..3 {
                        ep.barrier().unwrap();
                    }
                });
            }
        });
    }

    #[test]
    fn timeout_recv_routes_by_locality() {
        let mut eps = fabric(vec![0, 0, 1]);
        assert_eq!(eps[0].recv_bytes_timeout(1, 5).unwrap(), None);
        assert_eq!(eps[0].recv_bytes_timeout(2, 5).unwrap(), None);
        eps[1].send_bytes(0, &[1]).unwrap();
        eps[2].send_bytes(0, &[2]).unwrap();
        assert_eq!(
            eps[0].recv_bytes_timeout(1, 1000).unwrap(),
            Some(vec![1])
        );
        assert_eq!(
            eps[0].recv_bytes_timeout(2, 1000).unwrap(),
            Some(vec![2])
        );
    }
}
