//! Host topology: which physical host each rank lives on, and the
//! locality-sorted ring order derived from it.
//!
//! A heterogeneous deployment typically packs several ranks per host;
//! the intra-host fabric (shared memory, NVLink, PCIe) is an order of
//! magnitude faster than the inter-host NIC. The ring collectives walk
//! rank order, so an interleaved host map (h0, h1, h0, h1, ...) makes
//! EVERY hop cross the slow fabric. [`HostTopology::ring_order`]
//! permutes the ring so same-host ranks sit adjacent: exactly
//! `num_hosts` of the N−1 hops cross hosts (one outbound edge per
//! host), the rest stay local. The permutation is a pure function of
//! the host map, so every rank derives the identical order with no
//! extra coordination — and because the native backend's gradients
//! live on the dyadic grid, f32 summation around ANY ring order is
//! exactly associative, keeping the reorder bitwise-invisible
//! (DESIGN.md invariant 10).

/// Rank → host-id map for one fabric. Host ids are opaque `u64`s
/// (`worker --host-id`, or hashes exchanged at rendezvous); equality
/// is all that matters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostTopology {
    hosts: Vec<u64>,
}

impl HostTopology {
    /// Topology from an explicit rank → host map.
    pub fn new(hosts: Vec<u64>) -> HostTopology {
        assert!(!hosts.is_empty(), "topology needs at least one rank");
        HostTopology { hosts }
    }

    /// Every rank on one host — the single-machine default.
    pub fn single_host(world: usize) -> HostTopology {
        HostTopology::new(vec![0; world])
    }

    /// Parse a comma-separated host map, e.g. `"0,0,1,1"`.
    pub fn parse(spec: &str, world: usize) -> Result<HostTopology, String> {
        let hosts: Vec<u64> = spec
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<u64>()
                    .map_err(|_| format!("bad host id '{t}' in '{spec}'"))
            })
            .collect::<Result<_, _>>()?;
        if hosts.len() != world {
            return Err(format!(
                "host map '{spec}' names {} ranks, fabric has {world}",
                hosts.len()
            ));
        }
        Ok(HostTopology::new(hosts))
    }

    /// Number of ranks the host map covers.
    pub fn world_size(&self) -> usize {
        self.hosts.len()
    }

    /// The host id rank `r` lives on.
    pub fn host_of(&self, r: usize) -> u64 {
        self.hosts[r]
    }

    /// The full rank → host map, e.g. for wire encoding.
    pub fn hosts(&self) -> &[u64] {
        &self.hosts
    }

    /// Whether two ranks share a host (the shm-routing predicate).
    pub fn same_host(&self, a: usize, b: usize) -> bool {
        self.hosts[a] == self.hosts[b]
    }

    /// Number of distinct hosts.
    pub fn num_hosts(&self) -> usize {
        let mut seen: Vec<u64> = Vec::new();
        for &h in &self.hosts {
            if !seen.contains(&h) {
                seen.push(h);
            }
        }
        seen.len()
    }

    /// The topology restricted to the first `k` ranks (elastic shrink
    /// keeps memberships as canonical prefixes).
    pub fn prefix(&self, k: usize) -> HostTopology {
        assert!(k >= 1 && k <= self.hosts.len());
        HostTopology::new(self.hosts[..k].to_vec())
    }

    /// Locality-sorted ring order over the first `group` ranks: hosts
    /// appear in order of their first rank, all of a host's ranks
    /// adjacent, ranks ascending within a host. Rank 0 is always
    /// first, so single-host maps yield the identity order and the
    /// schedule degrades to the classic ring. Deterministic: every
    /// rank computes the same permutation from the shared map.
    pub fn ring_order(&self, group: usize) -> Vec<usize> {
        assert!(group >= 1 && group <= self.hosts.len());
        let mut order = Vec::with_capacity(group);
        let mut hosts_seen: Vec<u64> = Vec::new();
        for r in 0..group {
            let h = self.hosts[r];
            if !hosts_seen.contains(&h) {
                hosts_seen.push(h);
                order.extend(
                    (r..group).filter(|&s| self.hosts[s] == h),
                );
            }
        }
        order
    }

    /// Cross-host hops on the locality-sorted ring over the first
    /// `group` ranks — `num_hosts` when several hosts participate
    /// (each host has exactly one outbound cross edge), 0 otherwise.
    pub fn cross_hops(&self, group: usize) -> usize {
        let order = self.ring_order(group);
        cross_edges(self, &order)
    }
}

/// Cross-host edges of an arbitrary ring `order` (wraparound
/// included). Public so tests can compare orders.
pub fn cross_edges(topo: &HostTopology, order: &[usize]) -> usize {
    if order.len() <= 1 {
        return 0;
    }
    (0..order.len())
        .filter(|&i| {
            !topo.same_host(order[i], order[(i + 1) % order.len()])
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_host_is_the_identity_order() {
        let t = HostTopology::single_host(5);
        assert_eq!(t.ring_order(5), vec![0, 1, 2, 3, 4]);
        assert_eq!(t.ring_order(3), vec![0, 1, 2]);
        assert_eq!(t.cross_hops(5), 0);
        assert_eq!(t.num_hosts(), 1);
        assert!(t.same_host(0, 4));
    }

    #[test]
    fn interleaved_hosts_regroup_with_minimal_cross_edges() {
        // h0: {0,2,4}, h1: {1,3,5} — the worst case for rank order
        // (every hop crosses). Locality order groups each host.
        let t = HostTopology::new(vec![0, 1, 0, 1, 0, 1]);
        let order = t.ring_order(6);
        assert_eq!(order, vec![0, 2, 4, 1, 3, 5]);
        assert_eq!(cross_edges(&t, &order), 2);
        // Identity order crosses on all six edges.
        assert_eq!(cross_edges(&t, &[0, 1, 2, 3, 4, 5]), 6);
        assert_eq!(t.cross_hops(6), t.num_hosts());
    }

    #[test]
    fn order_is_a_permutation_and_rank0_leads() {
        let t = HostTopology::new(vec![7, 3, 7, 9, 3, 9, 7]);
        for group in 1..=7 {
            let order = t.ring_order(group);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..group).collect::<Vec<_>>());
            assert_eq!(order[0], 0, "rank 0 must lead the ring");
            // All of a host's members are contiguous: one outbound
            // cross edge per host (none on a single-host prefix).
            let hosts = t.prefix(group).num_hosts();
            assert_eq!(
                cross_edges(&t, &order),
                if hosts > 1 { hosts } else { 0 }
            );
        }
    }

    #[test]
    fn parse_round_trips_and_rejects_bad_specs() {
        let t = HostTopology::parse("0, 0, 1", 3).unwrap();
        assert_eq!(t.hosts(), &[0, 0, 1]);
        assert!(HostTopology::parse("0,1", 3).is_err());
        assert!(HostTopology::parse("0,x,1", 3).is_err());
    }

    #[test]
    fn prefix_tracks_membership_shrink() {
        let t = HostTopology::new(vec![0, 0, 1, 1]);
        let p = t.prefix(2);
        assert_eq!(p.num_hosts(), 1);
        assert_eq!(p.ring_order(2), vec![0, 1]);
    }
}
