//! The transport subsystem: a real inter-rank message plane.
//!
//! Everything before this module runs the paper's "cluster" inside one
//! address space — the ring collectives are deterministic array
//! transforms, the elastic session migrates Adam shards with
//! `copy_from_slice`. This module makes rank-to-rank communication a
//! first-class abstraction so the SAME trainer pipeline spans threads,
//! processes and (over TCP) hosts:
//!
//! * [`Transport`] — typed, length-prefixed frames (f32 vectors and raw
//!   bytes) between ranks, plus a barrier and rank/world metadata.
//!   Fail-stop semantics: any send/recv error means the peer is gone
//!   and the step that observed it returns the error.
//! * [`local::LocalFabric`] / [`local::LocalTransport`] — in-process
//!   channels (`std::sync::mpsc`), the zero-dependency default.
//! * [`tcp::TcpTransport`] — loopback/LAN sockets (`std::net` only)
//!   with a tiny rendezvous + full-mesh handshake protocol.
//! * [`shm::ShmTransport`] — the same-host fast path: the same typed
//!   frames over file-backed mmap SPSC rings under `/dev/shm`
//!   (seqlock-style head/tail cursors, futex-free spin-then-yield).
//! * [`hybrid::HybridTransport`] — per-peer locality routing guided by
//!   a [`topology::HostTopology`]: same-host lanes take shm, cross-host
//!   lanes take the fault-tolerant TCP mesh, and the ring collectives
//!   walk a locality-sorted order so only `num_hosts` of the N−1 hops
//!   cross the slow fabric.
//! * [`collectives`] — the segmented ring AllGather / ReduceScatter
//!   over the uneven `ShardLayout`, executed as actual N−1 rounds of
//!   peer messages, bit-identical to the in-process
//!   `crate::collectives::ring_*` (the native backend's dyadic
//!   exact-summation contract makes that testable bitwise — DESIGN.md
//!   invariant 10: *the wire is bitwise-invisible*).
//! * [`dist`] — the SPMD per-rank training engine
//!   (`dist::DistRank`), the `cephalo worker` serving loop
//!   (`dist::worker_loop`) and the coordinator-side driver
//!   (`dist::DistDriver`) that spawns worker threads or processes and
//!   routes `elastic::apply_migration` transfer lists over the wire.
//!
//! * [`chaos::ChaosTransport`] — deterministic fault-injection
//!   middleware over any fabric: seeded delays, duplicate frames, frame
//!   corruption and crash-at-step-k schedules from a replayable
//!   [`chaos::FaultPlan`].
//! * [`failure::FailureDetector`] — heartbeat bookkeeping behind the
//!   TCP fabric's per-peer liveness verdicts.
//!
//! ## Frame format
//!
//! On the wire (TCP, v2) every frame is
//! `[tag: u8][seq: u64 LE][len: u64 LE][payload][crc32: u32 LE]`;
//! tag 0 = raw bytes, tag 1 = f32 vector (payload is `4 × count`
//! little-endian bytes), tag 2 = heartbeat (empty payload, seq 0,
//! consumed by the reader thread and never surfaced to `recv_*`). The
//! CRC32 (IEEE) covers tag through payload; a mismatch is a typed
//! [`TransportError::Corrupt`] and closes the lane — the peer then
//! LOOKS dead, which routes corruption into the same recovery path as
//! a crash. Per-lane sequence numbers start at 1 and must arrive
//! gap-free; a duplicate (seq ≤ last seen) is silently dropped, which
//! is what makes duplicate-frame fault injection bitwise-invisible.
//! In-process transports carry the same frames as enum values without
//! serialization. A `recv_f32` that dequeues a bytes frame (or vice
//! versa) is a protocol error, not a silent reinterpretation — SPMD
//! lockstep means both sides always agree on the next frame type.
//!
//! ```text
//! v2 TCP frame
//! ┌──────┬────────────┬────────────┬───────────┬─────────────┐
//! │ tag  │ seq        │ len        │ payload   │ crc32       │
//! │ u8   │ u64 LE     │ u64 LE     │ len bytes │ u32 LE      │
//! └──────┴────────────┴────────────┴───────────┴─────────────┘
//!   0 = bytes   1 = f32 vector   2 = heartbeat (len 0, seq 0)
//!   crc32 (IEEE) covers tag..payload; seq is per-lane, gap-free
//! ```

#![warn(missing_docs)]

pub mod chaos;
pub mod collectives;
pub mod dist;
pub mod failure;
pub mod hybrid;
pub mod local;
pub mod shm;
pub mod tcp;
pub mod topology;

pub use chaos::{ChaosConfig, ChaosTransport, CrashMode, DriverFaults, FaultPlan};
pub use dist::{
    worker_loop, ChaosOpts, DistConfig, DistDriver, FabricSpec, MirrorLayout,
    PollReport, RankTiming, RejoinEvent,
};
pub use failure::FailureDetector;
pub use hybrid::HybridTransport;
pub use local::{LocalFabric, LocalTransport};
pub use shm::{ShmFabric, ShmTransport};
pub use tcp::{Rendezvous, TcpTransport};
pub use topology::HostTopology;

use crate::util::error::{anyhow, Result};

/// Typed transport-layer failures. Converts into the crate-wide opaque
/// [`crate::util::error::Error`] via its blanket `From<E: std::error::Error>`,
/// so fabric code can `?` these while tests still match on the variant
/// at the layer that produced it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// A frame's CRC32 check failed: `expected` from the trailer,
    /// `got` recomputed over the received bytes.
    Corrupt { from: usize, expected: u32, got: u32 },
    /// A lane's sequence numbers skipped ahead — at least one frame
    /// was lost in flight.
    SeqGap { from: usize, expected: u64, got: u64 },
    /// The peer's connection is closed (EOF, reset, or declared dead
    /// by the failure detector).
    PeerClosed { rank: usize },
    /// A bounded wait elapsed without a frame.
    Timeout { from: usize, after_ms: u64 },
    /// A `ChaosTransport` crash fault fired (thread-mode crash).
    ChaosCrash { rank: usize, step: u64 },
    /// Any other protocol violation.
    Protocol { detail: String },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Corrupt { from, expected, got } => write!(
                f,
                "corrupt frame from rank {from}: crc32 {got:#010x} != \
                 expected {expected:#010x}"
            ),
            TransportError::SeqGap { from, expected, got } => write!(
                f,
                "sequence gap from rank {from}: expected seq {expected}, \
                 got {got} (frame lost)"
            ),
            TransportError::PeerClosed { rank } => {
                write!(f, "rank {rank} connection closed (peer dead)")
            }
            TransportError::Timeout { from, after_ms } => write!(
                f,
                "no frame from rank {from} within {after_ms} ms"
            ),
            TransportError::ChaosCrash { rank, step } => write!(
                f,
                "chaos: rank {rank} crashed after step {step}"
            ),
            TransportError::Protocol { detail } => {
                write!(f, "transport protocol error: {detail}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Seed for the streaming CRC form ([`crc32_update`]).
pub const CRC32_INIT: u32 = 0xFFFF_FFFF;

/// Streaming CRC32: fold `data` into a running state (seed
/// [`CRC32_INIT`], finalize with bitwise NOT). The TCP sender uses
/// this to checksum a frame split across header/payload/trailer
/// regions without concatenating them into a staging buffer.
pub fn crc32_update(mut crc: u32, data: &[u8]) -> u32 {
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    crc
}

/// CRC32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
/// on every v2 TCP frame. Table-free bitwise form: this runs on
/// command-sized frames and heartbeats far more often than on bulk
/// tensor traffic, and the bulk path is dominated by the socket.
pub fn crc32(data: &[u8]) -> u32 {
    !crc32_update(CRC32_INIT, data)
}

/// One in-flight message. In-process transports pass these by value;
/// the TCP transport (de)serializes them with [`encode_frame`] /
/// `read_frame`.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Raw bytes (commands, acks, serialized state) — wire tag 0.
    Bytes(Vec<u8>),
    /// An f32 vector (tensor traffic) — wire tag 1, payload is
    /// `4 × count` little-endian bytes.
    F32(Vec<f32>),
}

/// Wire tag for a [`Frame::Bytes`] payload.
pub const TAG_BYTES: u8 = 0;
/// Wire tag for a [`Frame::F32`] payload.
pub const TAG_F32: u8 = 1;

/// The single byte exchanged by the default [`Transport::barrier`].
const BARRIER_TOKEN: u8 = 0xB7;

/// Point-to-point message transport between `world_size` ranks.
///
/// Implementations must be `Send` (endpoints move onto worker threads)
/// and support self-sends (`send_*(rank, ..)` followed by
/// `recv_*(rank)`), which keeps migration transfer loops free of
/// special cases. Frames between a (src, dst) pair are FIFO; frames
/// from different sources are independently ordered, and `recv_*(from)`
/// demultiplexes by source rank.
pub trait Transport: Send {
    /// Backend label ("local", "tcp") for logs and reports.
    fn backend(&self) -> &'static str;

    /// This endpoint's rank in `0..world_size`.
    fn rank(&self) -> usize;

    /// Total number of ranks in the fabric.
    fn world_size(&self) -> usize;

    /// Send an f32 vector to `to` (FIFO per destination).
    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()>;

    /// Receive the next f32 frame from `from` (blocking).
    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>>;

    /// Send a raw byte frame to `to`.
    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()>;

    /// Receive the next byte frame from `from` (blocking).
    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>>;

    /// Bounded-wait receive: `Ok(Some(frame))` if a byte frame arrives
    /// within `timeout_ms`, `Ok(None)` if the wait elapses OR the peer
    /// is already gone (both mean "no answer" to a liveness probe —
    /// the caller consults [`Transport::peer_closed`] to distinguish).
    /// Default: degrade to a blocking receive, mapping errors to
    /// `Ok(None)` so probing a fabric without timeout support is safe.
    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        let _ = timeout_ms;
        match self.recv_bytes(from) {
            Ok(b) => Ok(Some(b)),
            Err(_) => Ok(None),
        }
    }

    /// Whether the fabric suspects or KNOWS this peer's connection is
    /// gone (EOF, reset, heartbeat expiry). `false` means "no
    /// evidence", not "alive" — fabrics without liveness tracking
    /// always say `false`.
    fn peer_closed(&self, rank: usize) -> bool {
        let _ = rank;
        false
    }

    /// Whether the fabric has HARD evidence the peer is gone — a lane
    /// that saw EOF/reset and can never carry another frame. Unlike
    /// [`Transport::peer_closed`], a mere heartbeat-silence suspicion
    /// does NOT count: a suspected lane may still come back, which is
    /// what the rejoin window probes for. Default: same as
    /// `peer_closed` (fabrics without a soft-suspicion tier have no
    /// distinction to make).
    fn peer_failed(&self, rank: usize) -> bool {
        self.peer_closed(rank)
    }

    /// Tear down this endpoint's lanes so every peer blocked on a
    /// receive from us wakes with an error instead of hanging. After
    /// `close`, sends from this endpoint fail. Default: no-op.
    fn close(&mut self) {}

    /// Re-transmit the last frame sent to `to` byte-for-byte (same
    /// sequence number on the wire, so the receiver's dedup drops it).
    /// The duplicate-frame fault injector calls this; fabrics without
    /// wire-level dedup leave it a no-op so a "duplicate" never becomes
    /// a double delivery.
    fn resend_last(&mut self, to: usize) -> Result<()> {
        let _ = to;
        Ok(())
    }

    /// Arm a one-shot payload corruption on the NEXT frame sent to
    /// `to` (one byte flipped after the checksum is computed). Fault
    /// injection only; fabrics without a checksum to violate leave it
    /// a no-op.
    fn corrupt_next_send(&mut self, to: usize) {
        let _ = to;
    }

    /// Block until every rank has entered the barrier. Default:
    /// gather-to-0 then release, built on the point-to-point frames.
    fn barrier(&mut self) -> Result<()> {
        let n = self.world_size();
        if n <= 1 {
            return Ok(());
        }
        if self.rank() == 0 {
            for r in 1..n {
                let tok = self.recv_bytes(r)?;
                if tok != [BARRIER_TOKEN] {
                    return Err(anyhow!(
                        "barrier desync: rank {r} sent a non-barrier \
                         frame ({} bytes)",
                        tok.len()
                    ));
                }
            }
            for r in 1..n {
                self.send_bytes(r, &[BARRIER_TOKEN])?;
            }
        } else {
            self.send_bytes(0, &[BARRIER_TOKEN])?;
            let tok = self.recv_bytes(0)?;
            if tok != [BARRIER_TOKEN] {
                return Err(anyhow!("barrier desync at rank 0 release"));
            }
        }
        Ok(())
    }
}

/// Boxed endpoints are endpoints, so middleware like
/// [`ChaosTransport`] can wrap a `Box<dyn Transport>`. Every method
/// forwards — INCLUDING the defaulted ones, which would otherwise
/// shadow the inner fabric's overrides (a boxed TCP endpoint must keep
/// its real timeouts, liveness and dedup).
impl<T: Transport + ?Sized> Transport for Box<T> {
    fn backend(&self) -> &'static str {
        (**self).backend()
    }
    fn rank(&self) -> usize {
        (**self).rank()
    }
    fn world_size(&self) -> usize {
        (**self).world_size()
    }
    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        (**self).send_f32(to, data)
    }
    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        (**self).recv_f32(from)
    }
    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        (**self).send_bytes(to, data)
    }
    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        (**self).recv_bytes(from)
    }
    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        (**self).recv_bytes_timeout(from, timeout_ms)
    }
    fn peer_closed(&self, rank: usize) -> bool {
        (**self).peer_closed(rank)
    }
    fn peer_failed(&self, rank: usize) -> bool {
        (**self).peer_failed(rank)
    }
    fn close(&mut self) {
        (**self).close()
    }
    fn resend_last(&mut self, to: usize) -> Result<()> {
        (**self).resend_last(to)
    }
    fn corrupt_next_send(&mut self, to: usize) {
        (**self).corrupt_next_send(to)
    }
    fn barrier(&mut self) -> Result<()> {
        (**self).barrier()
    }
}

/// Serialize an f32 slice as little-endian bytes (the wire layout of a
/// [`Frame::F32`] payload).
pub fn f32s_to_le_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le_bytes`]; errors on a ragged length.
pub fn f32s_from_le_bytes(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(anyhow!("f32 frame of {} bytes is not 4-aligned", b.len()));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Render a frame in wire format: `[tag][len u64 LE][payload]`.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let (tag, payload) = match frame {
        Frame::Bytes(b) => (TAG_BYTES, b.clone()),
        Frame::F32(xs) => (TAG_F32, f32s_to_le_bytes(xs)),
    };
    let mut out = Vec::with_capacity(9 + payload.len());
    out.push(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Helpers shared by the concrete transports: dequeue a frame and
/// demand a specific variant.
pub(crate) fn expect_f32(frame: Frame, from: usize) -> Result<Vec<f32>> {
    match frame {
        Frame::F32(xs) => Ok(xs),
        Frame::Bytes(b) => Err(anyhow!(
            "protocol desync: expected an f32 frame from rank {from}, \
             got {} raw bytes",
            b.len()
        )),
    }
}

pub(crate) fn expect_bytes(frame: Frame, from: usize) -> Result<Vec<u8>> {
    match frame {
        Frame::Bytes(b) => Ok(b),
        Frame::F32(xs) => Err(anyhow!(
            "protocol desync: expected a byte frame from rank {from}, \
             got {} f32s",
            xs.len()
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trips_bitwise_through_le_bytes() {
        let xs = vec![0.0f32, -0.0, 1.5, -3.25e-7, f32::MIN_POSITIVE];
        let b = f32s_to_le_bytes(&xs);
        assert_eq!(b.len(), xs.len() * 4);
        let back = f32s_from_le_bytes(&b).unwrap();
        // Bitwise, not approximate: compare the bit patterns.
        let bits: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
        let back_bits: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, back_bits);
        assert!(f32s_from_le_bytes(&b[..3]).is_err());
    }

    #[test]
    fn frames_encode_with_tag_and_length() {
        let f = Frame::F32(vec![1.0, 2.0]);
        let w = encode_frame(&f);
        assert_eq!(w[0], TAG_F32);
        assert_eq!(u64::from_le_bytes(w[1..9].try_into().unwrap()), 8);
        assert_eq!(w.len(), 9 + 8);
        let b = encode_frame(&Frame::Bytes(vec![9, 9]));
        assert_eq!(b[0], TAG_BYTES);
        assert_eq!(b.len(), 9 + 2);
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The canonical CRC-32/IEEE check: crc32("123456789").
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // One flipped bit changes the checksum.
        assert_ne!(crc32(b"\x00"), crc32(b"\x01"));
    }

    #[test]
    fn transport_errors_render_and_compare() {
        let e = TransportError::Corrupt { from: 2, expected: 1, got: 9 };
        assert!(e.to_string().contains("corrupt frame from rank 2"));
        assert_eq!(e, e.clone());
        let g = TransportError::SeqGap { from: 1, expected: 4, got: 6 };
        assert!(g.to_string().contains("sequence gap"));
        // The blanket conversion into the crate error keeps the text.
        let op: crate::util::error::Error =
            TransportError::PeerClosed { rank: 3 }.into();
        assert!(op.to_string().contains("rank 3 connection closed"));
    }

    #[test]
    fn expect_helpers_reject_cross_type_frames() {
        assert!(expect_f32(Frame::Bytes(vec![1]), 0).is_err());
        assert!(expect_bytes(Frame::F32(vec![1.0]), 0).is_err());
        assert_eq!(expect_f32(Frame::F32(vec![2.0]), 0).unwrap(), vec![2.0]);
        assert_eq!(expect_bytes(Frame::Bytes(vec![3]), 0).unwrap(), vec![3]);
    }
}
