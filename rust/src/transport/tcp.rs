//! TCP socket transport (`std::net` only): loopback or LAN ranks with
//! a tiny rendezvous + full-mesh handshake.
//!
//! ## Rendezvous protocol
//!
//! Rank 0 hosts a [`Rendezvous`] listener at a well-known address (the
//! `--connect` address handed to `cephalo worker`). Establishment runs
//! in three phases, all length-prefixed little-endian:
//!
//! 1. **register** — every rank binds its own *data* listener on an
//!    ephemeral port, then ranks 1..N connect to the rendezvous
//!    address and send `[rank: u64][addr_len: u64][addr bytes]`.
//! 2. **table** — once all N−1 registrations arrive, rank 0 answers
//!    each with the full address table `[world: u64]` +
//!    `world × [len: u64][addr bytes]` (rank 0's data address at
//!    index 0) and drops the rendezvous streams.
//! 3. **mesh** — every pair gets exactly one TCP stream: rank i
//!    connects to the data listener of every j < i (sending
//!    `[i: u64]` as a hello) and accepts one connection from every
//!    j > i. A reader thread per stream drains frames into per-source
//!    FIFO queues, so writes on the protocol path never block on a
//!    slow receiver (the discipline that keeps the ring and migration
//!    loops deadlock-free).
//!
//! Failure semantics are fail-stop: a vanished peer surfaces as an
//! error from the next `send_*`/`recv_*` touching it, never as silent
//! corruption — frames are typed and length-checked.

use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use super::{
    expect_bytes, expect_f32, f32s_from_le_bytes, f32s_to_le_bytes, Frame,
    Transport, TAG_BYTES, TAG_F32,
};
use crate::util::error::{anyhow, Result};

/// Frames above this are a protocol error, not an allocation request.
const MAX_FRAME_BYTES: usize = 1 << 30;
/// Rendezvous/handshake strings above this are rejected.
const MAX_ADDR_BYTES: usize = 4096;
/// Connect retry budget: the listener side binds before advertising,
/// so retries only cover transient refusals (SYN backlog overflow).
const CONNECT_ATTEMPTS: usize = 250;
const CONNECT_BACKOFF: Duration = Duration::from_millis(20);

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_string(r: &mut impl Read) -> Result<String> {
    let len = read_u64(r)? as usize;
    if len > MAX_ADDR_BYTES {
        return Err(anyhow!("handshake string of {len} bytes rejected"));
    }
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    String::from_utf8(b).map_err(|e| anyhow!("bad handshake utf-8: {e}"))
}

fn write_string(w: &mut impl Write, s: &str) -> Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

/// Read one wire frame; `Ok(None)` on a clean EOF at a frame boundary.
fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut tag = [0u8; 1];
    if let Err(e) = r.read_exact(&mut tag) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Ok(None);
        }
        return Err(e.into());
    }
    let len = read_u64(r)? as usize;
    if len > MAX_FRAME_BYTES {
        return Err(anyhow!("oversized frame: {len} bytes"));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    match tag[0] {
        TAG_BYTES => Ok(Some(Frame::Bytes(payload))),
        TAG_F32 => Ok(Some(Frame::F32(f32s_from_le_bytes(&payload)?))),
        t => Err(anyhow!("unknown frame tag {t}")),
    }
}

fn connect_retry(addr: &str) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(CONNECT_BACKOFF);
            }
        }
    }
    Err(anyhow!(
        "could not connect to {addr} after {CONNECT_ATTEMPTS} attempts: {}",
        last.map(|e| e.to_string()).unwrap_or_default()
    ))
}

/// One reader thread per mesh stream: drain frames into the per-source
/// queue until EOF or error (either way the sender drops and `recv_*`
/// reports the peer as gone). Decode errors are logged before the
/// thread exits so a protocol desync is distinguishable from a peer
/// that simply went away.
fn spawn_reader(stream: TcpStream, tx: Sender<Frame>) {
    std::thread::spawn(move || {
        let mut r = BufReader::new(stream);
        loop {
            match read_frame(&mut r) {
                Ok(Some(frame)) => {
                    if tx.send(frame).is_err() {
                        break;
                    }
                }
                Ok(None) => break, // clean EOF at a frame boundary
                Err(e) => {
                    crate::warn!("tcp transport reader stopping: {e}");
                    break;
                }
            }
        }
    });
}

/// Phase-3 mesh formation, shared by rank 0 and workers.
fn mesh(
    rank: usize,
    world: usize,
    table: &[String],
    data_listener: TcpListener,
) -> Result<TcpTransport> {
    let mut inbox = Vec::with_capacity(world);
    let mut senders: Vec<Option<Sender<Frame>>> = Vec::with_capacity(world);
    for _ in 0..world {
        let (tx, rx) = channel();
        senders.push(Some(tx));
        inbox.push(rx);
    }
    let self_tx = senders[rank].take().expect("own sender present");
    let mut peers: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();

    // Connect DOWN the table; the hello names our rank.
    for peer in 0..rank {
        let mut s = connect_retry(&table[peer])?;
        let _ = s.set_nodelay(true);
        write_u64(&mut s, rank as u64)?;
        let tx = senders[peer].take().expect("peer sender unclaimed");
        spawn_reader(s.try_clone()?, tx);
        peers[peer] = Some(s);
    }
    // Accept UP: one stream from every higher rank, identified by its
    // hello.
    for _ in rank + 1..world {
        let (mut s, _) = data_listener.accept()?;
        let _ = s.set_nodelay(true);
        let peer = read_u64(&mut s)? as usize;
        if peer <= rank || peer >= world {
            return Err(anyhow!(
                "mesh hello from unexpected rank {peer} (we are {rank} \
                 of {world})"
            ));
        }
        let tx = senders[peer]
            .take()
            .ok_or_else(|| anyhow!("duplicate mesh stream from rank {peer}"))?;
        spawn_reader(s.try_clone()?, tx);
        peers[peer] = Some(s);
    }
    Ok(TcpTransport { rank, world, peers, inbox, self_tx })
}

/// Rank 0's side of the rendezvous: bind, advertise, establish.
pub struct Rendezvous {
    listener: TcpListener,
    world: usize,
}

impl Rendezvous {
    /// Bind the rendezvous listener (use port 0 for an ephemeral port,
    /// then read the real one back with [`Rendezvous::local_addr`]).
    pub fn bind(addr: &str, world: usize) -> Result<Rendezvous> {
        if world < 1 {
            return Err(anyhow!("world size must be at least 1"));
        }
        let listener = TcpListener::bind(addr)?;
        Ok(Rendezvous { listener, world })
    }

    /// The address workers must `--connect` to.
    pub fn local_addr(&self) -> Result<String> {
        Ok(self.listener.local_addr()?.to_string())
    }

    /// Collect all registrations, broadcast the table, form the mesh;
    /// returns rank 0's endpoint. Blocks until every worker connects.
    pub fn establish(self) -> Result<TcpTransport> {
        let world = self.world;
        let ip = self.listener.local_addr()?.ip();
        let data_listener = TcpListener::bind((ip, 0))?;
        let mut table: Vec<String> = vec![String::new(); world];
        table[0] = data_listener.local_addr()?.to_string();
        let mut pending: Vec<TcpStream> = Vec::with_capacity(world - 1);
        for _ in 1..world {
            let (mut s, _) = self.listener.accept()?;
            let rank = read_u64(&mut s)? as usize;
            if rank == 0 || rank >= world {
                return Err(anyhow!(
                    "registration from invalid rank {rank} (world {world})"
                ));
            }
            if !table[rank].is_empty() {
                return Err(anyhow!("rank {rank} registered twice"));
            }
            table[rank] = read_string(&mut s)?;
            pending.push(s);
        }
        for s in pending.iter_mut() {
            write_u64(s, world as u64)?;
            for a in &table {
                write_string(s, a)?;
            }
        }
        drop(pending);
        mesh(0, world, &table, data_listener)
    }
}

/// A worker rank's side: register with the rendezvous at `addr`, learn
/// the table, form the mesh. `rank` must be in `1..world`.
pub fn connect(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
    if rank == 0 || rank >= world {
        return Err(anyhow!(
            "worker rank must be in 1..{world}, got {rank} (rank 0 is \
             the coordinator)"
        ));
    }
    let mut rz = connect_retry(addr)?;
    let ip = rz.local_addr()?.ip();
    let data_listener = TcpListener::bind((ip, 0))?;
    write_u64(&mut rz, rank as u64)?;
    write_string(&mut rz, &data_listener.local_addr()?.to_string())?;
    let n = read_u64(&mut rz)? as usize;
    if n != world {
        return Err(anyhow!(
            "rendezvous world mismatch: coordinator says {n}, we say {world}"
        ));
    }
    let mut table = Vec::with_capacity(world);
    for _ in 0..world {
        table.push(read_string(&mut rz)?);
    }
    drop(rz);
    mesh(rank, world, &table, data_listener)
}

/// Stand up a full TCP-loopback fabric inside one process, one thread
/// per connecting rank — the shape used by tests and benches (worker
/// PROCESSES use [`Rendezvous`]/[`connect`] directly via
/// `cephalo worker`). `endpoints[r]` has rank `r`.
pub fn thread_fabric(world: usize) -> Result<Vec<Box<dyn Transport>>> {
    let rz = Rendezvous::bind("127.0.0.1:0", world)?;
    let addr = rz.local_addr()?;
    let handles: Vec<_> = (1..world)
        .map(|r| {
            let addr = addr.clone();
            std::thread::spawn(move || connect(&addr, r, world))
        })
        .collect();
    let rank0 = rz.establish()?;
    let mut eps: Vec<Box<dyn Transport>> = Vec::with_capacity(world);
    eps.push(Box::new(rank0));
    for h in handles {
        let t = h
            .join()
            .map_err(|_| anyhow!("rendezvous connect thread panicked"))??;
        eps.push(Box::new(t));
    }
    Ok(eps)
}

/// One rank's endpoint in a TCP mesh.
pub struct TcpTransport {
    rank: usize,
    world: usize,
    /// Write side of the mesh stream to each peer (`None` at our own
    /// index — self-sends short-circuit through `self_tx`).
    peers: Vec<Option<TcpStream>>,
    /// Per-source frame queues fed by the reader threads.
    inbox: Vec<Receiver<Frame>>,
    self_tx: Sender<Frame>,
}

impl TcpTransport {
    fn write_wire(&mut self, to: usize, tag: u8, payload: &[u8]) -> Result<()> {
        if to >= self.world {
            return Err(anyhow!(
                "send to rank {to} out of range (world {})",
                self.world
            ));
        }
        let s = self.peers[to].as_mut().expect("mesh is fully connected");
        let mut header = [0u8; 9];
        header[0] = tag;
        header[1..9].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        s.write_all(&header)?;
        s.write_all(payload)?;
        Ok(())
    }

    fn pull(&mut self, from: usize) -> Result<Frame> {
        if from >= self.world {
            return Err(anyhow!(
                "recv from rank {from} out of range (world {})",
                self.world
            ));
        }
        self.inbox[from]
            .recv()
            .map_err(|_| anyhow!("rank {from} disconnected"))
    }
}

impl Drop for TcpTransport {
    /// Shut both directions of every mesh stream down so OUR reader
    /// threads (which hold `try_clone`d handles of the same sockets)
    /// and the remote peers' readers all observe EOF and exit —
    /// without this, dropped endpoints would strand one blocked
    /// reader thread per peer for the life of the process.
    fn drop(&mut self) {
        for s in self.peers.iter().flatten() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Transport for TcpTransport {
    fn backend(&self) -> &'static str {
        "tcp"
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        if to == self.rank {
            return self
                .self_tx
                .send(Frame::F32(data.to_vec()))
                .map_err(|_| anyhow!("self queue closed"));
        }
        self.write_wire(to, TAG_F32, &f32s_to_le_bytes(data))
    }

    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        let f = self.pull(from)?;
        expect_f32(f, from)
    }

    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        if to == self.rank {
            return self
                .self_tx
                .send(Frame::Bytes(data.to_vec()))
                .map_err(|_| anyhow!("self queue closed"));
        }
        self.write_wire(to, TAG_BYTES, data)
    }

    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        let f = self.pull(from)?;
        expect_bytes(f, from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_mesh_routes_typed_frames() {
        let mut eps = thread_fabric(3).unwrap();
        for (r, ep) in eps.iter().enumerate() {
            assert_eq!(ep.rank(), r);
            assert_eq!(ep.world_size(), 3);
            assert_eq!(ep.backend(), "tcp");
        }
        // Split borrows: drive each endpoint from its own thread.
        std::thread::scope(|s| {
            let mut it = eps.iter_mut();
            let a = it.next().unwrap();
            let b = it.next().unwrap();
            let c = it.next().unwrap();
            s.spawn(move || {
                a.send_f32(1, &[1.0, -0.0]).unwrap();
                a.send_bytes(1, &[7, 8]).unwrap();
                assert_eq!(a.recv_bytes(2).unwrap(), vec![3]);
            });
            s.spawn(move || {
                let xs = b.recv_f32(0).unwrap();
                assert_eq!(xs.len(), 2);
                assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
                assert_eq!(b.recv_bytes(0).unwrap(), vec![7, 8]);
                // Self-send round-trips.
                b.send_f32(1, &[4.5]).unwrap();
                assert_eq!(b.recv_f32(1).unwrap(), vec![4.5]);
            });
            s.spawn(move || {
                c.send_bytes(0, &[3]).unwrap();
            });
        });
    }

    #[test]
    fn barrier_over_sockets_releases_everyone() {
        let eps = thread_fabric(4).unwrap();
        std::thread::scope(|s| {
            for mut ep in eps {
                s.spawn(move || {
                    for _ in 0..2 {
                        ep.barrier().unwrap();
                    }
                });
            }
        });
    }

    #[test]
    fn type_mismatch_is_a_protocol_error() {
        let mut eps = thread_fabric(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[1]).unwrap();
        assert!(b.recv_f32(0).is_err());
        drop(a);
        // Peer gone: recv reports disconnection instead of hanging.
        assert!(b.recv_bytes(0).is_err());
    }

    #[test]
    fn invalid_worker_ranks_are_rejected() {
        assert!(connect("127.0.0.1:1", 0, 4).is_err());
        assert!(connect("127.0.0.1:1", 4, 4).is_err());
        assert!(Rendezvous::bind("127.0.0.1:0", 0).is_err());
    }
}
