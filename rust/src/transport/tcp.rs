//! TCP socket transport (`std::net` only): loopback or LAN ranks with
//! a tiny rendezvous + full-mesh handshake, checksummed framing and
//! heartbeat-based failure detection.
//!
//! ## Rendezvous protocol
//!
//! Rank 0 hosts a [`Rendezvous`] listener at a well-known address (the
//! `--connect` address handed to `cephalo worker`). Establishment runs
//! in three phases, all length-prefixed little-endian:
//!
//! 1. **register** — every rank binds its own *data* listener on an
//!    ephemeral port, then ranks 1..N connect to the rendezvous
//!    address and send `[rank: u64][addr_len: u64][addr bytes]`.
//! 2. **table** — once all N−1 registrations arrive, rank 0 answers
//!    each with the full address table `[world: u64]` +
//!    `world × [len: u64][addr bytes]` (rank 0's data address at
//!    index 0) and drops the rendezvous streams.
//! 3. **mesh** — every pair gets exactly one TCP stream: rank i
//!    connects to the data listener of every j < i (sending
//!    `[i: u64]` as a hello) and accepts one connection from every
//!    j > i. A reader thread per stream drains frames into per-source
//!    FIFO queues, so writes on the protocol path never block on a
//!    slow receiver (the discipline that keeps the ring and migration
//!    loops deadlock-free).
//!
//! The rendezvous endpoint OUTLIVES the workers it meshed:
//! [`Rendezvous::establish`] borrows rather than consumes, so the
//! coordinator can keep the listener bound across a whole session and
//! survivors of a membership change could re-register against the same
//! well-known address (the `DistDriver` holds the endpoint for exactly
//! this reason).
//!
//! ## Failure semantics (v2)
//!
//! Every data frame carries a per-lane sequence number and a CRC32
//! trailer (layout in `transport` module docs). A heartbeat thread per
//! endpoint writes a `TAG_HB` frame to every peer roughly every
//! [`HEARTBEAT_EVERY`]; arrival of ANY frame feeds the shared
//! [`FailureDetector`], whose verdicts surface through
//! [`Transport::peer_closed`]. Detection is layered:
//!
//! * **hard** — EOF, reset, a CRC mismatch or a sequence gap kills the
//!   reader thread, which marks the peer closed. A corrupt frame is
//!   therefore EXACTLY as fatal as a crash, never a silent bad
//!   gradient (typed as [`TransportError::Corrupt`]).
//! * **soft** — a peer silent past the detector's suspicion threshold
//!   (no heartbeats, no data) is suspected even while its socket looks
//!   open — the kill -9 case where FIN never arrives.
//!
//! Retry policy: CONNECTION establishment retries with exponential
//! backoff under a deadline ([`connect_retry`]). In-stream frame
//! writes are single-attempt under a write timeout: a partially
//! written frame cannot be resumed (the receiver's CRC + sequence
//! checks would reject any resync), so a failed or timed-out write
//! marks the peer closed and fails fast into the session's recovery
//! path instead of retrying blind.

use std::io::{BufReader, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::{
    crc32, crc32_update, expect_bytes, expect_f32, f32s_from_le_bytes,
    f32s_to_le_bytes, FailureDetector, Frame, Transport, TransportError,
    CRC32_INIT, TAG_BYTES, TAG_F32,
};
use crate::telemetry;
use crate::transport::failure::DEFAULT_SUSPECT_AFTER_MS;
use crate::util::error::{anyhow, Result};

/// Wire tag for heartbeat frames (empty payload, seq 0; consumed by
/// the reader thread, never surfaced to `recv_*`).
pub const TAG_HB: u8 = 2;

/// Frames above this are a protocol error, not an allocation request.
const MAX_FRAME_BYTES: usize = 1 << 30;
/// Rendezvous/handshake strings above this are rejected.
const MAX_ADDR_BYTES: usize = 4096;
/// Connection-establishment retry policy: exponential backoff from
/// [`CONNECT_BACKOFF_START`] doubling to [`CONNECT_BACKOFF_MAX`],
/// bounded by a total deadline.
const CONNECT_DEADLINE: Duration = Duration::from_secs(10);
const CONNECT_BACKOFF_START: Duration = Duration::from_millis(10);
const CONNECT_BACKOFF_MAX: Duration = Duration::from_millis(500);
/// Heartbeat cadence; the detector's suspicion threshold
/// (`DEFAULT_SUSPECT_AFTER_MS`) tolerates ~40 missed beats.
const HEARTBEAT_EVERY: Duration = Duration::from_millis(50);
/// In-stream writes time out after this (a peer that stopped reading
/// with a full receive buffer must not wedge the sender forever).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// `resend_last` caches the last frame per lane only up to this size —
/// duplicate injection targets command traffic, not bulk tensors.
const DUP_CACHE_MAX_BYTES: usize = 1 << 16;

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_string(r: &mut impl Read) -> Result<String> {
    let len = read_u64(r)? as usize;
    if len > MAX_ADDR_BYTES {
        return Err(anyhow!("handshake string of {len} bytes rejected"));
    }
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    String::from_utf8(b).map_err(|e| anyhow!("bad handshake utf-8: {e}"))
}

fn write_string(w: &mut impl Write, s: &str) -> Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

/// Assemble a v2 wire frame:
/// `[tag][seq u64 LE][len u64 LE][payload][crc32 u32 LE]`, the CRC
/// covering everything before it. Public so the fault-injection tests
/// can record and replay real frames.
pub fn encode_wire_frame(tag: u8, seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(17 + payload.len() + 4);
    out.push(tag);
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// `write_all` for a frame split across three regions, as vectored
/// writes (the kernel sees header + payload + CRC in one syscall on
/// the happy path). Partial writes retry by re-slicing each region's
/// unsent suffix — a stable-Rust stand-in for `write_all_vectored`.
fn write_frame_vectored(
    w: &mut impl Write,
    header: &[u8],
    payload: &[u8],
    trailer: &[u8],
) -> std::io::Result<()> {
    let parts = [header, payload, trailer];
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut off = 0usize;
    while off < total {
        let mut bufs = Vec::with_capacity(3);
        let mut skip = off;
        for p in parts {
            if skip >= p.len() {
                skip -= p.len();
                continue;
            }
            bufs.push(IoSlice::new(&p[skip..]));
            skip = 0;
        }
        match w.write_vectored(&bufs) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write whole frame",
                ));
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Parse and verify one complete v2 wire frame. Returns the typed
/// [`TransportError`] directly (no opaque wrapping) so corruption is
/// distinguishable from every other failure at the layer that found
/// it: a bad CRC is [`TransportError::Corrupt`], a malformed envelope
/// is [`TransportError::Protocol`].
pub fn decode_wire_frame(
    buf: &[u8],
    from: usize,
) -> std::result::Result<(u8, u64, Vec<u8>), TransportError> {
    if buf.len() < 21 {
        return Err(TransportError::Protocol {
            detail: format!("frame of {} bytes is below minimum 21", buf.len()),
        });
    }
    let tag = buf[0];
    let seq = u64::from_le_bytes(buf[1..9].try_into().expect("8 bytes"));
    let len = u64::from_le_bytes(buf[9..17].try_into().expect("8 bytes"));
    let len = len as usize;
    if len > MAX_FRAME_BYTES || buf.len() != 17 + len + 4 {
        return Err(TransportError::Protocol {
            detail: format!(
                "frame length {len} does not match envelope of {} bytes",
                buf.len()
            ),
        });
    }
    let body = &buf[..17 + len];
    let expected =
        u32::from_le_bytes(buf[17 + len..].try_into().expect("4 bytes"));
    let got = crc32(body);
    if got != expected {
        telemetry::counters().crc_failures.fetch_add(1, Ordering::Relaxed);
        return Err(TransportError::Corrupt { from, expected, got });
    }
    Ok((tag, seq, buf[17..17 + len].to_vec()))
}

/// Read one wire frame off a stream; `Ok(None)` on a clean EOF at a
/// frame boundary. CRC and envelope verification run through
/// [`decode_wire_frame`], so the error text carries the typed variant.
fn read_wire_frame(
    r: &mut impl Read,
    from: usize,
) -> Result<Option<(u8, u64, Vec<u8>)>> {
    let mut first = [0u8; 1];
    if let Err(e) = r.read_exact(&mut first) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Ok(None);
        }
        return Err(e.into());
    }
    let mut rest = [0u8; 16];
    r.read_exact(&mut rest)?;
    let len = u64::from_le_bytes(rest[8..16].try_into().expect("8 bytes"));
    if len as usize > MAX_FRAME_BYTES {
        return Err(anyhow!("oversized frame: {len} bytes"));
    }
    let total = 17 + len as usize + 4;
    let mut buf = vec![0u8; total];
    buf[0] = first[0];
    buf[1..17].copy_from_slice(&rest);
    r.read_exact(&mut buf[17..])?;
    let (tag, seq, payload) = decode_wire_frame(&buf, from)?;
    Ok(Some((tag, seq, payload)))
}

fn frame_from_parts(tag: u8, payload: Vec<u8>) -> Result<Frame> {
    match tag {
        TAG_BYTES => Ok(Frame::Bytes(payload)),
        TAG_F32 => Ok(Frame::F32(f32s_from_le_bytes(&payload)?)),
        t => Err(anyhow!("unknown frame tag {t}")),
    }
}

/// Bounded-retry connect with exponential backoff (the listener side
/// binds before advertising, so retries cover transient refusals and
/// slow-to-schedule peers, not indefinite absence).
fn connect_retry(addr: &str) -> Result<TcpStream> {
    let start = Instant::now();
    let mut backoff = CONNECT_BACKOFF_START;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if start.elapsed() >= CONNECT_DEADLINE {
                    return Err(anyhow!(
                        "could not connect to {addr} within {:?}: {e}",
                        CONNECT_DEADLINE
                    ));
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(CONNECT_BACKOFF_MAX);
            }
        }
    }
}

/// One reader thread per mesh stream: drain frames into the per-source
/// queue until EOF or error, feeding the failure detector on every
/// arrival. Heartbeats are consumed here; data frames are dedup'd by
/// sequence number (duplicate ⇒ dropped, gap ⇒ fatal). Any exit path
/// marks the peer closed — EOF, reset, CRC mismatch and sequence gaps
/// all funnel into the same "peer is gone" verdict.
fn spawn_reader(
    stream: TcpStream,
    from: usize,
    tx: Sender<Frame>,
    detector: Arc<FailureDetector>,
    epoch: Instant,
) {
    std::thread::spawn(move || {
        let mut r = BufReader::new(stream);
        let mut last_seq = 0u64;
        loop {
            match read_wire_frame(&mut r, from) {
                Ok(Some((tag, seq, payload))) => {
                    detector.beat(from, epoch.elapsed().as_millis() as u64);
                    let c = telemetry::counters();
                    c.tcp_frames_recv.fetch_add(1, Ordering::Relaxed);
                    c.tcp_bytes_recv
                        .fetch_add(21 + payload.len() as u64, Ordering::Relaxed);
                    if tag == TAG_HB {
                        c.heartbeats_recv.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    if seq <= last_seq {
                        // A re-transmitted frame: already delivered.
                        c.seq_dedup_drops.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    if seq != last_seq + 1 {
                        let e = TransportError::SeqGap {
                            from,
                            expected: last_seq + 1,
                            got: seq,
                        };
                        crate::warn!("tcp transport reader stopping: {e}");
                        break;
                    }
                    last_seq = seq;
                    let frame = match frame_from_parts(tag, payload) {
                        Ok(f) => f,
                        Err(e) => {
                            crate::warn!(
                                "tcp transport reader stopping: {e}"
                            );
                            break;
                        }
                    };
                    if tx.send(frame).is_err() {
                        break;
                    }
                }
                Ok(None) => break, // clean EOF at a frame boundary
                Err(e) => {
                    crate::warn!("tcp transport reader stopping: {e}");
                    break;
                }
            }
        }
        detector.mark_closed(from);
    });
}

/// One outgoing lane: the shared write half plus per-lane tx state.
/// The stream is behind a mutex because the heartbeat thread writes
/// concurrently with protocol sends; every frame goes out as ONE
/// pre-assembled `write_all` under the lock, so frames never
/// interleave.
struct TxLane {
    stream: Arc<Mutex<TcpStream>>,
    tx_seq: u64,
    /// Last command-sized frame, byte-for-byte (same seq), for
    /// duplicate-frame fault injection.
    last_frame: Option<Vec<u8>>,
    corrupt_next: bool,
}

/// Phase-3 mesh formation, shared by rank 0 and workers.
fn mesh(
    rank: usize,
    world: usize,
    table: &[String],
    data_listener: TcpListener,
) -> Result<TcpTransport> {
    let mut inbox = Vec::with_capacity(world);
    let mut senders: Vec<Option<Sender<Frame>>> = Vec::with_capacity(world);
    for _ in 0..world {
        let (tx, rx) = channel();
        senders.push(Some(tx));
        inbox.push(rx);
    }
    let self_tx = senders[rank].take().expect("own sender present");
    let detector =
        Arc::new(FailureDetector::new(world, DEFAULT_SUSPECT_AFTER_MS));
    let epoch = Instant::now();
    let mut lanes: Vec<Option<TxLane>> = (0..world).map(|_| None).collect();

    let mut install = |peer: usize, s: TcpStream| -> Result<()> {
        let _ = s.set_nodelay(true);
        let _ = s.set_write_timeout(Some(WRITE_TIMEOUT));
        let tx = senders[peer]
            .take()
            .ok_or_else(|| anyhow!("duplicate mesh stream from rank {peer}"))?;
        spawn_reader(s.try_clone()?, peer, tx, Arc::clone(&detector), epoch);
        lanes[peer] = Some(TxLane {
            stream: Arc::new(Mutex::new(s)),
            tx_seq: 0,
            last_frame: None,
            corrupt_next: false,
        });
        Ok(())
    };

    // Connect DOWN the table; the hello names our rank.
    for peer in 0..rank {
        let mut s = connect_retry(&table[peer])?;
        write_u64(&mut s, rank as u64)?;
        install(peer, s)?;
    }
    // Accept UP: one stream from every higher rank, identified by its
    // hello.
    for _ in rank + 1..world {
        let (mut s, _) = data_listener.accept()?;
        let peer = read_u64(&mut s)? as usize;
        if peer <= rank || peer >= world {
            return Err(anyhow!(
                "mesh hello from unexpected rank {peer} (we are {rank} \
                 of {world})"
            ));
        }
        install(peer, s)?;
    }

    // One heartbeat thread per endpoint, ticking every lane.
    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb_frame = encode_wire_frame(TAG_HB, 0, &[]);
    let hb_lanes: Vec<(usize, Arc<Mutex<TcpStream>>)> = lanes
        .iter()
        .enumerate()
        .filter_map(|(p, l)| {
            l.as_ref().map(|l| (p, Arc::clone(&l.stream)))
        })
        .collect();
    let hb_thread = {
        let stop = Arc::clone(&hb_stop);
        let detector = Arc::clone(&detector);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                for (peer, stream) in &hb_lanes {
                    if detector.is_closed(*peer) {
                        continue;
                    }
                    if let Ok(mut s) = stream.lock() {
                        if s.write_all(&hb_frame).is_err() {
                            detector.mark_closed(*peer);
                        } else {
                            telemetry::counters()
                                .heartbeats_sent
                                .fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                std::thread::sleep(HEARTBEAT_EVERY);
            }
        })
    };

    Ok(TcpTransport {
        rank,
        world,
        lanes,
        inbox,
        self_tx,
        detector,
        epoch,
        hb_stop,
        hb_thread: Some(hb_thread),
    })
}

/// Rank 0's side of the rendezvous: bind, advertise, establish.
pub struct Rendezvous {
    listener: TcpListener,
    world: usize,
}

impl Rendezvous {
    /// Bind the rendezvous listener (use port 0 for an ephemeral port,
    /// then read the real one back with [`Rendezvous::local_addr`]).
    pub fn bind(addr: &str, world: usize) -> Result<Rendezvous> {
        if world < 1 {
            return Err(anyhow!("world size must be at least 1"));
        }
        let listener = TcpListener::bind(addr)?;
        Ok(Rendezvous { listener, world })
    }

    /// The address workers must `--connect` to.
    pub fn local_addr(&self) -> Result<String> {
        Ok(self.listener.local_addr()?.to_string())
    }

    /// Collect all registrations, broadcast the table, form the mesh;
    /// returns rank 0's endpoint. Blocks until every worker connects.
    /// Borrows rather than consumes: the endpoint stays bound, so it
    /// outlives the mesh it formed and can establish again after a
    /// membership change.
    pub fn establish(&self) -> Result<TcpTransport> {
        let world = self.world;
        let ip = self.listener.local_addr()?.ip();
        let data_listener = TcpListener::bind((ip, 0))?;
        let mut table: Vec<String> = vec![String::new(); world];
        table[0] = data_listener.local_addr()?.to_string();
        let mut pending: Vec<TcpStream> = Vec::with_capacity(world - 1);
        for _ in 1..world {
            let (mut s, _) = self.listener.accept()?;
            let rank = read_u64(&mut s)? as usize;
            if rank == 0 || rank >= world {
                return Err(anyhow!(
                    "registration from invalid rank {rank} (world {world})"
                ));
            }
            if !table[rank].is_empty() {
                return Err(anyhow!("rank {rank} registered twice"));
            }
            table[rank] = read_string(&mut s)?;
            pending.push(s);
        }
        for s in pending.iter_mut() {
            write_u64(s, world as u64)?;
            for a in &table {
                write_string(s, a)?;
            }
        }
        drop(pending);
        mesh(0, world, &table, data_listener)
    }
}

/// A worker rank's side: register with the rendezvous at `addr`, learn
/// the table, form the mesh. `rank` must be in `1..world`.
pub fn connect(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
    if rank == 0 || rank >= world {
        return Err(anyhow!(
            "worker rank must be in 1..{world}, got {rank} (rank 0 is \
             the coordinator)"
        ));
    }
    let mut rz = connect_retry(addr)?;
    let ip = rz.local_addr()?.ip();
    let data_listener = TcpListener::bind((ip, 0))?;
    write_u64(&mut rz, rank as u64)?;
    write_string(&mut rz, &data_listener.local_addr()?.to_string())?;
    let n = read_u64(&mut rz)? as usize;
    if n != world {
        return Err(anyhow!(
            "rendezvous world mismatch: coordinator says {n}, we say {world}"
        ));
    }
    let mut table = Vec::with_capacity(world);
    for _ in 0..world {
        table.push(read_string(&mut rz)?);
    }
    drop(rz);
    mesh(rank, world, &table, data_listener)
}

/// Stand up a full TCP-loopback fabric inside one process, one thread
/// per connecting rank — the shape used by tests and benches (worker
/// PROCESSES use [`Rendezvous`]/[`connect`] directly via
/// `cephalo worker`). `endpoints[r]` has rank `r`.
pub fn thread_fabric(world: usize) -> Result<Vec<Box<dyn Transport>>> {
    let rz = Rendezvous::bind("127.0.0.1:0", world)?;
    let addr = rz.local_addr()?;
    let handles: Vec<_> = (1..world)
        .map(|r| {
            let addr = addr.clone();
            std::thread::spawn(move || connect(&addr, r, world))
        })
        .collect();
    let rank0 = rz.establish()?;
    let mut eps: Vec<Box<dyn Transport>> = Vec::with_capacity(world);
    eps.push(Box::new(rank0));
    for h in handles {
        let t = h
            .join()
            .map_err(|_| anyhow!("rendezvous connect thread panicked"))??;
        eps.push(Box::new(t));
    }
    Ok(eps)
}

/// One rank's endpoint in a TCP mesh.
pub struct TcpTransport {
    rank: usize,
    world: usize,
    /// Write lane to each peer (`None` at our own index — self-sends
    /// short-circuit through `self_tx`).
    lanes: Vec<Option<TxLane>>,
    /// Per-source frame queues fed by the reader threads.
    inbox: Vec<Receiver<Frame>>,
    self_tx: Sender<Frame>,
    /// Shared liveness verdicts (readers + heartbeat thread + us).
    detector: Arc<FailureDetector>,
    /// Zero point of the detector's millisecond clock.
    epoch: Instant,
    hb_stop: Arc<AtomicBool>,
    hb_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpTransport {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn write_wire(&mut self, to: usize, tag: u8, payload: &[u8]) -> Result<()> {
        if to >= self.world {
            return Err(anyhow!(
                "send to rank {to} out of range (world {})",
                self.world
            ));
        }
        if self.detector.is_closed(to) {
            return Err(TransportError::PeerClosed { rank: to }.into());
        }
        let lane = self.lanes[to].as_mut().expect("mesh is fully connected");
        lane.tx_seq += 1;
        let seq = lane.tx_seq;
        let framed = 17 + payload.len() + 4;
        if framed <= DUP_CACHE_MAX_BYTES || lane.corrupt_next {
            // Command-sized traffic (and fault injection, which must
            // flip a byte of the ASSEMBLED frame): contiguous path, so
            // the exact bytes can be cached for `resend_last`.
            let mut buf = encode_wire_frame(tag, seq, payload);
            if lane.corrupt_next {
                lane.corrupt_next = false;
                // Flip one payload byte AFTER the CRC was computed, so
                // the receiver's check must fire; empty payloads flip
                // the tag.
                let idx = if payload.is_empty() { 0 } else { 17 };
                buf[idx] ^= 0x01;
            }
            lane.last_frame =
                (buf.len() <= DUP_CACHE_MAX_BYTES).then(|| buf.clone());
            let mut s = lane
                .stream
                .lock()
                .map_err(|_| anyhow!("lane {to} mutex poisoned"))?;
            if let Err(e) = s.write_all(&buf) {
                // Single-attempt policy (see module docs): a failed or
                // timed-out frame write is unrecoverable mid-stream.
                self.detector.mark_closed(to);
                return Err(anyhow!("send to rank {to} failed: {e}"));
            }
            let c = telemetry::counters();
            c.tcp_frames_sent.fetch_add(1, Ordering::Relaxed);
            c.tcp_bytes_sent.fetch_add(buf.len() as u64, Ordering::Relaxed);
            return Ok(());
        }
        // Bulk tensor frame: stream the CRC over header + payload and
        // issue a vectored write of the three regions — the payload is
        // never copied into a frame-sized staging buffer. Bulk frames
        // were never dup-cached (see `DUP_CACHE_MAX_BYTES`).
        lane.last_frame = None;
        let mut header = [0u8; 17];
        header[0] = tag;
        header[1..9].copy_from_slice(&seq.to_le_bytes());
        header[9..17]
            .copy_from_slice(&(payload.len() as u64).to_le_bytes());
        let crc = !crc32_update(crc32_update(CRC32_INIT, &header), payload);
        let trailer = crc.to_le_bytes();
        let mut s = lane
            .stream
            .lock()
            .map_err(|_| anyhow!("lane {to} mutex poisoned"))?;
        if let Err(e) =
            write_frame_vectored(&mut *s, &header, payload, &trailer)
        {
            self.detector.mark_closed(to);
            return Err(anyhow!("send to rank {to} failed: {e}"));
        }
        let c = telemetry::counters();
        c.tcp_frames_sent.fetch_add(1, Ordering::Relaxed);
        c.tcp_bytes_sent
            .fetch_add(21 + payload.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn pull(&mut self, from: usize) -> Result<Frame> {
        if from >= self.world {
            return Err(anyhow!(
                "recv from rank {from} out of range (world {})",
                self.world
            ));
        }
        self.inbox[from]
            .recv()
            .map_err(|_| TransportError::PeerClosed { rank: from }.into())
    }

    fn close_impl(&mut self) {
        self.hb_stop.store(true, Ordering::Release);
        if let Some(h) = self.hb_thread.take() {
            let _ = h.join();
        }
        // Shut both directions of every mesh stream down so OUR reader
        // threads (which hold `try_clone`d handles of the same
        // sockets) and the remote peers' readers all observe EOF and
        // exit — without this, dropped endpoints would strand one
        // blocked reader thread per peer for the life of the process.
        for lane in self.lanes.iter().flatten() {
            if let Ok(s) = lane.stream.lock() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.close_impl();
    }
}

impl Transport for TcpTransport {
    fn backend(&self) -> &'static str {
        "tcp"
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        if to == self.rank {
            return self
                .self_tx
                .send(Frame::F32(data.to_vec()))
                .map_err(|_| anyhow!("self queue closed"));
        }
        self.write_wire(to, TAG_F32, &f32s_to_le_bytes(data))
    }

    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        let f = self.pull(from)?;
        expect_f32(f, from)
    }

    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        if to == self.rank {
            return self
                .self_tx
                .send(Frame::Bytes(data.to_vec()))
                .map_err(|_| anyhow!("self queue closed"));
        }
        self.write_wire(to, TAG_BYTES, data)
    }

    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        let f = self.pull(from)?;
        expect_bytes(f, from)
    }

    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        if from >= self.world {
            return Err(anyhow!(
                "recv from rank {from} out of range (world {})",
                self.world
            ));
        }
        match self.inbox[from].recv_timeout(Duration::from_millis(timeout_ms))
        {
            Ok(f) => expect_bytes(f, from).map(Some),
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => Ok(None),
        }
    }

    fn peer_closed(&self, rank: usize) -> bool {
        self.detector.is_closed(rank)
            || self.detector.suspected(rank, self.now_ms())
    }

    fn peer_failed(&self, rank: usize) -> bool {
        // Hard evidence only: an EOF/reset/corrupt lane is gone for
        // good, but heartbeat silence (`suspected`) may be a transient
        // partition — the rejoin window decides its fate.
        self.detector.is_closed(rank)
    }

    fn close(&mut self) {
        self.close_impl();
    }

    fn resend_last(&mut self, to: usize) -> Result<()> {
        if to >= self.world || to == self.rank {
            return Ok(());
        }
        let lane = self.lanes[to].as_mut().expect("mesh is fully connected");
        let Some(buf) = lane.last_frame.clone() else {
            return Ok(()); // nothing cached (bulk frame or no sends yet)
        };
        let mut s = lane
            .stream
            .lock()
            .map_err(|_| anyhow!("lane {to} mutex poisoned"))?;
        if let Err(e) = s.write_all(&buf) {
            self.detector.mark_closed(to);
            return Err(anyhow!("resend to rank {to} failed: {e}"));
        }
        let c = telemetry::counters();
        c.resends.fetch_add(1, Ordering::Relaxed);
        c.tcp_frames_sent.fetch_add(1, Ordering::Relaxed);
        c.tcp_bytes_sent.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn corrupt_next_send(&mut self, to: usize) {
        if to < self.world && to != self.rank {
            if let Some(lane) = self.lanes[to].as_mut() {
                lane.corrupt_next = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_mesh_routes_typed_frames() {
        let mut eps = thread_fabric(3).unwrap();
        for (r, ep) in eps.iter().enumerate() {
            assert_eq!(ep.rank(), r);
            assert_eq!(ep.world_size(), 3);
            assert_eq!(ep.backend(), "tcp");
        }
        // Split borrows: drive each endpoint from its own thread.
        std::thread::scope(|s| {
            let mut it = eps.iter_mut();
            let a = it.next().unwrap();
            let b = it.next().unwrap();
            let c = it.next().unwrap();
            s.spawn(move || {
                a.send_f32(1, &[1.0, -0.0]).unwrap();
                a.send_bytes(1, &[7, 8]).unwrap();
                assert_eq!(a.recv_bytes(2).unwrap(), vec![3]);
            });
            s.spawn(move || {
                let xs = b.recv_f32(0).unwrap();
                assert_eq!(xs.len(), 2);
                assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
                assert_eq!(b.recv_bytes(0).unwrap(), vec![7, 8]);
                // Self-send round-trips.
                b.send_f32(1, &[4.5]).unwrap();
                assert_eq!(b.recv_f32(1).unwrap(), vec![4.5]);
            });
            s.spawn(move || {
                c.send_bytes(0, &[3]).unwrap();
            });
        });
    }

    #[test]
    fn barrier_over_sockets_releases_everyone() {
        let eps = thread_fabric(4).unwrap();
        std::thread::scope(|s| {
            for mut ep in eps {
                s.spawn(move || {
                    for _ in 0..2 {
                        ep.barrier().unwrap();
                    }
                });
            }
        });
    }

    #[test]
    fn type_mismatch_is_a_protocol_error() {
        let mut eps = thread_fabric(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[1]).unwrap();
        assert!(b.recv_f32(0).is_err());
        drop(a);
        // Peer gone: recv reports disconnection instead of hanging.
        assert!(b.recv_bytes(0).is_err());
    }

    #[test]
    fn invalid_worker_ranks_are_rejected() {
        assert!(connect("127.0.0.1:1", 0, 4).is_err());
        assert!(connect("127.0.0.1:1", 4, 4).is_err());
        assert!(Rendezvous::bind("127.0.0.1:0", 0).is_err());
    }

    #[test]
    fn wire_frames_round_trip_and_reject_corruption() {
        // Satellite 3, unit scope: record a real frame, corrupt one
        // byte, and the decode failure is the TYPED corrupt variant —
        // not a panic, not a silently wrong payload.
        let payload = vec![5u8, 6, 7, 8];
        let buf = encode_wire_frame(TAG_BYTES, 42, &payload);
        let (tag, seq, body) = decode_wire_frame(&buf, 1).unwrap();
        assert_eq!((tag, seq), (TAG_BYTES, 42));
        assert_eq!(body, payload);

        for idx in 0..buf.len() {
            let mut bad = buf.clone();
            bad[idx] ^= 0x10;
            let err = decode_wire_frame(&bad, 1).unwrap_err();
            assert!(
                matches!(
                    err,
                    TransportError::Corrupt { from: 1, .. }
                        | TransportError::Protocol { .. }
                ),
                "byte {idx}: unexpected error {err}"
            );
        }
        // A truncated envelope is a protocol error, not a CRC error.
        assert!(matches!(
            decode_wire_frame(&buf[..10], 0).unwrap_err(),
            TransportError::Protocol { .. }
        ));
    }

    #[test]
    fn vectored_writer_survives_short_writes() {
        // A sink that accepts ONE byte per call forces the re-slicing
        // path on every boundary, including mid-region and
        // region-straddling offsets.
        struct OneByte(Vec<u8>);
        impl Write for OneByte {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                if buf.is_empty() {
                    return Ok(0);
                }
                self.0.push(buf[0]);
                Ok(1)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w = OneByte(Vec::new());
        write_frame_vectored(&mut w, &[1, 2], &[3, 4, 5], &[6]).unwrap();
        assert_eq!(w.0, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn bulk_frames_take_the_vectored_path_and_skip_the_dup_cache() {
        // 20k f32s = 80 KB payload, well past DUP_CACHE_MAX_BYTES: the
        // frame goes out as header + payload + CRC vectored regions
        // and must arrive bit-exact. Bulk frames are not dup-cached,
        // so a following resend_last is a no-op, and the next
        // command frame's sequence number still lines up.
        let mut eps = thread_fabric(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let big: Vec<f32> = (0..20_000).map(|i| i as f32 * 0.5).collect();
        a.send_f32(1, &big).unwrap();
        assert_eq!(b.recv_f32(0).unwrap(), big);
        a.resend_last(1).unwrap();
        a.send_bytes(1, &[1]).unwrap();
        assert_eq!(b.recv_bytes(0).unwrap(), vec![1]);
        assert_eq!(b.recv_bytes_timeout(0, 50).unwrap(), None);
    }

    #[test]
    fn fabric_counters_track_tcp_traffic() {
        let before = telemetry::counters().snapshot();
        let mut eps = thread_fabric(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[1, 2, 3]).unwrap();
        assert_eq!(b.recv_bytes(0).unwrap(), vec![1, 2, 3]);
        a.resend_last(1).unwrap();
        let after = telemetry::counters().snapshot();
        assert!(after["tcp_frames_sent"] > before["tcp_frames_sent"]);
        assert!(after["tcp_bytes_sent"] >= before["tcp_bytes_sent"] + 24);
        assert!(after["tcp_frames_recv"] > before["tcp_frames_recv"]);
        assert!(after["tcp_bytes_recv"] > before["tcp_bytes_recv"]);
        assert!(after["resends"] > before["resends"]);
    }

    #[test]
    fn duplicate_frames_are_dropped_by_sequence_dedup() {
        let mut eps = thread_fabric(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[1]).unwrap();
        a.resend_last(1).unwrap(); // same bytes, same seq
        a.send_bytes(1, &[2]).unwrap();
        assert_eq!(b.recv_bytes(0).unwrap(), vec![1]);
        assert_eq!(b.recv_bytes(0).unwrap(), vec![2]);
        // The duplicate was dropped, not queued.
        assert_eq!(b.recv_bytes_timeout(0, 50).unwrap(), None);
    }

    #[test]
    fn corrupt_frame_kills_the_lane_and_marks_the_peer_dead() {
        let mut eps = thread_fabric(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.corrupt_next_send(1);
        a.send_bytes(1, &[9, 9, 9]).unwrap();
        // b's reader hits the CRC mismatch: the frame never surfaces,
        // the lane closes, and the peer is declared dead.
        assert!(b.recv_bytes(0).is_err());
        assert!(b.peer_closed(0), "corruption must mark the peer closed");
    }

    #[test]
    fn heartbeats_keep_idle_peers_alive_and_eof_marks_them_dead() {
        let mut eps = thread_fabric(2).unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        // Idle well past several heartbeat intervals: still alive.
        std::thread::sleep(Duration::from_millis(150));
        assert!(!a.peer_closed(1));
        assert!(!b.peer_closed(0));
        // Dropping b closes its sockets; a's reader sees EOF.
        drop(b);
        let t0 = Instant::now();
        while !a.peer_closed(1) {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "EOF never surfaced as peer_closed"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
