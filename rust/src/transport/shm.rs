//! Shared-memory transport — the same-host fast path.
//!
//! Same-host ranks talking over loopback TCP pay syscalls, kernel
//! copies and NIC-stack latency for what is ultimately a memcpy. This
//! backend carries the typed frame protocol over file-backed mmap ring
//! buffers instead: one SPSC ring per (src, dst) lane, living in a
//! per-fabric directory under `/dev/shm` (tmpfs — pages never touch a
//! disk), attachable from any process on the host.
//!
//! ## Ring layout
//!
//! Each lane file is `[tail u64][head u64][closed u32]` (each on its
//! own cache line) followed by `LANE_CAP` data bytes. `tail` counts
//! bytes ever published (writer-owned), `head` bytes ever consumed
//! (reader-owned) — both monotone, positions are `offset % LANE_CAP`,
//! seqlock-style: the writer copies payload first and release-stores
//! `tail`; the reader acquire-loads `tail` before touching data. The
//! ring is a byte STREAM, so frames larger than the ring flow through
//! in chunks with the writer and reader overlapped. Waits are
//! futex-free: a bounded spin, then `yield_now` — same-host wakeups
//! are tens of nanoseconds, a futex syscall costs more than the wait.
//!
//! ## Frames
//!
//! In-ring framing is `[tag u8][len u64 LE][payload]` — the
//! [`encode_frame`] wire layout. No CRC and no sequence numbers: the
//! "wire" is host memory, there is no lossy middle to checksum
//! against, so `resend_last`/`corrupt_next_send` are no-ops (like the
//! channel fabric) and duplicate-frame chaos injection is trivially
//! invisible. Everything above the framing — FIFO per lane,
//! self-sends, typed-frame desync errors, barrier — matches the other
//! backends bitwise (DESIGN.md invariant 10).
//!
//! Liveness: `close` release-stores the `closed` flag on every
//! outbound lane, so a peer blocked in `recv_*` wakes with
//! [`TransportError::PeerClosed`] once the stream drains. A SIGKILLed
//! process never sets the flag — pure-shm fabrics rely on cooperative
//! close (thread workers, chaos `CrashMode::Error`); the hybrid
//! fabric keeps TCP heartbeats for real crash detection.
//!
//! Dependency-free like the std-only backends: the two syscalls this
//! needs (`mmap`/`munmap`) are declared as raw `libc` externs — std
//! already links libc on every unix target.

use std::fs::OpenOptions;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::{
    expect_bytes, expect_f32, f32s_from_le_bytes, f32s_to_le_bytes, Frame,
    Transport, TransportError, TAG_BYTES, TAG_F32,
};
use crate::util::error::{anyhow, Result};

/// Data capacity of one lane ring in bytes. Larger frames stream
/// through in chunks; 2 MiB keeps a 4-rank full mesh (16 lanes) at a
/// comfortable 32 MiB of tmpfs.
pub const LANE_CAP: usize = 1 << 21;

/// Header region: tail / head / closed, each on its own 64-byte line.
const HDR: usize = 256;
const OFF_TAIL: usize = 0;
const OFF_HEAD: usize = 64;
const OFF_CLOSED: usize = 128;

/// Hard bound on one frame, matching the TCP fabric's sanity check.
const MAX_FRAME_BYTES: u64 = 1 << 30;

mod ffi {
    use std::os::raw::{c_int, c_void};
    pub const PROT_READ: c_int = 0x1;
    pub const PROT_WRITE: c_int = 0x2;
    pub const MAP_SHARED: c_int = 0x01;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// One endpoint's view of one lane file: the mmap'd header + data
/// region. Both ends of a lane attach the same file; creation is
/// idempotent (`O_CREAT` without `O_EXCL` + same-size `set_len`), so
/// neither side needs to win a race to go first.
struct Ring {
    base: *mut u8,
    map_len: usize,
    path: PathBuf,
}

// The mapping is plain shared memory driven through atomics; moving
// the raw pointer to another thread is safe (endpoints take &mut for
// all I/O, so a Ring is never used from two threads at once).
unsafe impl Send for Ring {}

impl Ring {
    fn attach(path: &Path) -> Result<Ring> {
        use std::os::unix::io::AsRawFd;
        let map_len = HDR + LANE_CAP;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| anyhow!("shm lane {}: {e}", path.display()))?;
        file.set_len(map_len as u64)
            .map_err(|e| anyhow!("shm lane {}: {e}", path.display()))?;
        let base = unsafe {
            ffi::mmap(
                std::ptr::null_mut(),
                map_len,
                ffi::PROT_READ | ffi::PROT_WRITE,
                ffi::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if base == ffi::map_failed() {
            return Err(anyhow!(
                "mmap of shm lane {} failed",
                path.display()
            ));
        }
        Ok(Ring { base: base as *mut u8, map_len, path: path.to_path_buf() })
    }

    fn word(&self, off: usize) -> &AtomicU64 {
        unsafe { &*(self.base.add(off) as *const AtomicU64) }
    }

    fn tail(&self) -> &AtomicU64 {
        self.word(OFF_TAIL)
    }

    fn head(&self) -> &AtomicU64 {
        self.word(OFF_HEAD)
    }

    fn closed(&self) -> &AtomicU32 {
        unsafe { &*(self.base.add(OFF_CLOSED) as *const AtomicU32) }
    }

    /// Copy `data` into the ring at stream offset `at` (wrapping).
    fn put(&self, at: u64, data: &[u8]) {
        let pos = (at % LANE_CAP as u64) as usize;
        let first = data.len().min(LANE_CAP - pos);
        unsafe {
            let dst = self.base.add(HDR + pos);
            std::ptr::copy_nonoverlapping(data.as_ptr(), dst, first);
            if first < data.len() {
                std::ptr::copy_nonoverlapping(
                    data.as_ptr().add(first),
                    self.base.add(HDR),
                    data.len() - first,
                );
            }
        }
    }

    /// Copy out of the ring at stream offset `at` (wrapping).
    fn get(&self, at: u64, out: &mut [u8]) {
        let pos = (at % LANE_CAP as u64) as usize;
        let first = out.len().min(LANE_CAP - pos);
        unsafe {
            let src = self.base.add(HDR + pos);
            std::ptr::copy_nonoverlapping(src, out.as_mut_ptr(), first);
            if first < out.len() {
                std::ptr::copy_nonoverlapping(
                    self.base.add(HDR),
                    out.as_mut_ptr().add(first),
                    out.len() - first,
                );
            }
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        unsafe {
            ffi::munmap(self.base as *mut _, self.map_len);
        }
    }
}

/// Futex-free wait: spin briefly (same-host producers publish within
/// nanoseconds), then yield the timeslice so a descheduled peer can
/// run. Never sleeps — wakeup latency stays sub-microsecond under
/// load, and idle lanes only cost a runnable thread during waits.
struct Backoff {
    spins: u32,
}

impl Backoff {
    fn new() -> Backoff {
        Backoff { spins: 0 }
    }

    fn snooze(&mut self) {
        if self.spins < 1 << 10 {
            self.spins += 1;
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

fn lane_path(dir: &Path, src: usize, dst: usize) -> PathBuf {
    dir.join(format!("lane_{src}_{dst}.ring"))
}

/// Pick the fabric directory root: tmpfs when the platform has it.
fn shm_root() -> PathBuf {
    let dev_shm = PathBuf::from("/dev/shm");
    if dev_shm.is_dir() {
        dev_shm
    } else {
        std::env::temp_dir()
    }
}

/// A fresh, collision-free fabric directory for this process.
pub fn fresh_dir() -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    shm_root()
        .join(format!("cephalo-shm-{}-{n}", std::process::id()))
}

/// Constructor for a same-host shared-memory fabric.
pub struct ShmFabric;

impl ShmFabric {
    /// Build `world` connected endpoints in one process (threads),
    /// full mesh including self-lanes, under a fresh directory.
    pub fn new(world: usize) -> Result<Vec<ShmTransport>> {
        let dir = fresh_dir();
        (0..world)
            .map(|r| ShmTransport::attach(&dir, r, world))
            .collect()
    }

    /// Attach rank `rank` of a `world`-rank mesh under `dir` — the
    /// cross-process entry (`cephalo worker --shm-dir`).
    pub fn attach(
        dir: &Path,
        rank: usize,
        world: usize,
    ) -> Result<ShmTransport> {
        ShmTransport::attach(dir, rank, world)
    }
}

/// One rank's endpoint over mmap ring lanes. Lanes may cover only a
/// subset of peers (`attach_peers`) — the hybrid fabric attaches shm
/// lanes for same-host ranks only.
pub struct ShmTransport {
    rank: usize,
    world: usize,
    /// `out[dst]` — this rank's ring to each destination.
    out: Vec<Option<Ring>>,
    /// `inn[src]` — each source's ring to us.
    inn: Vec<Option<Ring>>,
    closed: bool,
}

impl ShmTransport {
    /// Full-mesh attach (every peer incl. self).
    pub fn attach(dir: &Path, rank: usize, world: usize) -> Result<Self> {
        let peers: Vec<usize> = (0..world).collect();
        ShmTransport::attach_peers(dir, rank, world, &peers)
    }

    /// Attach lanes to `peers` only; other ranks are unreachable
    /// through this endpoint (the hybrid router never asks).
    pub fn attach_peers(
        dir: &Path,
        rank: usize,
        world: usize,
        peers: &[usize],
    ) -> Result<Self> {
        assert!(world >= 1 && rank < world);
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("shm dir {}: {e}", dir.display()))?;
        let mut out: Vec<Option<Ring>> = (0..world).map(|_| None).collect();
        let mut inn: Vec<Option<Ring>> = (0..world).map(|_| None).collect();
        for &p in peers {
            assert!(p < world, "peer {p} out of range (world {world})");
            out[p] = Some(Ring::attach(&lane_path(dir, rank, p))?);
            inn[p] = Some(Ring::attach(&lane_path(dir, p, rank))?);
        }
        Ok(ShmTransport { rank, world, out, inn, closed: false })
    }

    /// Whether this endpoint has a lane to `peer`.
    pub fn has_lane(&self, peer: usize) -> bool {
        peer < self.world && self.out[peer].is_some()
    }

    fn out_lane(&self, to: usize) -> Result<&Ring> {
        if to >= self.world {
            return Err(anyhow!(
                "send to rank {to} out of range (world {})",
                self.world
            ));
        }
        self.out[to]
            .as_ref()
            .ok_or_else(|| anyhow!("no shm lane to rank {to}"))
    }

    fn in_lane(&self, from: usize) -> Result<&Ring> {
        if from >= self.world {
            return Err(anyhow!(
                "recv from rank {from} out of range (world {})",
                self.world
            ));
        }
        self.inn[from]
            .as_ref()
            .ok_or_else(|| anyhow!("no shm lane from rank {from}"))
    }

    /// Stream `data` into the lane, waiting for ring space as needed.
    fn write_all(&self, to: usize, data: &[u8]) -> Result<()> {
        let ring = self.out_lane(to)?;
        let mut tail = ring.tail().load(Ordering::Relaxed);
        let mut rest = data;
        let mut wait = Backoff::new();
        while !rest.is_empty() {
            let head = ring.head().load(Ordering::Acquire);
            let free = LANE_CAP - (tail - head) as usize;
            if free == 0 {
                wait.snooze();
                continue;
            }
            let n = free.min(rest.len());
            ring.put(tail, &rest[..n]);
            tail += n as u64;
            // Publish after the copy: acquire-readers of `tail` see
            // initialized bytes (the seqlock half of the protocol).
            ring.tail().store(tail, Ordering::Release);
            rest = &rest[n..];
        }
        Ok(())
    }

    /// Fill `buf` from the lane. `deadline` bounds ONLY the wait for
    /// the first byte (like the TCP fabric's whole-frame timeout);
    /// once a frame starts it is read to completion. Returns false on
    /// a clean deadline miss with nothing consumed.
    fn read_exact(
        &self,
        from: usize,
        buf: &mut [u8],
        deadline: Option<Instant>,
    ) -> Result<bool> {
        let ring = self.in_lane(from)?;
        let mut head = ring.head().load(Ordering::Relaxed);
        let mut filled = 0usize;
        let mut wait = Backoff::new();
        while filled < buf.len() {
            let tail = ring.tail().load(Ordering::Acquire);
            let avail = (tail - head) as usize;
            if avail == 0 {
                if ring.closed().load(Ordering::Acquire) != 0 {
                    // The writer closes AFTER its final tail store;
                    // re-check so the flag never truncates a stream.
                    if ring.tail().load(Ordering::Acquire) == head {
                        if filled == 0 {
                            return Err(TransportError::PeerClosed {
                                rank: from,
                            }
                            .into());
                        }
                        return Err(anyhow!(
                            "rank {from} closed mid-frame ({filled} of {} \
                             bytes)",
                            buf.len()
                        ));
                    }
                    continue;
                }
                if filled == 0 {
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            return Ok(false);
                        }
                    }
                }
                wait.snooze();
                continue;
            }
            let n = avail.min(buf.len() - filled);
            ring.get(head, &mut buf[filled..filled + n]);
            head += n as u64;
            ring.head().store(head, Ordering::Release);
            filled += n;
        }
        Ok(true)
    }

    fn send_frame(&mut self, to: usize, frame: &Frame) -> Result<()> {
        if self.closed {
            return Err(anyhow!("rank {} endpoint is closed", self.rank));
        }
        // Header and payload stream separately: no staging concat.
        let (tag, payload): (u8, &[u8]) = match frame {
            Frame::Bytes(b) => (TAG_BYTES, b),
            Frame::F32(_) => unreachable!("f32 goes through send_f32"),
        };
        let mut hdr = [0u8; 9];
        hdr[0] = tag;
        hdr[1..9].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        self.write_all(to, &hdr)?;
        self.write_all(to, payload)?;
        let c = crate::telemetry::counters();
        c.shm_frames_sent.fetch_add(1, Ordering::Relaxed);
        c.shm_bytes_sent
            .fetch_add(9 + payload.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    pub(crate) fn recv_frame(
        &mut self,
        from: usize,
        deadline: Option<Instant>,
    ) -> Result<Option<Frame>> {
        let mut hdr = [0u8; 9];
        if !self.read_exact(from, &mut hdr, deadline)? {
            return Ok(None);
        }
        let tag = hdr[0];
        let len = u64::from_le_bytes(hdr[1..9].try_into().unwrap());
        if len > MAX_FRAME_BYTES {
            return Err(anyhow!(
                "shm frame from rank {from} claims {len} bytes (cap {})",
                MAX_FRAME_BYTES
            ));
        }
        let mut payload = vec![0u8; len as usize];
        self.read_exact(from, &mut payload, None)?;
        let c = crate::telemetry::counters();
        c.shm_frames_recv.fetch_add(1, Ordering::Relaxed);
        c.shm_bytes_recv.fetch_add(9 + len, Ordering::Relaxed);
        match tag {
            TAG_BYTES => Ok(Some(Frame::Bytes(payload))),
            TAG_F32 => Ok(Some(Frame::F32(f32s_from_le_bytes(&payload)?))),
            t => Err(anyhow!("unknown shm frame tag {t} from rank {from}")),
        }
    }
}

impl Transport for ShmTransport {
    fn backend(&self) -> &'static str {
        "shm"
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        if self.closed {
            return Err(anyhow!("rank {} endpoint is closed", self.rank));
        }
        let mut hdr = [0u8; 9];
        hdr[0] = TAG_F32;
        hdr[1..9].copy_from_slice(&((data.len() * 4) as u64).to_le_bytes());
        self.write_all(to, &hdr)?;
        self.write_all(to, &f32s_to_le_bytes(data))?;
        let c = crate::telemetry::counters();
        c.shm_frames_sent.fetch_add(1, Ordering::Relaxed);
        c.shm_bytes_sent
            .fetch_add(9 + (data.len() * 4) as u64, Ordering::Relaxed);
        Ok(())
    }

    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        let f = self
            .recv_frame(from, None)?
            .expect("blocking recv cannot time out");
        expect_f32(f, from)
    }

    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        self.send_frame(to, &Frame::Bytes(data.to_vec()))
    }

    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        let f = self
            .recv_frame(from, None)?
            .expect("blocking recv cannot time out");
        expect_bytes(f, from)
    }

    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        match self.recv_frame(from, Some(deadline)) {
            Ok(Some(f)) => expect_bytes(f, from).map(Some),
            Ok(None) => Ok(None),
            // A gone peer is "no answer" to a probe, like the other
            // fabrics; the caller checks peer_closed to distinguish.
            Err(_) => Ok(None),
        }
    }

    fn peer_closed(&self, rank: usize) -> bool {
        match self.inn.get(rank).and_then(|l| l.as_ref()) {
            Some(ring) => ring.closed().load(Ordering::Acquire) != 0,
            None => false,
        }
    }

    fn close(&mut self) {
        // Flag every outbound lane closed so peers blocked on us wake
        // with PeerClosed once they drain. Ordering: any final tail
        // store happened before this Release store.
        self.closed = true;
        for lane in self.out.iter().flatten() {
            lane.closed().store(1, Ordering::Release);
        }
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        self.close();
        // Unlink our inbound lane files (mappings stay valid for any
        // live peer); the last endpoint out removes the directory.
        let mut dir = None;
        for lane in self.inn.iter().flatten() {
            dir = lane.path.parent().map(Path::to_path_buf);
            let _ = std::fs::remove_file(&lane.path);
        }
        if let Some(d) = dir {
            let _ = std::fs::remove_dir(&d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(world: usize) -> Vec<ShmTransport> {
        ShmFabric::new(world).expect("shm fabric")
    }

    #[test]
    fn frames_route_between_ranks_and_self() {
        let mut eps = fabric(3);
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert_eq!((a.rank(), b.rank(), c.rank()), (0, 1, 2));
        assert_eq!(a.backend(), "shm");

        a.send_f32(1, &[1.0, -0.0]).unwrap();
        a.send_bytes(1, &[7]).unwrap();
        c.send_f32(1, &[9.0]).unwrap();
        assert_eq!(b.recv_f32(2).unwrap(), vec![9.0]);
        let xs = b.recv_f32(0).unwrap();
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(b.recv_bytes(0).unwrap(), vec![7]);

        b.send_bytes(1, &[1, 2]).unwrap();
        assert_eq!(b.recv_bytes(1).unwrap(), vec![1, 2]);
    }

    #[test]
    fn type_mismatch_and_bad_rank_error() {
        let mut eps = fabric(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[1]).unwrap();
        assert!(b.recv_f32(0).is_err());
        assert!(a.send_f32(5, &[1.0]).is_err());
        assert!(a.recv_bytes(9).is_err());
    }

    #[test]
    fn frames_larger_than_the_ring_stream_through() {
        // 3 x LANE_CAP of payload must flow while the reader drains
        // concurrently — the byte-stream framing, not frame-at-once.
        let mut eps = fabric(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let big: Vec<f32> =
            (0..(3 * LANE_CAP / 4)).map(|i| i as f32 * 0.5).collect();
        let expect = big.clone();
        let writer = std::thread::spawn(move || {
            a.send_f32(1, &big).unwrap();
            a
        });
        let got = b.recv_f32(0).unwrap();
        writer.join().unwrap();
        assert_eq!(got.len(), expect.len());
        assert!(got
            .iter()
            .zip(&expect)
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn close_wakes_blocked_peers_and_fails_later_sends() {
        let mut eps = fabric(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let waiter = std::thread::spawn(move || a.recv_bytes(1));
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(waiter.join().unwrap().is_err(), "close must wake peers");
        assert!(b.send_bytes(0, &[1]).is_err());
    }

    #[test]
    fn queued_frames_survive_a_close() {
        // Data published before close must drain before PeerClosed.
        let mut eps = fabric(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_bytes(1, &[5, 6]).unwrap();
        a.close();
        assert_eq!(b.recv_bytes(0).unwrap(), vec![5, 6]);
        let err = b.recv_bytes(0).unwrap_err();
        assert!(err.to_string().contains("closed"), "got: {err}");
        assert!(b.peer_closed(0));
    }

    #[test]
    fn recv_timeout_returns_none_on_silence_and_some_on_frames() {
        let mut eps = fabric(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert_eq!(a.recv_bytes_timeout(1, 5).unwrap(), None);
        b.send_bytes(0, &[42]).unwrap();
        assert_eq!(a.recv_bytes_timeout(1, 1000).unwrap(), Some(vec![42]));
        b.close();
        assert_eq!(a.recv_bytes_timeout(1, 5).unwrap(), None);
    }

    #[test]
    fn barrier_releases_all_ranks() {
        let eps = fabric(4);
        std::thread::scope(|s| {
            for mut ep in eps {
                s.spawn(move || {
                    for _ in 0..3 {
                        ep.barrier().unwrap();
                    }
                });
            }
        });
    }

    #[test]
    fn cross_process_style_attach_shares_the_lane_files() {
        // Two endpoints attached separately (the worker path) see the
        // same rings as fabric-constructed ones.
        let dir = fresh_dir();
        let mut a = ShmFabric::attach(&dir, 0, 2).unwrap();
        let mut b = ShmFabric::attach(&dir, 1, 2).unwrap();
        a.send_f32(1, &[3.5]).unwrap();
        assert_eq!(b.recv_f32(0).unwrap(), vec![3.5]);
        b.send_bytes(0, b"hi").unwrap();
        assert_eq!(a.recv_bytes(1).unwrap(), b"hi".to_vec());
    }

    #[test]
    fn partial_attach_only_reaches_named_peers() {
        let dir = fresh_dir();
        let t =
            ShmTransport::attach_peers(&dir, 0, 3, &[0, 2]).unwrap();
        assert!(t.has_lane(0) && t.has_lane(2) && !t.has_lane(1));
        let mut t = t;
        assert!(t.send_bytes(1, &[1]).is_err());
    }
}
