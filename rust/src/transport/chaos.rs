//! Deterministic fault injection: [`ChaosTransport`] wraps any fabric
//! and perturbs it according to a seeded, replayable [`FaultPlan`].
//!
//! The injected fault classes, and why each is safe to replay:
//!
//! * **delay** — seeded per-rank sleeps before sends/receives. Purely
//!   temporal: bitwise-invisible by construction, so delay-only chaos
//!   must leave every trajectory identical (tested in
//!   `tests/transport_parity.rs`).
//! * **duplicate** — after a send, re-transmit the last frame
//!   byte-for-byte via [`Transport::resend_last`]. The TCP receiver's
//!   sequence dedup drops it; fabrics without wire-level dedup no-op
//!   the resend. Either way: invisible.
//! * **corrupt** — flip one byte of an outgoing frame AFTER its CRC
//!   was computed ([`Transport::corrupt_next_send`]). Restricted to
//!   PING replies so the corruption-⇒-death conversion always lands at
//!   a step boundary — the victim has already delivered its step
//!   results, so recovery stays bitwise.
//! * **crash** — after fully completing step `k` (reply and
//!   fault-tolerance sync sent), the rank dies on its next command
//!   fetch: [`CrashMode::Error`] returns a typed
//!   [`TransportError::ChaosCrash`] (thread-mode workers), while
//!   [`CrashMode::Abort`] calls `std::process::exit(137)` — a genuine
//!   abrupt process death, socket torn down mid-mesh, exactly what
//!   `kill -9` leaves behind.
//! * **drop-shutdown** — swallow the coordinator's SHUTDOWN frame, the
//!   lost-teardown-message case that used to hang
//!   `DistDriver::shutdown` (regression-tested in
//!   `tests/dist_session.rs`).
//!
//! Crash faults only make sense on worker ranks (rank 0 is the
//! coordinator), and the step counter is driven by DECODING the
//! coordinator's step commands off the wire — the middleware needs no
//! cooperation from the training code, so the same wrapper serves
//! thread workers, process workers and bare fabric tests.

use crate::transport::dist::{OP_PING, OP_SHUTDOWN, OP_STEP};
use crate::transport::{Transport, TransportError};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::prng::Rng;
use std::collections::BTreeMap;

/// Seed-mixing constant for [`FaultPlan::generate`] (so a chaos seed
/// never collides with the training seed's streams).
const PLAN_SEED_MIX: u64 = 0xC4A0_5F00;

/// What a crash fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashMode {
    /// Return a typed [`TransportError::ChaosCrash`] from the command
    /// fetch — the thread-worker form (a process exit would kill the
    /// whole test harness).
    Error,
    /// `std::process::exit(137)` — the process-worker form; 137 is the
    /// shell's code for SIGKILL, because that is what this simulates.
    Abort,
}

/// The faults assigned to one rank. All fields public so tests can
/// construct precise schedules directly; [`FaultPlan::generate`]
/// derives them from a seed.
#[derive(Debug, Clone, PartialEq)]
pub struct RankFaults {
    /// The rank this schedule applies to.
    pub rank: usize,
    /// Crash on the next command fetch after COMPLETING this global
    /// step (the reply and ft-sync frames for the step are already
    /// out).
    pub crash_after_step: Option<u64>,
    /// Corrupt the next PING reply once this global step has
    /// completed.
    pub corrupt_pong_after_step: Option<u64>,
    /// Swallow the coordinator's SHUTDOWN frame.
    pub drop_shutdown: bool,
    /// Probability of a seeded sleep before each transport op.
    pub delay_prob: f64,
    /// Sleeps are uniform in `0..=max_delay_ms` milliseconds.
    pub max_delay_ms: u64,
    /// Probability of re-transmitting a frame after sending it.
    pub dup_prob: f64,
}

impl RankFaults {
    /// No faults at all for `rank`.
    pub fn quiet(rank: usize) -> Self {
        RankFaults {
            rank,
            crash_after_step: None,
            corrupt_pong_after_step: None,
            drop_shutdown: false,
            delay_prob: 0.0,
            max_delay_ms: 0,
            dup_prob: 0.0,
        }
    }

    fn is_quiet(&self) -> bool {
        self == &RankFaults::quiet(self.rank)
    }
}

/// Coordinator-side fault schedule: faults that fire in the DRIVER,
/// not on a worker lane. The coordinator was previously assumed
/// reliable; these knobs let chaos runs exercise its recovery paths —
/// dropped liveness frames, delayed polls, a tainted rejoin digest,
/// and a crash between re-plan and migrate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DriverFaults {
    /// Drop the named rank's PING echo at the coordinator (the frame
    /// arrives but the driver pretends it did not), forcing a
    /// suspicion on a healthy rank.
    pub drop_ping_rank: Option<usize>,
    /// First liveness poll (1-based) at which `drop_ping_rank` fires.
    pub drop_ping_first_poll: u64,
    /// How many consecutive polls the drop persists for.
    pub drop_ping_polls: u64,
    /// Sleep this long at the top of every liveness poll (a slow,
    /// overloaded coordinator).
    pub poll_delay_ms: u64,
    /// Corrupt this rank's reported rejoin fingerprint ONCE, forcing
    /// the re-stream path on an otherwise-clean rejoin.
    pub taint_rank: Option<usize>,
    /// Crash the coordinator between re-plan and migrate on the n-th
    /// (1-based) recovery, exercising idempotent recovery.
    pub coord_crash_recovery: Option<u64>,
}

impl Default for DriverFaults {
    fn default() -> Self {
        DriverFaults::quiet()
    }
}

impl DriverFaults {
    /// No coordinator-side faults.
    pub fn quiet() -> DriverFaults {
        DriverFaults {
            drop_ping_rank: None,
            drop_ping_first_poll: 1,
            drop_ping_polls: 1,
            poll_delay_ms: 0,
            taint_rank: None,
            coord_crash_recovery: None,
        }
    }

    /// True when no coordinator-side fault can ever fire.
    pub fn is_quiet(&self) -> bool {
        self.drop_ping_rank.is_none()
            && self.poll_delay_ms == 0
            && self.taint_rank.is_none()
            && self.coord_crash_recovery.is_none()
    }

    /// Should the coordinator drop `rank`'s PING echo on liveness poll
    /// number `poll` (1-based)?
    pub fn drops_ping(&self, rank: usize, poll: u64) -> bool {
        self.drop_ping_rank == Some(rank)
            && poll >= self.drop_ping_first_poll
            && poll < self.drop_ping_first_poll + self.drop_ping_polls
    }
}

/// Knobs for [`FaultPlan::generate`].
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// How many of the HIGHEST worker ranks receive crash faults.
    /// Crashing top-down keeps every surviving membership a canonical
    /// prefix, which is what lets recovery reuse the graceful-churn
    /// machinery unchanged (DESIGN.md §Fault model).
    pub crash_ranks: usize,
    /// Global step after which the first (highest) rank crashes.
    pub first_crash_step: u64,
    /// Minimum spacing between successive crash steps; the generator
    /// adds seeded jitter on top.
    pub crash_step_stride: u64,
    /// Per-send probability of a seeded delay.
    pub delay_prob: f64,
    /// Upper bound on one injected delay, in milliseconds.
    pub max_delay_ms: u64,
    /// Per-send probability of re-transmitting the frame (the
    /// receiver's sequence dedup must absorb it).
    pub dup_prob: f64,
    /// Coordinator-side faults, copied into the plan verbatim (they
    /// are schedules already, nothing to derive from the seed).
    pub driver: DriverFaults,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            crash_ranks: 1,
            first_crash_step: 1,
            crash_step_stride: 2,
            delay_prob: 0.05,
            max_delay_ms: 2,
            dup_prob: 0.05,
            driver: DriverFaults::quiet(),
        }
    }
}

impl ChaosConfig {
    /// Parse a CLI chaos spec: comma-separated `key=value` pairs with
    /// a mandatory `seed` — e.g. `seed=7,crash=2,first=1,stride=2`.
    /// Returns `(seed, config)`.
    pub fn parse(spec: &str) -> Result<(u64, ChaosConfig)> {
        let mut seed: Option<u64> = None;
        let mut cfg = ChaosConfig::default();
        fn parsed<V: std::str::FromStr>(
            key: &str,
            value: &str,
        ) -> Result<V>
        where
            V::Err: std::fmt::Display,
        {
            value.parse().map_err(|e| {
                crate::anyhow!("chaos {key}={value}: {e}")
            })
        }
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, value) = part.split_once('=').ok_or_else(|| {
                crate::anyhow!("chaos spec `{part}` is not key=value")
            })?;
            match key {
                "seed" => seed = Some(parsed(key, value)?),
                "crash" => cfg.crash_ranks = parsed(key, value)?,
                "first" => cfg.first_crash_step = parsed(key, value)?,
                "stride" => cfg.crash_step_stride = parsed(key, value)?,
                "delay" => cfg.delay_prob = parsed(key, value)?,
                "delay_ms" => cfg.max_delay_ms = parsed(key, value)?,
                "dup" => cfg.dup_prob = parsed(key, value)?,
                "drop_ping" => {
                    cfg.driver.drop_ping_rank = Some(parsed(key, value)?)
                }
                "drop_first" => {
                    cfg.driver.drop_ping_first_poll = parsed(key, value)?
                }
                "drop_count" => {
                    cfg.driver.drop_ping_polls = parsed(key, value)?
                }
                "poll_delay_ms" => {
                    cfg.driver.poll_delay_ms = parsed(key, value)?
                }
                "taint" => {
                    cfg.driver.taint_rank = Some(parsed(key, value)?)
                }
                "coord_crash" => {
                    cfg.driver.coord_crash_recovery =
                        Some(parsed(key, value)?)
                }
                _ => {
                    return Err(crate::anyhow!(
                        "unknown chaos key `{key}` (try seed/crash/first/\
                         stride/delay/delay_ms/dup/drop_ping/drop_first/\
                         drop_count/poll_delay_ms/taint/coord_crash)"
                    ))
                }
            }
        }
        let seed = seed
            .ok_or_else(|| crate::anyhow!("chaos spec needs seed=<n>"))?;
        Ok((seed, cfg))
    }
}

/// A complete, replayable fault schedule for one world. Equality is
/// structural, so "same seed ⇒ same plan" is directly assertable.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// The seed the schedule was generated from.
    pub seed: u64,
    /// `faults[rank]` — rank 0 (the coordinator) is always quiet ON
    /// ITS LANES; coordinator-side faults live in `driver`.
    pub faults: Vec<RankFaults>,
    /// Faults that fire in the coordinator itself (dropped liveness
    /// frames, delayed polls, tainted rejoin digests, a crash between
    /// re-plan and migrate).
    pub driver: DriverFaults,
}

impl FaultPlan {
    /// An all-quiet plan (tests mutate individual ranks for precise
    /// schedules).
    pub fn quiet(world: usize) -> FaultPlan {
        FaultPlan {
            seed: 0,
            faults: (0..world).map(RankFaults::quiet).collect(),
            driver: DriverFaults::quiet(),
        }
    }

    /// Derive a schedule from `seed`: crashes on the HIGHEST worker
    /// ranks at strictly increasing step thresholds (seeded jitter on
    /// the spacing), delay/dup noise on every worker. Pure in
    /// `(seed, world, cfg)` — the replayability contract.
    pub fn generate(seed: u64, world: usize, cfg: &ChaosConfig) -> FaultPlan {
        let mut rng = Rng::new(seed ^ PLAN_SEED_MIX);
        let mut faults: Vec<RankFaults> =
            (0..world).map(RankFaults::quiet).collect();
        for f in faults.iter_mut().skip(1) {
            f.delay_prob = cfg.delay_prob;
            f.max_delay_ms = cfg.max_delay_ms;
            f.dup_prob = cfg.dup_prob;
        }
        let n_crash = cfg.crash_ranks.min(world.saturating_sub(1));
        let mut step = cfg.first_crash_step;
        for i in 0..n_crash {
            faults[world - 1 - i].crash_after_step = Some(step);
            let stride = cfg.crash_step_stride.max(1);
            step += stride + rng.range(0, stride as usize + 1) as u64;
        }
        FaultPlan { seed, faults, driver: cfg.driver.clone() }
    }

    /// Number of ranks the schedule covers.
    pub fn world(&self) -> usize {
        self.faults.len()
    }

    /// The faults for one rank (quiet if out of range).
    pub fn for_rank(&self, rank: usize) -> RankFaults {
        self.faults
            .get(rank)
            .cloned()
            .unwrap_or_else(|| RankFaults::quiet(rank))
    }

    /// Render the schedule for the chaos-smoke JSON artifact.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("seed".into(), Json::Num(self.seed as f64));
        let ranks: Vec<Json> = self
            .faults
            .iter()
            .filter(|f| !f.is_quiet())
            .map(|f| {
                let mut o = BTreeMap::new();
                o.insert("rank".into(), Json::Num(f.rank as f64));
                if let Some(s) = f.crash_after_step {
                    o.insert("crash_after_step".into(), Json::Num(s as f64));
                }
                if let Some(s) = f.corrupt_pong_after_step {
                    o.insert(
                        "corrupt_pong_after_step".into(),
                        Json::Num(s as f64),
                    );
                }
                if f.drop_shutdown {
                    o.insert("drop_shutdown".into(), Json::Bool(true));
                }
                if f.delay_prob > 0.0 {
                    o.insert("delay_prob".into(), Json::Num(f.delay_prob));
                    o.insert(
                        "max_delay_ms".into(),
                        Json::Num(f.max_delay_ms as f64),
                    );
                }
                if f.dup_prob > 0.0 {
                    o.insert("dup_prob".into(), Json::Num(f.dup_prob));
                }
                Json::Obj(o)
            })
            .collect();
        obj.insert("faults".into(), Json::Arr(ranks));
        if !self.driver.is_quiet() {
            let mut d = BTreeMap::new();
            if let Some(r) = self.driver.drop_ping_rank {
                d.insert("drop_ping_rank".into(), Json::Num(r as f64));
                d.insert(
                    "drop_ping_first_poll".into(),
                    Json::Num(self.driver.drop_ping_first_poll as f64),
                );
                d.insert(
                    "drop_ping_polls".into(),
                    Json::Num(self.driver.drop_ping_polls as f64),
                );
            }
            if self.driver.poll_delay_ms > 0 {
                d.insert(
                    "poll_delay_ms".into(),
                    Json::Num(self.driver.poll_delay_ms as f64),
                );
            }
            if let Some(r) = self.driver.taint_rank {
                d.insert("taint_rank".into(), Json::Num(r as f64));
            }
            if let Some(n) = self.driver.coord_crash_recovery {
                d.insert(
                    "coord_crash_recovery".into(),
                    Json::Num(n as f64),
                );
            }
            obj.insert("driver".into(), Json::Obj(d));
        }
        Json::Obj(obj)
    }
}

/// Fault-injecting middleware over any [`Transport`] (see module
/// docs). One wrapper per endpoint, carrying that rank's slice of the
/// plan plus a rank-forked RNG for the probabilistic faults.
pub struct ChaosTransport<T: Transport> {
    inner: T,
    faults: RankFaults,
    mode: CrashMode,
    rng: Rng,
    /// Set once the step named by `crash_after_step` has been decoded;
    /// the NEXT command fetch dies.
    crash_armed: bool,
    /// Step threshold seen for `corrupt_pong_after_step`; the next
    /// PING reply goes out corrupted.
    corrupt_armed: bool,
    /// The step index that armed the crash (for the typed error).
    armed_at_step: u64,
}

impl<T: Transport> ChaosTransport<T> {
    /// Wrap `inner` with its rank's faults from `plan`.
    pub fn new(inner: T, plan: &FaultPlan, mode: CrashMode) -> Self {
        let rank = inner.rank();
        let faults = plan.for_rank(rank);
        // Per-rank stream: same plan seed, disjoint delay/dup draws.
        let rng = Rng::new(
            plan.seed ^ PLAN_SEED_MIX ^ (rank as u64).wrapping_mul(0x9E37),
        );
        ChaosTransport {
            inner,
            faults,
            mode,
            rng,
            crash_armed: false,
            corrupt_armed: false,
            armed_at_step: 0,
        }
    }

    /// Unwrap the middleware, returning the inner fabric endpoint.
    pub fn into_inner(self) -> T {
        self.inner
    }

    /// Tally a fired fault and, when tracing, drop an instant event on
    /// the timeline. Step-keyed faults encode `s{step}` so trace tests
    /// can match instants against the seeded plan.
    fn record_fault(&self, kind: &str, step: Option<u64>) {
        use std::sync::atomic::Ordering;
        crate::telemetry::counters()
            .chaos_faults
            .fetch_add(1, Ordering::Relaxed);
        if crate::telemetry::on() {
            let rank = self.inner.rank();
            let name = match step {
                Some(s) => format!("{kind} r{rank} s{s}"),
                None => format!("{kind} r{rank}"),
            };
            crate::telemetry::instant(crate::telemetry::CAT_FAULT, &name);
        }
    }

    fn maybe_delay(&mut self) {
        if self.faults.delay_prob > 0.0
            && self.rng.bool(self.faults.delay_prob)
        {
            let ms = self
                .rng
                .range(0, self.faults.max_delay_ms as usize + 1);
            if ms > 0 {
                self.record_fault("delay", None);
                std::thread::sleep(std::time::Duration::from_millis(
                    ms as u64,
                ));
            }
        }
    }

    fn maybe_dup(&mut self, to: usize) {
        if self.faults.dup_prob > 0.0 && self.rng.bool(self.faults.dup_prob) {
            // Best effort: a failed duplicate is still a duplicate
            // fault (the original went through).
            self.record_fault("dup", None);
            let _ = self.inner.resend_last(to);
        }
    }

    fn crash(&mut self) -> crate::util::error::Error {
        self.record_fault("crash", Some(self.armed_at_step));
        if self.mode == CrashMode::Abort {
            // Simulated kill -9: no unwinding, no socket teardown
            // beyond what the OS does for a dead process.
            std::process::exit(137);
        }
        TransportError::ChaosCrash {
            rank: self.inner.rank(),
            step: self.armed_at_step,
        }
        .into()
    }

    /// Inspect a command frame from the coordinator: advance the step
    /// counter and arm step-keyed faults. Returns `false` if the frame
    /// must be SWALLOWED (drop-shutdown fault).
    fn observe_command(&mut self, frame: &[u8]) -> bool {
        match frame.first() {
            Some(&OP_STEP) if frame.len() >= 9 => {
                let step = u64::from_le_bytes(
                    frame[1..9].try_into().expect("8 bytes"),
                );
                if self.faults.crash_after_step == Some(step) {
                    self.crash_armed = true;
                    self.armed_at_step = step;
                }
                if self.faults.corrupt_pong_after_step == Some(step) {
                    self.corrupt_armed = true;
                }
                true
            }
            Some(&OP_SHUTDOWN) if self.faults.drop_shutdown => false,
            _ => true,
        }
    }
}

impl<T: Transport> Transport for ChaosTransport<T> {
    fn backend(&self) -> &'static str {
        self.inner.backend()
    }

    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world_size(&self) -> usize {
        self.inner.world_size()
    }

    fn send_f32(&mut self, to: usize, data: &[f32]) -> Result<()> {
        self.maybe_delay();
        self.inner.send_f32(to, data)?;
        self.maybe_dup(to);
        Ok(())
    }

    fn recv_f32(&mut self, from: usize) -> Result<Vec<f32>> {
        self.maybe_delay();
        self.inner.recv_f32(from)
    }

    fn send_bytes(&mut self, to: usize, data: &[u8]) -> Result<()> {
        self.maybe_delay();
        if to == 0
            && self.corrupt_armed
            && data.first() == Some(&OP_PING)
        {
            // Corrupt exactly one PING reply, then disarm: the
            // coordinator's CRC check converts this into a dead-rank
            // verdict at a clean step boundary.
            self.corrupt_armed = false;
            self.record_fault("corrupt", None);
            self.inner.corrupt_next_send(0);
        }
        self.inner.send_bytes(to, data)?;
        self.maybe_dup(to);
        Ok(())
    }

    fn recv_bytes(&mut self, from: usize) -> Result<Vec<u8>> {
        loop {
            if from == 0 && self.crash_armed {
                return Err(self.crash());
            }
            self.maybe_delay();
            let frame = self.inner.recv_bytes(from)?;
            if from == 0 && !self.observe_command(&frame) {
                continue; // swallowed (drop-shutdown fault)
            }
            return Ok(frame);
        }
    }

    fn recv_bytes_timeout(
        &mut self,
        from: usize,
        timeout_ms: u64,
    ) -> Result<Option<Vec<u8>>> {
        if from == 0 && self.crash_armed {
            return Err(self.crash());
        }
        self.inner.recv_bytes_timeout(from, timeout_ms)
    }

    fn peer_closed(&self, rank: usize) -> bool {
        self.inner.peer_closed(rank)
    }

    fn peer_failed(&self, rank: usize) -> bool {
        self.inner.peer_failed(rank)
    }

    fn close(&mut self) {
        self.inner.close();
    }

    fn resend_last(&mut self, to: usize) -> Result<()> {
        self.inner.resend_last(to)
    }

    fn corrupt_next_send(&mut self, to: usize) {
        self.inner.corrupt_next_send(to);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_pure_in_seed_world_and_config() {
        let cfg = ChaosConfig { crash_ranks: 3, ..Default::default() };
        let a = FaultPlan::generate(7, 5, &cfg);
        let b = FaultPlan::generate(7, 5, &cfg);
        assert_eq!(a, b, "same seed must give the same plan");
        let c = FaultPlan::generate(8, 5, &cfg);
        assert_ne!(a, c, "different seeds must be able to differ");
    }

    #[test]
    fn crashes_land_on_the_highest_ranks_at_increasing_steps() {
        let cfg = ChaosConfig { crash_ranks: 3, ..Default::default() };
        let plan = FaultPlan::generate(42, 5, &cfg);
        assert_eq!(plan.for_rank(0).crash_after_step, None);
        assert_eq!(plan.for_rank(1).crash_after_step, None);
        let s4 = plan.for_rank(4).crash_after_step.unwrap();
        let s3 = plan.for_rank(3).crash_after_step.unwrap();
        let s2 = plan.for_rank(2).crash_after_step.unwrap();
        assert!(
            s4 < s3 && s3 < s2,
            "descending ranks must crash at increasing steps: \
             {s4} {s3} {s2}"
        );
        // Crash count never exceeds the worker count.
        let small = FaultPlan::generate(42, 2, &cfg);
        let crashed = small
            .faults
            .iter()
            .filter(|f| f.crash_after_step.is_some())
            .count();
        assert_eq!(crashed, 1);
    }

    #[test]
    fn chaos_spec_parses_and_rejects_garbage() {
        let (seed, cfg) = ChaosConfig::parse("seed=7").unwrap();
        assert_eq!(seed, 7);
        assert_eq!(cfg, ChaosConfig::default());
        let (seed, cfg) =
            ChaosConfig::parse("seed=9,crash=2,first=3,stride=4,dup=0.5")
                .unwrap();
        assert_eq!(seed, 9);
        assert_eq!(cfg.crash_ranks, 2);
        assert_eq!(cfg.first_crash_step, 3);
        assert_eq!(cfg.crash_step_stride, 4);
        assert_eq!(cfg.dup_prob, 0.5);
        assert!(ChaosConfig::parse("crash=2").is_err(), "seed is required");
        assert!(ChaosConfig::parse("seed=x").is_err());
        assert!(ChaosConfig::parse("seed=1,zap=2").is_err());
    }

    #[test]
    fn driver_fault_spec_parses_and_schedules() {
        let (_, cfg) = ChaosConfig::parse(
            "seed=3,drop_ping=2,drop_first=4,drop_count=2,taint=1,\
             poll_delay_ms=5,coord_crash=1",
        )
        .unwrap();
        let d = &cfg.driver;
        assert_eq!(d.drop_ping_rank, Some(2));
        assert!(!d.drops_ping(2, 3), "before the window");
        assert!(d.drops_ping(2, 4));
        assert!(d.drops_ping(2, 5));
        assert!(!d.drops_ping(2, 6), "after the window");
        assert!(!d.drops_ping(1, 4), "only the named rank");
        assert_eq!(d.taint_rank, Some(1));
        assert_eq!(d.poll_delay_ms, 5);
        assert_eq!(d.coord_crash_recovery, Some(1));
        assert!(!d.is_quiet());
        assert!(DriverFaults::quiet().is_quiet());
        // The plan carries the schedule verbatim and renders it.
        let plan = FaultPlan::generate(3, 3, &cfg);
        assert_eq!(plan.driver, cfg.driver);
        let rendered = plan.to_json().render();
        assert!(rendered.contains("\"drop_ping_rank\":2"));
        assert!(rendered.contains("\"taint_rank\":1"));
        // A quiet driver stays out of the JSON entirely.
        let quiet = FaultPlan::quiet(3).to_json().render();
        assert!(!quiet.contains("driver"));
    }

    #[test]
    fn schedule_json_names_only_faulted_ranks() {
        let mut plan = FaultPlan::quiet(3);
        plan.faults[2].crash_after_step = Some(4);
        let rendered = plan.to_json().render();
        assert!(rendered.contains("\"crash_after_step\":4"));
        assert!(rendered.contains("\"rank\":2"));
        assert!(!rendered.contains("\"rank\":1"), "quiet ranks omitted");
    }

    #[test]
    fn crash_fires_on_the_fetch_after_the_armed_step() {
        use crate::transport::LocalFabric;
        let mut eps = LocalFabric::new(2);
        let worker = eps.pop().unwrap();
        let mut driver = eps.pop().unwrap();
        let mut plan = FaultPlan::quiet(2);
        plan.faults[1].crash_after_step = Some(3);
        let mut chaotic =
            ChaosTransport::new(worker, &plan, CrashMode::Error);

        // Step 3's command frame: [OP_STEP][3 u64 LE].
        let mut cmd = vec![OP_STEP];
        cmd.extend_from_slice(&3u64.to_le_bytes());
        driver.send_bytes(1, &cmd).unwrap();
        driver.send_bytes(1, &[9, 9]).unwrap(); // some later frame
        // The armed step's own frame is DELIVERED (the worker must
        // complete the step)...
        assert_eq!(chaotic.recv_bytes(0).unwrap(), cmd);
        // ...and the NEXT fetch dies with the typed error.
        let err = chaotic.recv_bytes(0).unwrap_err().to_string();
        assert!(
            err.contains("chaos: rank 1 crashed after step 3"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn drop_shutdown_swallows_the_frame_and_keeps_listening() {
        use crate::transport::LocalFabric;
        let mut eps = LocalFabric::new(2);
        let worker = eps.pop().unwrap();
        let mut driver = eps.pop().unwrap();
        let mut plan = FaultPlan::quiet(2);
        plan.faults[1].drop_shutdown = true;
        let mut chaotic =
            ChaosTransport::new(worker, &plan, CrashMode::Error);
        driver.send_bytes(1, &[OP_SHUTDOWN]).unwrap();
        driver.send_bytes(1, &[7, 7]).unwrap();
        // The SHUTDOWN vanished; the next frame is what surfaces.
        assert_eq!(chaotic.recv_bytes(0).unwrap(), vec![7, 7]);
    }
}
