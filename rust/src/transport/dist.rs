//! Multi-process training: the SPMD rank engine, the `cephalo worker`
//! serving loop, and the coordinator-side driver.
//!
//! Every rank — the coordinator's resident rank 0 and each worker
//! thread/process — runs the SAME per-step pipeline as the in-process
//! [`crate::trainer::Trainer`], but against its own state and a
//! [`Transport`] endpoint:
//!
//! 1. sample the global batch from the shared-seed corpus (ALL ranks,
//!    standby included, so a rank that rejoins after churn is still on
//!    the same data stream);
//! 2. run the native backend on this rank's batch share only;
//! 3. ring ReduceScatter the gradients over the wire
//!    ([`super::collectives`]), scale by 1/tokens (Eq. 1);
//! 4. sharded Adam on this rank's `r_i` shard;
//! 5. ring AllGather the updated parameters.
//!
//! Because the native backend's gradient summation is exact (dyadic
//! quantization, `exec::native`) and the wire collectives are
//! bit-identical to the in-process rings, the distributed trajectory is
//! BITWISE the in-process (and single-worker) trajectory — asserted in
//! `tests/dist_session.rs`.
//!
//! Membership churn: the coordinator broadcasts a [`MigrateCmd`]
//! carrying the new membership and the `elastic::plan_migration`
//! transfer list; survivors keep their resident overlap, peers stream
//! moved ranges rank-to-rank, and ranges whose owner left the
//! membership are re-streamed by the (still running, now standby)
//! process that holds them — numerically identical to the in-process
//! session's checkpoint restore. Command/data frames are FIFO per
//! peer, so no barrier is needed between commands.
//!
//! Fault tolerance (`DistConfig::ft`): after every step each active
//! rank's post-step Adam moments (and, fully-sharded, its weight
//! slice) are backed up into a [`Mirror`]. The DEFAULT placement is
//! the sharded mirror: a [`MirrorLayout`] assigns every owner's backup
//! to its ring successor (`(owner + 1) % group`, rank-0 fallback at
//! `group <= 2`), so backup bytes per rank scale as `state/(n-1)`
//! instead of concentrating on the leader. `DistConfig::mirror_leader`
//! opts back into the legacy rank-0 flat mirror; both placements
//! recover onto the SAME bits (DESIGN.md invariant 15). When
//! [`DistDriver::poll_failures`] declares a rank dead (closed lane, or
//! an unanswered `PING` within the timeout), the next [`MigrateCmd`]
//! carries the dead set and every rank substitutes the dead owner's
//! mirror holder in the transfer loop — so a crashed rank's state
//! migrates EXACTLY like a graceful departure's, and the recovered
//! trajectory is bitwise the never-crashed one (DESIGN.md invariant
//! 12). Crashes are detected at step boundaries only: a rank that died
//! mid-step fails the step itself (fail-stop), because a
//! half-participated collective has no consistent state to recover.
//!
//! Rejoin (`DistConfig::rejoin_window_ms`): an unanswered probe no
//! longer has to be a death sentence. With a non-zero window the
//! driver retries the suspect with `REJOIN` probes (exponential
//! backoff) until the window closes; a worker that was merely
//! partitioned (or stopped) answers with its step counter and a
//! fingerprint of its resident shards. A matching fingerprint
//! re-admits the rank with NO data movement; a mismatch re-streams its
//! ranges from the mirror like a fresh arrival ([`MigrateCmd`]'s
//! `restream` set). Either way the trajectory is bitwise the
//! never-partitioned one (DESIGN.md invariant 15).
//!
//! ```text
//! REJOIN handshake (byte-frame payloads; framing per transport/mod.rs)
//!
//!   driver -> suspect     ┌───────────┬──────────────┐
//!   (probe, retried with  │ op = 7    │ nonce        │
//!   50→400ms backoff)     │ u8        │ u64 LE       │
//!                         └───────────┴──────────────┘
//!   suspect -> driver     ┌───────────┬──────────────┬──────────┬─────────────┐
//!   (ack; echoes the      │ op = 7    │ nonce        │ step     │ fingerprint │
//!   freshest nonce seen)  │ u8        │ u64 LE       │ u64 LE   │ u64 LE      │
//!                         └───────────┴──────────────┴──────────┴─────────────┘
//!
//!   step        = global steps the rank has completed (a mismatch is
//!                 fatal: its corpus stream position diverged)
//!   fingerprint = FNV-1a 64 over the rank's shard step + Adam moment
//!                 bits + weight-slice bits; compared against the
//!                 driver's per-rank ledger (refreshed from every STEP
//!                 reply and after every MIGRATE)
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::coordinator::elastic::Transfer;
use crate::exec::native::MAX_STEP_TOKENS;
use crate::exec::{NativeExecutor, StepExecutor, StepTimeModel, SurrogateSpec};
use crate::sharding::{ShardLayout, UnitLayout};
use crate::telemetry::{self, PhaseBreakdown};
use crate::trainer::adam::{AdamConfig, AdamShard};
use crate::trainer::data::{split_batch, Corpus};
use crate::trainer::{
    flatten, unflatten, unflatten_into, StepStats, WorkerSpec,
};
use crate::transport::chaos::DriverFaults;
use crate::transport::{
    collectives as wire, ChaosTransport, CrashMode, FaultPlan,
    HostTopology, HybridTransport, LocalFabric, ShmFabric, ShmTransport,
    Transport,
};
use crate::util::error::{anyhow, Result};

/// Which fabric a distributed run is built on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricSpec {
    /// In-process channels, worker ranks as threads (`--transport
    /// local`). Zero syscalls; the message plane is still real.
    Local,
    /// TCP loopback sockets, worker ranks as threads — the shape tests
    /// and benches use (real sockets, one process).
    TcpThreads,
    /// TCP sockets, worker ranks as SPAWNED `cephalo worker` processes
    /// (`--transport tcp`). Requires the running executable to BE the
    /// cephalo binary: workers are spawned as `current_exe() worker
    /// --rank i --connect addr --world n`.
    TcpProcesses,
    /// Shared-memory ring buffers under `/dev/shm`, worker ranks as
    /// threads — what the shm parity tests and benches use (one
    /// process, real mmap lanes).
    ShmThreads,
    /// Shared-memory ring buffers, worker ranks as SPAWNED `cephalo
    /// worker` processes (`--transport shm`). All ranks must share
    /// this host; workers attach the coordinator's lane directory via
    /// `--shm-dir`.
    ShmProcesses,
    /// Locality-routed two-tier fabric, worker ranks as threads: shm
    /// lanes between same-host ranks (per [`DistConfig::hosts`]), TCP
    /// loopback sockets across hosts.
    HybridThreads,
    /// Locality-routed fabric with SPAWNED worker processes
    /// (`--transport hybrid`): the fault-tolerant TCP mesh everywhere,
    /// plus shm fast-path lanes between same-host ranks.
    HybridProcesses,
}

impl FabricSpec {
    /// Parse a `--transport` CLI value; `None` for the in-process
    /// (transport-less) trainer.
    pub fn parse(s: &str) -> Result<Option<FabricSpec>> {
        match s {
            "inproc" => Ok(None),
            "local" => Ok(Some(FabricSpec::Local)),
            "tcp" => Ok(Some(FabricSpec::TcpProcesses)),
            "shm" => Ok(Some(FabricSpec::ShmProcesses)),
            "hybrid" => Ok(Some(FabricSpec::HybridProcesses)),
            other => Err(anyhow!(
                "unknown transport '{other}' (inproc | local | tcp | \
                 shm | hybrid)"
            )),
        }
    }

    /// Short fabric name for logs, reports and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            FabricSpec::Local => "local",
            FabricSpec::TcpThreads => "tcp",
            FabricSpec::TcpProcesses => "tcp",
            FabricSpec::ShmThreads => "shm",
            FabricSpec::ShmProcesses => "shm",
            FabricSpec::HybridThreads => "hybrid",
            FabricSpec::HybridProcesses => "hybrid",
        }
    }
}

/// Everything a rank needs to stand itself up, broadcast in `INIT`.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Seed for weight init and the shared corpus stream.
    pub seed: u64,
    /// Adam hyperparameters, identical on every rank.
    pub adam: AdamConfig,
    /// Corpus branch index (selects the data stream).
    pub corpus_branch: usize,
    /// The executed model (surrogate transformer spec).
    pub surrogate: SurrogateSpec,
    /// Fully-sharded parameters: every rank holds only its `r_i` slice
    /// of the weights, materializing the full vector per step with the
    /// wire AllGather (mirrors [`crate::trainer::TrainConfig`]'s flag;
    /// bitwise-identical either way).
    pub shard_params: bool,
    /// Fault tolerance: workers stream post-step optimizer state (and,
    /// fully-sharded, weight slices) to rank 0's [`Mirror`] every step,
    /// and the driver probes liveness so a dead rank's ranges can be
    /// re-streamed from the mirror (see the module docs). Off by
    /// default — the sync costs one extra model-sized transfer per
    /// step per rank.
    pub ft: bool,
    /// Keep the legacy LEADER mirror (rank 0 folds every rank's backup
    /// into one flat copy) instead of the default sharded
    /// [`MirrorLayout`] placement. The leader mirror concentrates
    /// `state` bytes on rank 0; the sharded mirror spreads
    /// `state/(n-1)` bytes per rank over ring successors. Recovery is
    /// bitwise identical either way (DESIGN.md invariant 15).
    pub mirror_leader: bool,
    /// Bounded rejoin window in milliseconds: when a liveness probe
    /// goes unanswered, retry the suspect with `REJOIN` handshakes
    /// (exponential backoff) for this long before declaring it dead.
    /// `0` (the default) disables retry — the first unanswered probe
    /// is a death verdict, the pre-rejoin behavior.
    pub rejoin_window_ms: u64,
    /// How long [`DistDriver::poll_failures`] waits for a `PING` echo.
    /// Probes run at step boundaries when every live worker is blocked
    /// on `recv`, so a live echo arrives in microseconds; the default
    /// 2000 ms margin covers scheduler jitter and chaos-injected
    /// delivery delays. Tests shrink it to keep suspicion cheap.
    pub ping_timeout_ms: u64,
    /// FSDP units for the sharded step (`<= 1` = whole-model gather):
    /// each rank gathers unit k+1's weights on the wire WHILE unit k
    /// computes (round-stepped [`wire::AllGatherOp`] driven between
    /// compute chunks), frees each unit after use, and reduce-scatters
    /// its gradients per unit. Transient parameter memory then scales
    /// with the largest unit; the trajectory stays bitwise the
    /// whole-gather one (DESIGN.md invariant 13).
    pub fsdp_units: usize,
    /// Rank → host-id map for locality routing (`--hosts`); `None` =
    /// every rank on one host. Hybrid fabrics route same-host traffic
    /// over shm lanes by this map, and ring collectives walk a
    /// locality-sorted [`wire::RingOrder`] derived from it, so only
    /// `num_hosts` of the N−1 ring hops cross the slow fabric. The
    /// reorder permutes traversal, never shard ownership, and the
    /// dyadic gradient grid keeps the reduce-scatter sums exactly
    /// associative — so the trajectory stays BITWISE the
    /// identity-order one (DESIGN.md invariant 10).
    pub hosts: Option<Vec<u64>>,
    /// Trace-output base path (`--trace-out`). Coordinator-side only —
    /// NOT wire-encoded: spawned worker processes receive their
    /// per-rank path ([`telemetry::rank_trace_path`]) as a CLI flag,
    /// and thread workers share the coordinator's process tracer.
    pub trace_out: Option<String>,
}

impl Default for DistConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            adam: AdamConfig::default(),
            corpus_branch: 4,
            surrogate: SurrogateSpec::default(),
            shard_params: false,
            ft: false,
            mirror_leader: false,
            rejoin_window_ms: 0,
            ping_timeout_ms: 2000,
            fsdp_units: 1,
            hosts: None,
            trace_out: None,
        }
    }
}

/// A membership change, broadcast by the coordinator.
#[derive(Debug, Clone)]
pub struct MigrateCmd {
    /// The membership after the change (a prefix of the world).
    pub new_membership: Vec<WorkerSpec>,
    /// `survivors[new_rank]` = the old rank of the same physical
    /// worker. Over a transport, memberships are prefixes of the fixed
    /// process world, so survivor entries must be identity (`Some(i)`
    /// at index `i`) or `None` for ranks entering the membership.
    pub survivors: Vec<Option<usize>>,
    /// State ranges to move, in deterministic order.
    pub transfers: Vec<Transfer>,
    /// Adam step counter carried onto rebuilt shards.
    pub adam_step: u64,
    /// Ranks declared dead by the coordinator. Transfers whose
    /// old-layout owner is in this set are served from the owner's ft
    /// [`Mirror`] holder instead — every rank computes the same
    /// substitution, so nobody waits on a corpse.
    pub dead: Vec<usize>,
    /// Ranks that rejoined with a MISMATCHED shard fingerprint: still
    /// live (they receive and execute this command), but their
    /// resident state is untrusted, so transfers they would have
    /// SERVED are re-routed to their mirror holder exactly like a dead
    /// owner's. Unlike `dead`, a restreamed rank is re-admitted — the
    /// transfer list rebuilds its shard from trusted bytes.
    pub restream: Vec<usize>,
}

// ---- command wire codec (length-prefixed LE, no serde) --------------

const OP_INIT: u8 = 1;
pub(crate) const OP_STEP: u8 = 2;
const OP_MIGRATE: u8 = 3;
pub(crate) const OP_SHUTDOWN: u8 = 4;
/// Explicit parameter export (fully-sharded runs only): every active
/// rank streams its weight slice to rank 0, which assembles the full
/// vector — the wire counterpart of `Trainer::gather_params`.
const OP_COLLECT: u8 = 5;
/// Liveness probe: the coordinator sends `[OP_PING]`, a live worker
/// echoes `[OP_PING]` back. Pings never touch a worker's step counter,
/// so they are transparent to the corpus-alignment desync guard.
pub(crate) const OP_PING: u8 = 6;
/// Rejoin handshake. Probe (driver → suspect):
/// `[OP_REJOIN][nonce u64 LE]`. Ack (suspect → driver):
/// `[OP_REJOIN][nonce u64 LE][next_step u64 LE][fingerprint u64 LE]` —
/// the worker's step counter (corpus-alignment proof) and the FNV-1a
/// fingerprint of its resident shards ([`DistRank::fingerprint`]).
/// The nonce pairs each ack with its probe so stale echoes from
/// earlier attempts are skipped, never misread. Like `PING`, a
/// `REJOIN` probe never touches the worker's step counter.
pub(crate) const OP_REJOIN: u8 = 7;

#[derive(Default)]
struct W(Vec<u8>);

impl W {
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }
    fn u64(&mut self, x: u64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn i64(&mut self, x: i64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f64(&mut self, x: f64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
}

struct R<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> R<'a> {
    fn new(b: &'a [u8]) -> R<'a> {
        R { b, i: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(anyhow!("truncated command frame"));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn put_membership(w: &mut W, m: &[WorkerSpec]) {
    w.u64(m.len() as u64);
    for spec in m {
        w.u64(spec.batch as u64);
        w.f64(spec.state_ratio);
    }
}

fn get_membership(r: &mut R<'_>) -> Result<Vec<WorkerSpec>> {
    let n = r.u64()? as usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let batch = r.u64()? as usize;
        let state_ratio = r.f64()?;
        out.push(WorkerSpec { batch, state_ratio, name: format!("rank{i}") });
    }
    Ok(out)
}

fn encode_init(cfg: &DistConfig, membership: &[WorkerSpec]) -> Vec<u8> {
    let mut w = W::default();
    w.u8(OP_INIT);
    w.u64(cfg.seed);
    w.u64(cfg.corpus_branch as u64);
    w.u64(cfg.surrogate.vocab as u64);
    w.u64(cfg.surrogate.dim as u64);
    w.u64(cfg.surrogate.seq_len as u64);
    w.f64(cfg.adam.lr as f64);
    w.f64(cfg.adam.beta1 as f64);
    w.f64(cfg.adam.beta2 as f64);
    w.f64(cfg.adam.eps as f64);
    w.f64(cfg.adam.weight_decay as f64);
    w.u8(u8::from(cfg.shard_params));
    w.u8(u8::from(cfg.ft));
    w.u8(u8::from(cfg.mirror_leader));
    w.u64(cfg.rejoin_window_ms);
    w.u64(cfg.ping_timeout_ms);
    w.u64(cfg.fsdp_units as u64);
    match &cfg.hosts {
        Some(h) => {
            w.u8(1);
            w.u64(h.len() as u64);
            for &id in h {
                w.u64(id);
            }
        }
        None => w.u8(0),
    }
    put_membership(&mut w, membership);
    w.0
}

fn decode_init(r: &mut R<'_>) -> Result<(DistConfig, Vec<WorkerSpec>)> {
    let seed = r.u64()?;
    let corpus_branch = r.u64()? as usize;
    let surrogate = SurrogateSpec {
        vocab: r.u64()? as usize,
        dim: r.u64()? as usize,
        seq_len: r.u64()? as usize,
    };
    let adam = AdamConfig {
        lr: r.f64()? as f32,
        beta1: r.f64()? as f32,
        beta2: r.f64()? as f32,
        eps: r.f64()? as f32,
        weight_decay: r.f64()? as f32,
    };
    let shard_params = r.u8()? != 0;
    let ft = r.u8()? != 0;
    let mirror_leader = r.u8()? != 0;
    let rejoin_window_ms = r.u64()?;
    let ping_timeout_ms = r.u64()?;
    let fsdp_units = r.u64()? as usize;
    let hosts = if r.u8()? != 0 {
        let n = r.u64()? as usize;
        let mut h = Vec::with_capacity(n);
        for _ in 0..n {
            h.push(r.u64()?);
        }
        Some(h)
    } else {
        None
    };
    let membership = get_membership(r)?;
    Ok((
        DistConfig {
            seed,
            adam,
            corpus_branch,
            surrogate,
            shard_params,
            ft,
            mirror_leader,
            rejoin_window_ms,
            ping_timeout_ms,
            fsdp_units,
            hosts,
            trace_out: None,
        },
        membership,
    ))
}

fn encode_migrate(cmd: &MigrateCmd) -> Vec<u8> {
    let mut w = W::default();
    w.u8(OP_MIGRATE);
    w.u64(cmd.adam_step);
    put_membership(&mut w, &cmd.new_membership);
    w.u64(cmd.survivors.len() as u64);
    for s in &cmd.survivors {
        w.i64(s.map(|x| x as i64).unwrap_or(-1));
    }
    w.u64(cmd.transfers.len() as u64);
    for t in &cmd.transfers {
        w.i64(t.from.map(|x| x as i64).unwrap_or(-1));
        w.u64(t.to as u64);
        w.u64(t.start as u64);
        w.u64(t.len as u64);
    }
    w.u64(cmd.dead.len() as u64);
    for d in &cmd.dead {
        w.u64(*d as u64);
    }
    w.u64(cmd.restream.len() as u64);
    for d in &cmd.restream {
        w.u64(*d as u64);
    }
    w.0
}

fn decode_migrate(r: &mut R<'_>) -> Result<MigrateCmd> {
    let adam_step = r.u64()?;
    let new_membership = get_membership(r)?;
    let n = r.u64()? as usize;
    let mut survivors = Vec::with_capacity(n);
    for _ in 0..n {
        let s = r.i64()?;
        survivors.push(if s < 0 { None } else { Some(s as usize) });
    }
    let nt = r.u64()? as usize;
    let mut transfers = Vec::with_capacity(nt);
    for _ in 0..nt {
        let from = r.i64()?;
        transfers.push(Transfer {
            from: if from < 0 { None } else { Some(from as usize) },
            to: r.u64()? as usize,
            start: r.u64()? as usize,
            len: r.u64()? as usize,
        });
    }
    let nd = r.u64()? as usize;
    let mut dead = Vec::with_capacity(nd);
    for _ in 0..nd {
        dead.push(r.u64()? as usize);
    }
    let nr = r.u64()? as usize;
    let mut restream = Vec::with_capacity(nr);
    for _ in 0..nr {
        restream.push(r.u64()? as usize);
    }
    Ok(MigrateCmd {
        new_membership,
        survivors,
        transfers,
        adam_step,
        dead,
        restream,
    })
}

/// The old-layout owner of flat position `pos` (the process that holds
/// the bytes, whether or not it is still in the membership).
fn owner_of(layout: &ShardLayout, pos: usize) -> Result<usize> {
    (0..layout.num_ranks())
        .find(|&r| layout.range(r).contains(&pos))
        .ok_or_else(|| anyhow!("flat position {pos} outside the layout"))
}

fn layout_of(membership: &[WorkerSpec], flat_len: usize) -> ShardLayout {
    // EXACTLY Trainer::from_executor's derivation, so the dist and
    // in-process shard boundaries agree bit for bit.
    let ratios: Vec<f64> =
        membership.iter().map(|w| w.state_ratio.max(0.0)).collect();
    ShardLayout::by_ratios(flat_len, &ratios)
}

/// The locality-sorted ring order for a `group`-rank membership:
/// same-host ranks adjacent per the topology, identity without one.
/// Memberships are prefixes of the process world, so the host map may
/// name MORE ranks than the group — never fewer.
fn ring_order(
    topo: &Option<HostTopology>,
    group: usize,
) -> Result<wire::RingOrder> {
    match topo {
        Some(t) => {
            if t.world_size() < group {
                return Err(anyhow!(
                    "host map names {} ranks, membership has {group}",
                    t.world_size()
                ));
            }
            Ok(wire::RingOrder::from_topology(t, group))
        }
        None => Ok(wire::RingOrder::identity(group.max(1))),
    }
}

/// The host map a hybrid fabric routes by: `DistConfig::hosts`
/// verbatim (it must cover the whole process world), or everyone on
/// one host when unset — a degenerate-but-valid map where every lane
/// takes the shm fast path.
fn hybrid_topology(cfg: &DistConfig, world: usize) -> Result<HostTopology> {
    match &cfg.hosts {
        Some(h) => {
            if h.len() != world {
                return Err(anyhow!(
                    "host map names {} ranks, fabric has {world}",
                    h.len()
                ));
            }
            Ok(HostTopology::new(h.clone()))
        }
        None => Ok(HostTopology::single_host(world)),
    }
}

/// Per-rank `--trace-out` args for a spawned worker process: each rank
/// writes its own trace file ([`telemetry::rank_trace_path`]); empty
/// when tracing was not requested.
fn trace_args(cfg: &DistConfig, rank: usize) -> Vec<String> {
    match &cfg.trace_out {
        Some(base) => vec![
            "--trace-out".into(),
            telemetry::rank_trace_path(base, rank),
        ],
        None => Vec::new(),
    }
}

/// EXACTLY `Trainer::unit_plan`'s derivation, so the dist and
/// in-process unit boundaries agree bit for bit.
fn unit_plan(
    exec: &NativeExecutor,
    layout: &ShardLayout,
    shard_params: bool,
    fsdp_units: usize,
) -> UnitLayout {
    if shard_params && fsdp_units > 1 {
        UnitLayout::for_prefix(
            layout,
            exec.unit_region(),
            exec.unit_alignment(),
            fsdp_units,
        )
    } else {
        UnitLayout::whole(layout)
    }
}

/// Events traced by [`drive_overlapped`], in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapEvent {
    /// One compute chunk ran.
    Compute,
    /// One ring round of the prefetch AllGather was driven.
    CommRound,
}

/// The compute/comm overlap scheduler: run `chunks` compute chunks,
/// driving one ring round of the `prefetch` AllGather after each (unit
/// k's compute interleaved with unit k+1's gather on the same
/// endpoint), then drain the remaining rounds. Only ONE collective is
/// ever in flight, so ranks may run different chunk counts (uneven
/// `b_i`) without violating the op-interleaving contract — the wire
/// sees the same op order everywhere, just at different times.
/// `trace` records the interleaving for tests.
pub(crate) fn drive_overlapped(
    t: &mut dyn Transport,
    mut prefetch: Option<&mut wire::AllGatherOp>,
    chunks: usize,
    mut compute_chunk: impl FnMut(usize) -> Result<()>,
    mut trace: impl FnMut(OverlapEvent),
) -> Result<()> {
    for c in 0..chunks {
        compute_chunk(c)?;
        trace(OverlapEvent::Compute);
        if let Some(op) = prefetch.as_deref_mut() {
            if !op.is_done() {
                op.step_round(t)?;
                trace(OverlapEvent::CommRound);
            }
        }
    }
    if let Some(op) = prefetch {
        while !op.is_done() {
            op.step_round(t)?;
            trace(OverlapEvent::CommRound);
        }
    }
    Ok(())
}

/// Where one rank's ft backup lives: ring-successor placement with a
/// rank-0 fallback for tiny groups. Every rank computes the same map
/// locally from the membership size — placement is never negotiated on
/// the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MirrorLayout {
    group: usize,
}

impl MirrorLayout {
    /// The placement map for a `group`-rank membership.
    pub fn new(group: usize) -> MirrorLayout {
        MirrorLayout { group }
    }

    /// The rank holding `owner`'s backup shard: the ring successor
    /// `(owner + 1) % group`, except at `group <= 2` where rank 0
    /// holds everything (with two ranks the "successor" of each is the
    /// other, and rank 0 — the coordinator, which cannot die — is the
    /// only holder that survives every admissible failure).
    pub fn holder(&self, owner: usize) -> usize {
        if self.group <= 2 {
            0
        } else {
            (owner + 1) % self.group
        }
    }

    /// The owners whose backups `holder` keeps, ascending.
    pub fn sources(&self, holder: usize) -> Vec<usize> {
        (0..self.group).filter(|&o| self.holder(o) == holder).collect()
    }
}

/// One owner's backup shard under the sharded mirror: the owner's
/// post-step moments (and, fully-sharded, weight slice) at flat
/// offset `start`.
struct Backup {
    start: usize,
    m: Vec<f32>,
    v: Vec<f32>,
    w: Option<Vec<f32>>,
}

/// A rank's copy of cluster backup state, kept current by
/// [`DistRank::ft_sync`]. Flat positions, not ranks, index both
/// variants, so a mirror is valid across membership changes; after
/// step k it holds exactly the bytes each rank held at the k/k+1
/// boundary. Weight planes are populated only in fully-sharded mode —
/// leader-resident runs already keep the full weights on rank 0.
enum Mirror {
    /// The legacy placement (`DistConfig::mirror_leader`): rank 0
    /// folds every rank's backup into one flat copy.
    Leader { m: Vec<f32>, v: Vec<f32>, w: Option<Vec<f32>> },
    /// The default [`MirrorLayout`] placement: this rank holds the
    /// backups of the owners whose ring successor it is, keyed by
    /// owner rank.
    Sharded { backups: BTreeMap<usize, Backup> },
}

/// One rank's SPMD training state.
pub struct DistRank {
    rank: usize,
    exec: NativeExecutor,
    corpus: Corpus,
    /// Leader-resident mode: the full parameters, rebuilt every step by
    /// the tail AllGather. EMPTY in fully-sharded mode (no rank holds a
    /// full copy between steps).
    params: Vec<Vec<f32>>,
    sizes: Vec<usize>,
    membership: Vec<WorkerSpec>,
    layout: ShardLayout,
    /// `None` while this rank is standby (outside the membership).
    shard: Option<AdamShard>,
    adam: AdamConfig,
    /// Fully-sharded weights: this rank's `layout.range(rank)` slice
    /// (`None` for standby ranks and in leader-resident mode).
    param_shard: Option<Vec<f32>>,
    shard_params: bool,
    /// Requested FSDP unit count (`<= 1` = whole-model gather).
    fsdp_units: usize,
    /// The unit plan over `layout`; rebuilt on every migration.
    units: UnitLayout,
    /// Host topology for locality-sorted rings (`None` = identity).
    topo: Option<HostTopology>,
    /// The ring order over the current membership, rebuilt on every
    /// migration — same-host ranks adjacent, so only `num_hosts` of
    /// the N−1 ring hops cross the slow fabric.
    order: wire::RingOrder,
    /// Fault tolerance on: run the per-step [`DistRank::ft_sync`].
    ft: bool,
    /// Legacy leader mirror placement (everything on rank 0) instead
    /// of the default sharded [`MirrorLayout`].
    mirror_leader: bool,
    /// With `ft`: rank 0's flat mirror (leader placement), or this
    /// rank's slice of backups (sharded placement; active ranks only).
    mirror: Option<Mirror>,
    /// Flat gather scratch, recycled across steps (and across units
    /// within a step) so the sharded hot path performs no per-step
    /// full-weight allocation.
    scratch: Vec<f32>,
    /// ABI-shaped materialized-weights buffer for the whole-gather
    /// path, reused across steps.
    full_scratch: Vec<Vec<f32>>,
    /// Phase timings of the most recent step, measured UNCONDITIONALLY
    /// (they ride the STEP wire reply — invariant 14).
    last_phases: PhaseBreakdown,
}

impl DistRank {
    /// Stand up one rank from the broadcast `INIT` payload: build the
    /// executor, derive the shard layout and seed the local state.
    pub fn init(
        rank: usize,
        cfg: &DistConfig,
        membership: Vec<WorkerSpec>,
    ) -> Result<DistRank> {
        if membership.is_empty() {
            return Err(anyhow!("need at least one member rank"));
        }
        let exec = NativeExecutor::new(cfg.surrogate.clone());
        let sizes = exec.param_sizes().to_vec();
        let flat_len: usize = sizes.iter().sum();
        let init = exec.init_params(cfg.seed);
        let corpus = Corpus::new(exec.vocab(), cfg.corpus_branch, cfg.seed);
        let layout = layout_of(&membership, flat_len);
        let active = rank < membership.len();
        let shard =
            active.then(|| AdamShard::new(layout.size(rank), cfg.adam));
        let leads = rank == 0 && cfg.ft && cfg.mirror_leader;
        let (params, param_shard, init_flat) = if cfg.shard_params {
            // Keep only this rank's slice of the deterministic init;
            // the full flat copy survives only where a mirror needs a
            // weight plane (after a crash nobody else holds the dead
            // rank's slice).
            let flat = crate::trainer::flatten(&init, flat_len);
            let ps = active.then(|| flat[layout.range(rank)].to_vec());
            (Vec::new(), ps, cfg.ft.then_some(flat))
        } else {
            (init, None, None)
        };
        // Mirrors are populated LOCALLY at init — every rank derives
        // the same deterministic init state, so standing up either
        // placement costs zero wire traffic.
        let mirror = if cfg.ft && cfg.mirror_leader {
            leads.then(|| Mirror::Leader {
                m: vec![0f32; flat_len],
                v: vec![0f32; flat_len],
                w: init_flat.clone(),
            })
        } else if cfg.ft && active {
            let ml = MirrorLayout::new(membership.len());
            let mut backups = BTreeMap::new();
            for src in ml.sources(rank) {
                let range = layout.range(src);
                if range.is_empty() {
                    continue;
                }
                backups.insert(
                    src,
                    Backup {
                        start: range.start,
                        m: vec![0f32; range.len()],
                        v: vec![0f32; range.len()],
                        w: init_flat
                            .as_ref()
                            .map(|f| f[range.clone()].to_vec()),
                    },
                );
            }
            Some(Mirror::Sharded { backups })
        } else {
            None
        };
        let units = unit_plan(
            &exec,
            &layout,
            cfg.shard_params,
            cfg.fsdp_units,
        );
        let topo =
            cfg.hosts.as_ref().map(|h| HostTopology::new(h.clone()));
        let order = ring_order(&topo, membership.len())?;
        Ok(DistRank {
            rank,
            exec,
            corpus,
            params,
            sizes,
            membership,
            layout,
            shard,
            adam: cfg.adam,
            param_shard,
            shard_params: cfg.shard_params,
            fsdp_units: cfg.fsdp_units,
            units,
            topo,
            order,
            ft: cfg.ft,
            mirror_leader: cfg.mirror_leader,
            mirror,
            scratch: Vec::new(),
            full_scratch: Vec::new(),
            last_phases: PhaseBreakdown::default(),
        })
    }

    /// The current membership (what the shard layout is derived from).
    pub fn membership(&self) -> &[WorkerSpec] {
        &self.membership
    }

    /// The leader-resident full parameters (empty in sharded mode —
    /// use the COLLECT path / `DistDriver::gather_params`).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// This rank's weight slice (`Some` only in fully-sharded mode on
    /// active ranks).
    pub fn param_shard_view(&self) -> Option<&[f32]> {
        self.param_shard.as_deref()
    }

    /// Whether parameters are fully sharded (no leader copy).
    pub fn is_sharded(&self) -> bool {
        self.shard_params
    }

    /// Per-tensor flat lengths of the executed model.
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// The uneven shard layout over the flat state.
    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    fn flat_len(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Phase timings of the most recent [`DistRank::step`] (zeros for
    /// standby ranks and before the first step).
    pub fn last_phases(&self) -> PhaseBreakdown {
        self.last_phases
    }

    /// FNV-1a 64 digest of this rank's resident training state: the
    /// Adam step counter, both moment shards and (fully-sharded) the
    /// weight slice, mixed as bit patterns — so two states are
    /// fingerprint-equal only when they are BITWISE equal. Standby
    /// ranks (no shard) digest to the bare offset basis. The rejoin
    /// handshake compares this against the driver's ledger to decide
    /// resume-in-place vs. re-stream.
    pub fn fingerprint(&self) -> u64 {
        const BASIS: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = BASIS;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(PRIME);
        };
        if let Some(shard) = &self.shard {
            mix(shard.step);
            for x in shard.m.iter().chain(shard.v.iter()) {
                mix(x.to_bits() as u64);
            }
        }
        if let Some(w) = &self.param_shard {
            for x in w {
                mix(x.to_bits() as u64);
            }
        }
        h
    }

    /// One SPMD step; returns this rank's `(loss_sum, token_count)`
    /// contribution (zeros for standby ranks, which only advance the
    /// corpus stream).
    pub fn step(&mut self, t: &mut dyn Transport) -> Result<(f64, f64)> {
        let seq = self.exec.seq_len();
        let b: usize = self.membership.iter().map(|w| w.batch).sum();
        if b == 0 {
            return Err(anyhow!("global batch is zero"));
        }
        // Every rank samples the SAME global batch (shared corpus
        // stream) — standby ranks too, so rejoining keeps alignment.
        let (tokens, targets) = self.corpus.sample_batch(b, seq);
        let group = self.membership.len();
        if self.rank >= group {
            self.last_phases = PhaseBreakdown::default();
            return Ok((0.0, 0.0));
        }
        if b * seq > MAX_STEP_TOKENS {
            return Err(anyhow!(
                "{} tokens/step exceeds the exact-summation bound \
                 {MAX_STEP_TOKENS} (shrink batch or seq_len)",
                b * seq
            ));
        }
        let batches: Vec<usize> =
            self.membership.iter().map(|w| w.batch).collect();
        let parts = split_batch(&tokens, &targets, seq, &batches);
        let (my_tokens, my_targets) = parts
            .into_iter()
            .nth(self.rank)
            .expect("rank within membership");

        // Unit-pipelined FSDP path: per-unit wire gathers overlapped
        // with compute instead of one whole-model gather.
        if self.units.num_units() > 1 {
            return self.step_units(t, &my_tokens, &my_targets, b);
        }

        let flat_len = self.flat_len();
        // Materialize the full weights: resident in leader mode; in
        // fully-sharded mode a head-of-step wire AllGather of the
        // per-rank slices — bitwise the vector the leader path rebuilt
        // at the previous step's tail. The gather lands in persistent
        // scratch buffers (recycled step to step — the gather
        // overwrites every element), so the hot path performs no
        // per-step full-weight allocation.
        let mut phases = PhaseBreakdown::default();
        let use_scratch = self.shard_params;
        if self.shard_params {
            let sp = telemetry::span(telemetry::CAT_GATHER, "param allgather");
            let tg = Instant::now();
            let mine = self.param_shard.as_deref().ok_or_else(|| {
                anyhow!("active rank {} has no parameter shard", self.rank)
            })?;
            let mut op = wire::AllGatherOp::start_into_ordered(
                &*t,
                mine,
                &self.layout,
                std::mem::take(&mut self.scratch),
                &self.order,
            )?;
            while !op.step_round(t)? {}
            let flat = op.finish()?;
            unflatten_into(&flat, &self.sizes, &mut self.full_scratch);
            self.scratch = flat;
            phases.gather_s += tg.elapsed().as_secs_f64();
            drop(sp);
        }
        let full: &[Vec<f32>] =
            if use_scratch { &self.full_scratch } else { &self.params };
        let tc = Instant::now();
        let (my_grad, my_loss, my_count) = if my_tokens.is_empty() {
            // A state-only rank (b_i = 0) contributes an exact zero
            // vector — bitwise what `worker_pass` returns on no rows.
            (vec![0f32; flat_len], 0.0, 0.0)
        } else {
            let part = vec![(my_tokens, my_targets)];
            let out = self.exec.run_step(full, &part)?;
            let g = out
                .worker_grads
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("backend returned no gradients"))?;
            (g, out.loss_sum, out.token_count)
        };
        phases.compute_s += tc.elapsed().as_secs_f64();

        // Eq.-1 denominator: the GLOBAL token count, known to all ranks
        // from the membership (sums of exact integers — identical to
        // the leader's f64 accumulation).
        let token_count = (b * seq) as f64;

        let sp = telemetry::span(telemetry::CAT_REDUCE_SCATTER, "grad rs");
        let tr = Instant::now();
        let mut grad_shard = wire::ring_reduce_scatter_ordered(
            t,
            &my_grad,
            &self.layout,
            &self.order,
        )?;
        phases.reduce_scatter_s += tr.elapsed().as_secs_f64();
        drop(sp);
        let inv = 1.0 / token_count as f32;
        for g in grad_shard.iter_mut() {
            *g *= inv;
        }

        let range = self.layout.range(self.rank);
        let shard = self
            .shard
            .as_mut()
            .ok_or_else(|| anyhow!("active rank {} has no shard", self.rank))?;
        if self.shard_params {
            // Update the resident slice in place; no tail AllGather —
            // the next step's head gather re-materializes.
            let sp = telemetry::span(telemetry::CAT_OPTIMIZER, "sharded adam");
            let ta = Instant::now();
            let mut mine = self.param_shard.take().ok_or_else(|| {
                anyhow!("active rank {} has no parameter shard", self.rank)
            })?;
            shard.update(&mut mine, &grad_shard);
            self.param_shard = Some(mine);
            phases.optimizer_s += ta.elapsed().as_secs_f64();
            drop(sp);
        } else {
            let sp = telemetry::span(telemetry::CAT_OPTIMIZER, "sharded adam");
            let ta = Instant::now();
            let mut flat = flatten(&self.params, flat_len);
            shard.update(&mut flat[range.clone()], &grad_shard);
            let shard_view = flat[range].to_vec();
            phases.optimizer_s += ta.elapsed().as_secs_f64();
            drop(sp);
            let sp = telemetry::span(telemetry::CAT_GATHER, "tail allgather");
            let tg = Instant::now();
            let gathered = wire::ring_allgather_ordered(
                t,
                &shard_view,
                &self.layout,
                &self.order,
            )?;
            self.params = unflatten(&gathered, &self.sizes);
            phases.gather_s += tg.elapsed().as_secs_f64();
            drop(sp);
        }
        self.last_phases = phases;
        Ok((my_loss, my_count))
    }

    /// The unit-pipelined SPMD step: gather unit k+1's weights on the
    /// wire WHILE unit k computes (one [`wire::AllGatherOp`] round
    /// between per-row compute chunks, via [`drive_overlapped`]), free
    /// each unit after its gradients are reduce-scattered, and keep
    /// only the tail (the executor's non-unit suffix) materialized
    /// across the step. The wire sees a strictly sequential op order —
    /// tail AG, AG 0, AG 1, RS 0, AG 2, RS 1, … — identical on every
    /// rank; only compute overlaps communication, so uneven `b_i`
    /// chunk counts cannot violate the op-interleaving contract.
    /// Per-unit gradient shards concatenate exactly to the global
    /// `r_i` shard and the dyadic grid makes every partial sum exactly
    /// associative, so the trajectory is BITWISE the whole-gather one
    /// (DESIGN.md invariant 13); only the f64 loss accumulation order
    /// differs (last bits, never parameters). Freed unit buffers are
    /// recycled into the next gather — steady state allocates no
    /// weight-sized buffers.
    fn step_units(
        &mut self,
        t: &mut dyn Transport,
        my_tokens: &[i32],
        my_targets: &[i32],
        b: usize,
    ) -> Result<(f64, f64)> {
        let seq = self.exec.seq_len();
        let flat_len = self.flat_len();
        let me = self.rank;
        let nu = self.units.num_units();
        let region = self.exec.unit_region().min(flat_len);
        let d = self.exec.unit_alignment().max(1);
        let tail_is_unit = region < flat_len;
        let table_units = nu - usize::from(tail_is_unit);
        let my_rows = my_tokens.len() / seq;
        let token_count = (b * seq) as f64;

        let mut loss = 0f64;
        let mut phases = PhaseBreakdown::default();
        let mut compute_acc = 0f64;
        let mut overlap_acc = 0f64;
        let mut pieces: Vec<Vec<f32>> = Vec::with_capacity(nu);
        {
            let mine = self.param_shard.as_deref().ok_or_else(|| {
                anyhow!("active rank {me} has no parameter shard")
            })?;
            let base = self.layout.range(me).start;
            let ul = &self.units;
            let slice = |u: usize| -> &[f32] {
                let s = ul.rank_slice(u, me);
                &mine[s.start - base..s.end - base]
            };
            // Head-of-step tail gather (tiny — the native surrogate's
            // bias), then unit 0, both blocking: nothing to overlap
            // with yet.
            let sp =
                telemetry::span(telemetry::CAT_GATHER, "tail+unit0 ag");
            let tg = Instant::now();
            let tail: Vec<f32> = if tail_is_unit {
                wire::ring_allgather_ordered(
                    t,
                    slice(nu - 1),
                    ul.unit_layout(nu - 1),
                    &self.order,
                )?
            } else {
                Vec::new()
            };
            let mut tail_g = vec![0f32; tail.len()];
            let mut spare = std::mem::take(&mut self.scratch);
            let mut current = {
                let mut op = wire::AllGatherOp::start_into_ordered(
                    &*t,
                    slice(0),
                    ul.unit_layout(0),
                    spare,
                    &self.order,
                )?;
                while !op.step_round(t)? {}
                op.finish()?
            };
            phases.gather_s += tg.elapsed().as_secs_f64();
            drop(sp);
            spare = Vec::new();
            for k in 0..table_units {
                let mut next_op = if k + 1 < table_units {
                    Some(wire::AllGatherOp::start_into_ordered(
                        &*t,
                        slice(k + 1),
                        ul.unit_layout(k + 1),
                        std::mem::take(&mut spare),
                        &self.order,
                    )?)
                } else {
                    None
                };
                // Compute unit k row by row, driving one gather round
                // of unit k+1 between rows, then drain the gather.
                let urange = ul.unit_range(k);
                let rows = urange.start / d..urange.end / d;
                let mut unit_g = vec![0f32; urange.len()];
                let sp = telemetry::span(
                    telemetry::CAT_COMPUTE,
                    "unit compute+prefetch",
                );
                let td = Instant::now();
                drive_overlapped(
                    t,
                    next_op.as_mut(),
                    my_rows,
                    |c| {
                        let tk = &my_tokens[c * seq..(c + 1) * seq];
                        let tg = &my_targets[c * seq..(c + 1) * seq];
                        let t1 = Instant::now();
                        loss += self.exec.unit_pass_chunk(
                            rows.clone(),
                            &current,
                            &tail,
                            tk,
                            tg,
                            &mut unit_g,
                            &mut tail_g,
                        )?;
                        compute_acc += t1.elapsed().as_secs_f64();
                        Ok(())
                    },
                    |_| {},
                )?;
                overlap_acc += td.elapsed().as_secs_f64();
                drop(sp);
                // Unit k is done: recycle its buffer, reduce-scatter
                // its gradients onto the owning ranks.
                spare = current;
                let sp =
                    telemetry::span(telemetry::CAT_REDUCE_SCATTER, "unit rs");
                let tr = Instant::now();
                pieces.push(wire::ring_reduce_scatter_ordered(
                    t,
                    &unit_g,
                    ul.unit_layout(k),
                    &self.order,
                )?);
                phases.reduce_scatter_s += tr.elapsed().as_secs_f64();
                drop(sp);
                current = match next_op {
                    Some(op) => op.finish()?,
                    None => Vec::new(),
                };
            }
            if tail_is_unit {
                let sp =
                    telemetry::span(telemetry::CAT_REDUCE_SCATTER, "tail rs");
                let tr = Instant::now();
                pieces.push(wire::ring_reduce_scatter_ordered(
                    t,
                    &tail_g,
                    ul.unit_layout(nu - 1),
                    &self.order,
                )?);
                phases.reduce_scatter_s += tr.elapsed().as_secs_f64();
                drop(sp);
            }
            self.scratch = spare;
        }

        // This rank's global gradient shard is its per-unit slices
        // concatenated in unit order (they tile layout.range(me)
        // exactly), then the Eq.-1 scale — bitwise the whole-gather
        // ReduceScatter by exact associativity.
        let mut grad_shard: Vec<f32> =
            Vec::with_capacity(self.layout.size(me));
        for p in &pieces {
            grad_shard.extend_from_slice(p);
        }
        let inv = 1.0 / token_count as f32;
        for g in grad_shard.iter_mut() {
            *g *= inv;
        }
        let shard = self.shard.as_mut().ok_or_else(|| {
            anyhow!("active rank {me} has no shard")
        })?;
        let sp = telemetry::span(telemetry::CAT_OPTIMIZER, "sharded adam");
        let ta = Instant::now();
        let mut mine = self.param_shard.take().ok_or_else(|| {
            anyhow!("active rank {me} has no parameter shard")
        })?;
        shard.update(&mut mine, &grad_shard);
        self.param_shard = Some(mine);
        phases.optimizer_s += ta.elapsed().as_secs_f64();
        drop(sp);
        // The drive_overlapped window covers compute AND the prefetch
        // gather rounds driven between chunks; the remainder after
        // subtracting pure compute is time spent waiting on the wire.
        phases.compute_s = compute_acc;
        phases.overlap_wait_s = (overlap_acc - compute_acc).max(0.0);
        self.last_phases = phases;
        Ok((loss, my_tokens.len() as f64))
    }

    /// Ship this rank's weight slice to rank 0 — the worker half of the
    /// COLLECT export (fully-sharded runs only). Standby ranks and
    /// empty slices stay silent; the coordinator skips them by layout.
    pub fn send_param_shard(&self, t: &mut dyn Transport) -> Result<()> {
        if !self.shard_params {
            return Err(anyhow!("COLLECT on a leader-resident rank"));
        }
        if self.rank >= self.membership.len()
            || self.layout.size(self.rank) == 0
        {
            return Ok(());
        }
        let mine = self.param_shard.as_deref().ok_or_else(|| {
            anyhow!("active rank {} has no parameter shard", self.rank)
        })?;
        t.send_f32(0, mine)
    }

    /// Per-step mirror sync (ft runs only; no-op otherwise). Leader
    /// placement: active workers stream their post-step moments (and,
    /// fully-sharded, weight slice) to rank 0, which folds every live
    /// range into the flat [`Mirror::Leader`]. Sharded placement:
    /// every active rank ships its shard to its [`MirrorLayout`]
    /// holder instead ([`DistRank::mirror_shift`]). Pure copies on the
    /// side — the training trajectory never reads the mirror, so the
    /// sync is bitwise-invisible.
    ///
    /// Frame order is safe by per-lane FIFO: a worker's step reply
    /// (bytes) precedes its ft frames (f32), and the driver folds all
    /// replies before rank 0 receives here.
    pub fn ft_sync(&mut self, t: &mut dyn Transport) -> Result<()> {
        if !self.ft {
            return Ok(());
        }
        if !self.mirror_leader {
            return self.mirror_shift(t);
        }
        let group = self.membership.len();
        if self.rank != 0 {
            if self.rank >= group || self.layout.size(self.rank) == 0 {
                return Ok(());
            }
            let shard = self.shard.as_ref().ok_or_else(|| {
                anyhow!("active rank {} has no shard", self.rank)
            })?;
            t.send_f32(0, &shard.m)?;
            t.send_f32(0, &shard.v)?;
            if self.shard_params {
                let w = self.param_shard.as_deref().ok_or_else(|| {
                    anyhow!(
                        "active rank {} has no parameter shard",
                        self.rank
                    )
                })?;
                t.send_f32(0, w)?;
            }
            return Ok(());
        }
        let Some(Mirror::Leader { m, v, w }) = self.mirror.as_mut() else {
            return Err(anyhow!("ft_sync on rank 0 without a leader mirror"));
        };
        if let Some(shard) = self.shard.as_ref() {
            let r0 = self.layout.range(0);
            m[r0.clone()].copy_from_slice(&shard.m);
            v[r0.clone()].copy_from_slice(&shard.v);
            if let (Some(ws), Some(mw)) =
                (self.param_shard.as_deref(), w.as_mut())
            {
                mw[r0].copy_from_slice(ws);
            }
        }
        for r in 1..group {
            let sz = self.layout.size(r);
            if sz == 0 {
                continue;
            }
            let range = self.layout.range(r);
            let m_in = t.recv_f32(r)?;
            let v_in = t.recv_f32(r)?;
            if m_in.len() != sz || v_in.len() != sz {
                return Err(anyhow!(
                    "ft sync from rank {r} holds {}+{} elems, wanted {sz}",
                    m_in.len(),
                    v_in.len()
                ));
            }
            m[range.clone()].copy_from_slice(&m_in);
            v[range.clone()].copy_from_slice(&v_in);
            if self.shard_params {
                let w_in = t.recv_f32(r)?;
                if w_in.len() != sz {
                    return Err(anyhow!(
                        "ft weight sync from rank {r} holds {} elems, \
                         wanted {sz}",
                        w_in.len()
                    ));
                }
                w.as_mut()
                    .ok_or_else(|| {
                        anyhow!("sharded ft mirror has no weight plane")
                    })?[range]
                    .copy_from_slice(&w_in);
            }
        }
        Ok(())
    }

    /// The sharded-mirror sync: every active rank ships its post-step
    /// shard to its [`MirrorLayout`] holder and collects the backups
    /// it holds for others, walking owners in global rank order (one
    /// point-to-point per owner — sends never block and recvs follow
    /// per-lane FIFO, so the walk is deadlock-free with zero transport
    /// buffering). Standby ranks hold no backups: a rank outside the
    /// membership may itself die, so nothing may depend on its copy.
    fn mirror_shift(&mut self, t: &mut dyn Transport) -> Result<()> {
        let group = self.membership.len();
        if self.rank >= group {
            self.mirror = None;
            return Ok(());
        }
        let ml = MirrorLayout::new(group);
        let mut backups = BTreeMap::new();
        for src in 0..group {
            let range = self.layout.range(src);
            if range.is_empty() {
                continue;
            }
            let holder = ml.holder(src);
            if src == self.rank {
                let shard = self.shard.as_ref().ok_or_else(|| {
                    anyhow!("active rank {src} has no shard")
                })?;
                if holder == self.rank {
                    // Self-placement (group <= 2 on rank 0): a local
                    // copy, no wire traffic.
                    backups.insert(
                        src,
                        Backup {
                            start: range.start,
                            m: shard.m.clone(),
                            v: shard.v.clone(),
                            w: self.param_shard.clone(),
                        },
                    );
                } else {
                    t.send_f32(holder, &shard.m)?;
                    t.send_f32(holder, &shard.v)?;
                    if self.shard_params {
                        let w = self.param_shard.as_deref().ok_or_else(
                            || {
                                anyhow!(
                                    "active rank {src} has no parameter \
                                     shard"
                                )
                            },
                        )?;
                        t.send_f32(holder, w)?;
                    }
                }
            } else if holder == self.rank {
                let m_in = t.recv_f32(src)?;
                let v_in = t.recv_f32(src)?;
                if m_in.len() != range.len() || v_in.len() != range.len() {
                    return Err(anyhow!(
                        "mirror shift from rank {src} holds {}+{} elems, \
                         wanted {}",
                        m_in.len(),
                        v_in.len(),
                        range.len()
                    ));
                }
                let w_in = if self.shard_params {
                    let w = t.recv_f32(src)?;
                    if w.len() != range.len() {
                        return Err(anyhow!(
                            "mirror weight shift from rank {src} holds \
                             {} elems, wanted {}",
                            w.len(),
                            range.len()
                        ));
                    }
                    Some(w)
                } else {
                    None
                };
                backups.insert(
                    src,
                    Backup {
                        start: range.start,
                        m: m_in,
                        v: v_in,
                        w: w_in,
                    },
                );
            }
        }
        self.mirror = Some(Mirror::Sharded { backups });
        Ok(())
    }

    /// Apply a membership change: local resident copy, peer transfers
    /// over the wire, params stream to ranks entering the membership.
    pub fn migrate(
        &mut self,
        t: &mut dyn Transport,
        cmd: &MigrateCmd,
    ) -> Result<()> {
        if cmd.new_membership.is_empty() {
            return Err(anyhow!("migration to an empty membership"));
        }
        if cmd.survivors.len() != cmd.new_membership.len() {
            return Err(anyhow!(
                "{} survivor entries for {} members",
                cmd.survivors.len(),
                cmd.new_membership.len()
            ));
        }
        for (i, s) in cmd.survivors.iter().enumerate() {
            if let Some(j) = s {
                if *j != i {
                    return Err(anyhow!(
                        "non-prefix survivor map (new rank {i} was old \
                         rank {j}): transport ranks are pinned to \
                         process ranks"
                    ));
                }
            }
        }
        let flat_len = self.flat_len();
        let old_layout = self.layout.clone();
        let new_layout = layout_of(&cmd.new_membership, flat_len);
        let new_group = cmd.new_membership.len();
        let is_active = self.rank < new_group;

        // Resident prefill: the overlap of my old and new ranges never
        // leaves this rank (mirrors `elastic::apply_migration`). In
        // fully-sharded mode the weight slice migrates exactly like the
        // moments — same ranges, same transfer list.
        let mut new_m = vec![0f32; if is_active { new_layout.size(self.rank) } else { 0 }];
        let mut new_v = vec![0f32; new_m.len()];
        let mut new_w =
            vec![0f32; if self.shard_params { new_m.len() } else { 0 }];
        if is_active && cmd.survivors[self.rank].is_some() {
            let old = self
                .shard
                .as_ref()
                .ok_or_else(|| anyhow!("survivor {} has no shard", self.rank))?;
            let nr = new_layout.range(self.rank);
            let or = old_layout.range(self.rank);
            let lo = nr.start.max(or.start);
            let hi = nr.end.min(or.end);
            if lo < hi {
                new_m[lo - nr.start..hi - nr.start]
                    .copy_from_slice(&old.m[lo - or.start..hi - or.start]);
                new_v[lo - nr.start..hi - nr.start]
                    .copy_from_slice(&old.v[lo - or.start..hi - or.start]);
                if self.shard_params {
                    let w = self.param_shard.as_ref().ok_or_else(|| {
                        anyhow!(
                            "survivor {} has no parameter shard",
                            self.rank
                        )
                    })?;
                    new_w[lo - nr.start..hi - nr.start].copy_from_slice(
                        &w[lo - or.start..hi - or.start],
                    );
                }
            }
        }

        // The transfer list, in list order on every rank (frames are
        // FIFO per pair, sends never block: deadlock-free by
        // induction on list position). An UNTRUSTED owner's ranges —
        // dead, or live-but-restreamed after a fingerprint-mismatch
        // rejoin — are served by its mirror holder: rank 0 under the
        // leader mirror, `MirrorLayout::holder(owner)` under the
        // default sharded mirror. Same list position, same payloads
        // the owner would have sent (the mirror holds its boundary
        // state), so the recovered bytes are bitwise the
        // graceful-departure bytes. Every rank (including ranks
        // declared dead that are in fact still running) computes the
        // same substitution, so nobody waits on the corpse.
        let old_group = self.membership.len();
        let ml = MirrorLayout::new(old_group);
        for tr in &cmd.transfers {
            let owner = owner_of(&old_layout, tr.start)?;
            if tr.start + tr.len > old_layout.range(owner).end {
                return Err(anyhow!(
                    "transfer [{}, +{}) spans old-shard boundaries",
                    tr.start,
                    tr.len
                ));
            }
            let untrusted = cmd.dead.contains(&owner)
                || cmd.restream.contains(&owner);
            let src = if !untrusted {
                owner
            } else if self.mirror_leader {
                0
            } else {
                let holder = ml.holder(owner);
                if cmd.dead.contains(&holder) {
                    return Err(anyhow!(
                        "rank {owner}'s mirror holder {holder} is also \
                         dead: correlated failure exceeds the sharded \
                         mirror's budget"
                    ));
                }
                holder
            };
            if self.rank == src && untrusted {
                match self.mirror.as_ref() {
                    Some(Mirror::Leader { m, v, w }) => {
                        let s = tr.start..tr.start + tr.len;
                        t.send_f32(tr.to, &m[s.clone()])?;
                        t.send_f32(tr.to, &v[s.clone()])?;
                        if self.shard_params {
                            let w = w.as_deref().ok_or_else(|| {
                                anyhow!(
                                    "leader ft mirror has no weight \
                                     plane"
                                )
                            })?;
                            t.send_f32(tr.to, &w[s])?;
                        }
                    }
                    Some(Mirror::Sharded { backups }) => {
                        let b = backups.get(&owner).ok_or_else(|| {
                            anyhow!(
                                "holder {src} has no backup for rank \
                                 {owner}"
                            )
                        })?;
                        let a = tr.start - b.start;
                        t.send_f32(tr.to, &b.m[a..a + tr.len])?;
                        t.send_f32(tr.to, &b.v[a..a + tr.len])?;
                        if self.shard_params {
                            let w = b.w.as_deref().ok_or_else(|| {
                                anyhow!(
                                    "backup for rank {owner} has no \
                                     weight plane"
                                )
                            })?;
                            t.send_f32(tr.to, &w[a..a + tr.len])?;
                        }
                    }
                    None => {
                        return Err(anyhow!(
                            "rank {owner}'s transfer needs the ft mirror"
                        ))
                    }
                }
            } else if self.rank == src {
                let old = self.shard.as_ref().ok_or_else(|| {
                    anyhow!("transfer source {src} holds no shard")
                })?;
                let a = tr.start - old_layout.range(src).start;
                t.send_f32(tr.to, &old.m[a..a + tr.len])?;
                t.send_f32(tr.to, &old.v[a..a + tr.len])?;
                if self.shard_params {
                    let w = self.param_shard.as_ref().ok_or_else(|| {
                        anyhow!(
                            "transfer source {src} holds no parameter \
                             shard"
                        )
                    })?;
                    t.send_f32(tr.to, &w[a..a + tr.len])?;
                }
            }
            if is_active && self.rank == tr.to {
                let nr = new_layout.range(self.rank);
                if tr.start < nr.start || tr.start + tr.len > nr.end {
                    return Err(anyhow!(
                        "transfer [{}, +{}) outside rank {}'s new range",
                        tr.start,
                        tr.len,
                        self.rank
                    ));
                }
                let a = tr.start - nr.start;
                let m_in = t.recv_f32(src)?;
                let v_in = t.recv_f32(src)?;
                if m_in.len() != tr.len || v_in.len() != tr.len {
                    return Err(anyhow!(
                        "transfer payload mismatch: got {}+{} elems, \
                         wanted {}",
                        m_in.len(),
                        v_in.len(),
                        tr.len
                    ));
                }
                new_m[a..a + tr.len].copy_from_slice(&m_in);
                new_v[a..a + tr.len].copy_from_slice(&v_in);
                if self.shard_params {
                    let w_in = t.recv_f32(src)?;
                    if w_in.len() != tr.len {
                        return Err(anyhow!(
                            "weight transfer holds {} elems, wanted {}",
                            w_in.len(),
                            tr.len
                        ));
                    }
                    new_w[a..a + tr.len].copy_from_slice(&w_in);
                }
            }
        }

        // Leader-resident only: ranks ENTERING the membership receive
        // the current full parameters from rank 0 (bitwise-identical on
        // every active rank, so any source would do). Fully-sharded
        // ranks need no such stream — an entering rank's entire weight
        // slice is covered by the transfer list above (ownership of
        // every element it now holds changed by definition).
        if !self.shard_params {
            let flat = flatten(&self.params, flat_len);
            for (r, surv) in cmd.survivors.iter().enumerate() {
                if surv.is_some() {
                    continue;
                }
                if self.rank == 0 {
                    t.send_f32(r, &flat)?;
                }
                if self.rank == r {
                    let data = t.recv_f32(0)?;
                    if data.len() != flat_len {
                        return Err(anyhow!(
                            "param stream holds {} elems, wanted {flat_len}",
                            data.len()
                        ));
                    }
                    self.params = unflatten(&data, &self.sizes);
                }
            }
        }

        self.membership = cmd.new_membership.clone();
        // The ring order is membership-relative: rebuild it so the
        // next step's rings stay locality-sorted over the NEW group.
        self.order = ring_order(&self.topo, self.membership.len())?;
        // Unit boundaries are layout-relative: rebuild them against the
        // post-migration shard layout so the next step's per-unit rank
        // slices tile the NEW ranges.
        self.units = unit_plan(
            &self.exec,
            &new_layout,
            self.shard_params,
            self.fsdp_units,
        );
        self.layout = new_layout;
        self.shard = is_active.then(|| AdamShard {
            m: new_m,
            v: new_v,
            step: cmd.adam_step,
            cfg: self.adam,
        });
        self.param_shard = if self.shard_params && is_active {
            Some(new_w)
        } else {
            None
        };
        // Re-seed the sharded mirror over the NEW membership: holders
        // change with the group size, and a rejoined-but-restreamed
        // rank's stale backups must be replaced before anyone trusts
        // them. (The leader mirror needs no reshape — it spans the full
        // flat vector and the next ft_sync refreshes it.)
        if self.ft && !self.mirror_leader {
            self.mirror_shift(t)?;
        }
        Ok(())
    }
}

/// The `cephalo worker` serving loop: execute coordinator commands
/// until `SHUTDOWN` (or the coordinator disconnects, which surfaces as
/// an error — fail-stop).
pub fn worker_loop(mut t: Box<dyn Transport>) -> Result<()> {
    let rank = t.rank();
    if rank == 0 {
        return Err(anyhow!("rank 0 is the coordinator, not a worker"));
    }
    // Tag this thread's trace events with its rank (thread-fabric
    // workers share the coordinator's process tracer).
    telemetry::set_rank(rank);
    let mut state: Option<DistRank> = None;
    let mut next_step: u64 = 0;
    loop {
        let cmd = t.recv_bytes(0)?;
        let mut r = R::new(&cmd);
        match r.u8()? {
            OP_INIT => {
                let (cfg, membership) = decode_init(&mut r)?;
                let st = DistRank::init(rank, &cfg, membership)?;
                // Seed the coordinator's fingerprint ledger: every
                // active rank reports its boundary-state digest so a
                // later rejoin can be checked against it.
                if st.ft && rank < st.membership().len() {
                    let mut w = W::default();
                    w.u64(st.fingerprint());
                    t.send_bytes(0, &w.0)?;
                }
                state = Some(st);
                next_step = 0;
            }
            OP_STEP => {
                // The step index doubles as a desync check: corpus
                // alignment requires EXACTLY one sample per step, so a
                // skipped or replayed command must fail loudly instead
                // of training on silently divergent batches.
                let idx = r.u64()?;
                if idx != next_step {
                    return Err(anyhow!(
                        "step desync at rank {rank}: coordinator says \
                         step {idx}, expected {next_step}"
                    ));
                }
                next_step += 1;
                let st = state
                    .as_mut()
                    .ok_or_else(|| anyhow!("STEP before INIT"))?;
                let active = rank < st.membership().len();
                let ts = Instant::now();
                let (loss, count) = st.step(t.as_mut())?;
                let measured = ts.elapsed().as_secs_f64();
                if active {
                    // The reply ALWAYS carries the phase fields and the
                    // measured step time — the wire format never
                    // depends on whether tracing is on (invariant 14).
                    // Under `--ft` it additionally carries the post-step
                    // shard fingerprint, refreshing the coordinator's
                    // rejoin ledger every step.
                    let mut w = W::default();
                    w.f64(loss);
                    w.f64(count);
                    for p in st.last_phases().to_array() {
                        w.f64(p);
                    }
                    w.f64(measured);
                    if st.ft {
                        w.u64(st.fingerprint());
                    }
                    t.send_bytes(0, &w.0)?;
                }
                // Reply first, mirror second: per-lane FIFO then
                // guarantees the driver folds the loss before rank 0
                // receives this rank's ft frames.
                st.ft_sync(t.as_mut())?;
                telemetry::drain();
            }
            OP_PING => {
                t.send_bytes(0, &[OP_PING])?;
            }
            OP_REJOIN => {
                // Rejoin handshake probe: echo the nonce with this
                // rank's step count and boundary-state fingerprint so
                // the coordinator can decide resume vs. re-stream.
                let nonce = r.u64()?;
                let st = state
                    .as_ref()
                    .ok_or_else(|| anyhow!("REJOIN before INIT"))?;
                let mut w = W::default();
                w.u8(OP_REJOIN);
                w.u64(nonce);
                w.u64(next_step);
                w.u64(st.fingerprint());
                t.send_bytes(0, &w.0)?;
            }
            OP_MIGRATE => {
                let mc = decode_migrate(&mut r)?;
                let st = state
                    .as_mut()
                    .ok_or_else(|| anyhow!("MIGRATE before INIT"))?;
                st.migrate(t.as_mut(), &mc)?;
                // Ledger refresh: active ranks report the post-migration
                // digest (shards just moved, the old entries are stale).
                if st.ft && rank < st.membership().len() {
                    let mut w = W::default();
                    w.u64(st.fingerprint());
                    t.send_bytes(0, &w.0)?;
                }
            }
            OP_COLLECT => {
                state
                    .as_ref()
                    .ok_or_else(|| anyhow!("COLLECT before INIT"))?
                    .send_param_shard(t.as_mut())?;
            }
            OP_SHUTDOWN => return Ok(()),
            op => return Err(anyhow!("unknown command op {op}")),
        }
    }
}

/// Chaos injection request for [`DistDriver::launch_with_chaos`]:
/// every WORKER endpoint is wrapped in a
/// [`crate::transport::ChaosTransport`] driven by `plan` (rank 0 — the
/// coordinator — is never wrapped). Thread fabrics crash via
/// [`CrashMode::Error`]; spawned worker processes regenerate the plan
/// from `cli_spec` and crash for real via [`CrashMode::Abort`].
#[derive(Debug, Clone)]
pub struct ChaosOpts {
    /// The seeded fault schedule every endpoint replays.
    pub plan: FaultPlan,
    /// The `--chaos` spec string handed to spawned `cephalo worker`
    /// processes; required for [`FabricSpec::TcpProcesses`] and
    /// [`FabricSpec::HybridProcesses`]. ([`FabricSpec::ShmProcesses`]
    /// rejects chaos outright: an aborted process never closes its shm
    /// lanes and pure shm has no liveness fabric to notice.)
    pub cli_spec: Option<String>,
}

/// Coordinator-side handle on a distributed run: rank 0's own
/// [`DistRank`] plus the broadcast/collect plumbing and the worker
/// threads/processes behind it.
pub struct DistDriver {
    t: Box<dyn Transport>,
    rank0: DistRank,
    world: usize,
    spec: FabricSpec,
    sharded: bool,
    ft: bool,
    /// Ranks declared dead by [`DistDriver::poll_failures`]. Dead
    /// ranks are skipped by every broadcast except the final
    /// best-effort `SHUTDOWN` (a rank declared dead may still be
    /// running, e.g. after a one-sided lane failure).
    dead: BTreeSet<usize>,
    /// Per-rank boundary-state fingerprints, refreshed from every
    /// `INIT`/`STEP`/`MIGRATE` reply; `None` for rank 0 (never
    /// rejoins) and for standby ranks. The reference a rejoin
    /// handshake is checked against.
    ledger: Vec<Option<u64>>,
    /// Liveness polls issued so far (1-based in fault schedules).
    polls: u64,
    /// Coordinator-side fault schedule (quiet unless chaos is on).
    faults: DriverFaults,
    /// The one-shot `taint` fault has fired.
    taint_spent: bool,
    /// Milliseconds a suspected rank is probed for rejoin before being
    /// declared dead; 0 disables the rejoin path entirely.
    rejoin_window_ms: u64,
    /// Echo timeout for a single liveness `PING`.
    ping_timeout_ms: u64,
    timer: Option<StepTimeModel>,
    threads: Vec<std::thread::JoinHandle<()>>,
    children: Vec<std::process::Child>,
    /// TCP fabrics keep the rendezvous endpoint alive for the run's
    /// lifetime, so losing workers never tears down the meeting point.
    _rz: Option<crate::transport::tcp::Rendezvous>,
    /// Process fabrics with shm lanes: the lane directory, swept after
    /// the children are reaped (a killed worker never unlinks its
    /// inbound lane files, so per-endpoint cleanup is not enough).
    shm_dir: Option<PathBuf>,
    down: bool,
    /// Stats of every completed global step, in order.
    pub history: Vec<StepStats>,
    /// Per-rank phase totals folded from STEP replies (rank 0 measured
    /// locally) — the measured side of the skew report.
    phase_totals: Vec<PhaseBreakdown>,
    /// Per-rank accumulated measured step seconds.
    measured_totals: Vec<f64>,
    /// Steps each rank contributed timing for.
    steps_timed: Vec<u64>,
}

/// Outcome of one [`DistDriver::poll_failures`] sweep: ranks declared
/// dead, and suspected ranks that answered a rejoin handshake inside
/// the window.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PollReport {
    /// Ranks newly declared dead this sweep, ascending.
    pub dead: Vec<usize>,
    /// Suspected ranks that completed the rejoin handshake, in sweep
    /// order.
    pub rejoined: Vec<RejoinEvent>,
}

impl PollReport {
    /// No deaths and no rejoins: nothing for the coordinator to do.
    pub fn is_empty(&self) -> bool {
        self.dead.is_empty() && self.rejoined.is_empty()
    }

    /// Rejoined ranks whose fingerprint MISSED the ledger, ascending:
    /// live, corpus-aligned, but with untrusted state — the
    /// coordinator must re-stream them like fresh joiners.
    pub fn restream(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .rejoined
            .iter()
            .filter(|e| !e.hit)
            .map(|e| e.rank)
            .collect();
        v.sort_unstable();
        v
    }
}

/// One completed rejoin handshake (see [`DistDriver::poll_failures`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RejoinEvent {
    /// The rank that went silent and came back.
    pub rank: usize,
    /// REJOIN probes it took before the rank answered.
    pub attempts: u64,
    /// True when the reported fingerprint matched the ledger: the rank
    /// resumes from its resident shards, zero bytes move.
    pub hit: bool,
}

/// One rank's accumulated measured timing, folded by the driver from
/// the phase fields every STEP reply carries.
#[derive(Debug, Clone)]
pub struct RankTiming {
    /// The rank the timing belongs to.
    pub rank: usize,
    /// Steps this rank contributed timing for.
    pub steps: u64,
    /// Accumulated phase breakdown across those steps.
    pub phases: PhaseBreakdown,
    /// Accumulated measured wall seconds across those steps.
    pub measured_seconds: f64,
}

impl DistDriver {
    /// Stand up the fabric, spawn worker ranks, broadcast `INIT`.
    /// `membership` must have at most `world` entries (standby ranks
    /// idle until a migration admits them).
    pub fn launch(
        spec: FabricSpec,
        world: usize,
        cfg: DistConfig,
        membership: Vec<WorkerSpec>,
    ) -> Result<DistDriver> {
        Self::launch_with_chaos(spec, world, cfg, membership, None)
    }

    /// [`DistDriver::launch`] with deterministic fault injection on
    /// the worker endpoints (see [`ChaosOpts`]).
    pub fn launch_with_chaos(
        spec: FabricSpec,
        world: usize,
        cfg: DistConfig,
        membership: Vec<WorkerSpec>,
        chaos: Option<ChaosOpts>,
    ) -> Result<DistDriver> {
        if world < 1 {
            return Err(anyhow!("world size must be at least 1"));
        }
        if membership.is_empty() || membership.len() > world {
            return Err(anyhow!(
                "membership of {} ranks does not fit a {world}-rank world",
                membership.len()
            ));
        }
        let wrap = |ep: Box<dyn Transport>,
                    chaos: &Option<ChaosOpts>|
         -> Box<dyn Transport> {
            match chaos {
                Some(ch) => Box::new(ChaosTransport::new(
                    ep,
                    &ch.plan,
                    CrashMode::Error,
                )),
                None => ep,
            }
        };
        let (t, threads, children, rz, shm_dir) = match spec {
            FabricSpec::Local => {
                let mut eps = LocalFabric::new(world);
                let rest = eps.split_off(1);
                let t0: Box<dyn Transport> = Box::new(eps.remove(0));
                let threads = rest
                    .into_iter()
                    .map(|ep| {
                        let ep = wrap(Box::new(ep), &chaos);
                        std::thread::spawn(move || {
                            if let Err(e) = worker_loop(ep) {
                                crate::warn!("local worker exited: {e}");
                            }
                        })
                    })
                    .collect();
                (t0, threads, Vec::new(), None, None)
            }
            FabricSpec::ShmThreads => {
                let mut eps = ShmFabric::new(world)?;
                let rest = eps.split_off(1);
                let t0: Box<dyn Transport> = Box::new(eps.remove(0));
                let threads = rest
                    .into_iter()
                    .map(|ep| {
                        let ep = wrap(Box::new(ep), &chaos);
                        std::thread::spawn(move || {
                            if let Err(e) = worker_loop(ep) {
                                crate::warn!("shm worker exited: {e}");
                            }
                        })
                    })
                    .collect();
                (t0, threads, Vec::new(), None, None)
            }
            FabricSpec::HybridThreads => {
                let topo = hybrid_topology(&cfg, world)?;
                let dir = crate::transport::shm::fresh_dir();
                let slow = crate::transport::tcp::thread_fabric(world)?;
                let mut eps = slow
                    .into_iter()
                    .map(|s| HybridTransport::wrap(s, &dir, topo.clone()))
                    .collect::<Result<Vec<_>>>()?;
                let rest = eps.split_off(1);
                let t0: Box<dyn Transport> = Box::new(eps.remove(0));
                let threads = rest
                    .into_iter()
                    .map(|ep| {
                        let ep = wrap(Box::new(ep), &chaos);
                        std::thread::spawn(move || {
                            if let Err(e) = worker_loop(ep) {
                                crate::warn!("hybrid worker exited: {e}");
                            }
                        })
                    })
                    .collect();
                (t0, threads, Vec::new(), None, None)
            }
            FabricSpec::ShmProcesses => {
                if chaos.is_some() {
                    // A chaos-aborted process never closes its shm
                    // lanes, and pure shm has no liveness fabric to
                    // notice — blocked recvs would park forever.
                    return Err(anyhow!(
                        "process-crash chaos needs a liveness fabric; \
                         use --transport hybrid (or tcp)"
                    ));
                }
                let dir = crate::transport::shm::fresh_dir();
                let exe = std::env::current_exe()?;
                let children = (1..world)
                    .map(|r| {
                        std::process::Command::new(&exe)
                            .args([
                                "worker",
                                "--rank",
                                &r.to_string(),
                                "--shm-dir",
                                &dir.display().to_string(),
                                "--world",
                                &world.to_string(),
                            ])
                            .args(trace_args(&cfg, r))
                            .spawn()
                    })
                    .collect::<std::io::Result<Vec<_>>>()?;
                let t0: Box<dyn Transport> =
                    Box::new(ShmTransport::attach(&dir, 0, world)?);
                (t0, Vec::new(), children, None, Some(dir))
            }
            FabricSpec::HybridProcesses => {
                let topo = hybrid_topology(&cfg, world)?;
                let rz = crate::transport::tcp::Rendezvous::bind(
                    "127.0.0.1:0",
                    world,
                )?;
                let addr = rz.local_addr()?;
                let dir = crate::transport::shm::fresh_dir();
                let hosts_spec = topo
                    .hosts()
                    .iter()
                    .map(|h| h.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                let exe = std::env::current_exe()?;
                let mut extra: Vec<String> = Vec::new();
                if let Some(ch) = &chaos {
                    let spec = ch.cli_spec.clone().ok_or_else(|| {
                        anyhow!(
                            "process fabric chaos needs a --chaos spec \
                             string (ChaosOpts::cli_spec)"
                        )
                    })?;
                    extra.push("--chaos".into());
                    extra.push(spec);
                }
                let children = (1..world)
                    .map(|r| {
                        std::process::Command::new(&exe)
                            .args([
                                "worker",
                                "--rank",
                                &r.to_string(),
                                "--connect",
                                &addr,
                                "--world",
                                &world.to_string(),
                                "--shm-dir",
                                &dir.display().to_string(),
                                "--hosts",
                                &hosts_spec,
                            ])
                            .args(&extra)
                            .args(trace_args(&cfg, r))
                            .spawn()
                    })
                    .collect::<std::io::Result<Vec<_>>>()?;
                let slow: Box<dyn Transport> = Box::new(rz.establish()?);
                let t0: Box<dyn Transport> =
                    Box::new(HybridTransport::wrap(slow, &dir, topo)?);
                (t0, Vec::new(), children, Some(rz), Some(dir))
            }
            FabricSpec::TcpThreads => {
                let rz = crate::transport::tcp::Rendezvous::bind(
                    "127.0.0.1:0",
                    world,
                )?;
                let addr = rz.local_addr()?;
                let threads = (1..world)
                    .map(|r| {
                        let addr = addr.clone();
                        let chaos = chaos.clone();
                        std::thread::spawn(move || {
                            match crate::transport::tcp::connect(
                                &addr, r, world,
                            ) {
                                Ok(t) => {
                                    let ep = wrap(Box::new(t), &chaos);
                                    if let Err(e) = worker_loop(ep) {
                                        crate::warn!(
                                            "tcp worker {r} exited: {e}"
                                        );
                                    }
                                }
                                Err(e) => crate::warn!(
                                    "tcp worker {r} never joined: {e}"
                                ),
                            }
                        })
                    })
                    .collect();
                let t0: Box<dyn Transport> = Box::new(rz.establish()?);
                (t0, threads, Vec::new(), Some(rz), None)
            }
            FabricSpec::TcpProcesses => {
                let rz = crate::transport::tcp::Rendezvous::bind(
                    "127.0.0.1:0",
                    world,
                )?;
                let addr = rz.local_addr()?;
                let exe = std::env::current_exe()?;
                let mut extra: Vec<String> = Vec::new();
                if let Some(ch) = &chaos {
                    let spec = ch.cli_spec.clone().ok_or_else(|| {
                        anyhow!(
                            "process fabric chaos needs a --chaos spec \
                             string (ChaosOpts::cli_spec)"
                        )
                    })?;
                    extra.push("--chaos".into());
                    extra.push(spec);
                }
                let children = (1..world)
                    .map(|r| {
                        std::process::Command::new(&exe)
                            .args([
                                "worker",
                                "--rank",
                                &r.to_string(),
                                "--connect",
                                &addr,
                                "--world",
                                &world.to_string(),
                            ])
                            .args(&extra)
                            .args(trace_args(&cfg, r))
                            .spawn()
                    })
                    .collect::<std::io::Result<Vec<_>>>()?;
                let t0: Box<dyn Transport> = Box::new(rz.establish()?);
                (t0, Vec::new(), children, Some(rz), None)
            }
        };
        let mut t = t;
        let init = encode_init(&cfg, &membership);
        for r in 1..world {
            t.send_bytes(r, &init)?;
        }
        let sharded = cfg.shard_params;
        let ft = cfg.ft;
        let rejoin_window_ms = cfg.rejoin_window_ms;
        let ping_timeout_ms = cfg.ping_timeout_ms;
        let group = membership.len();
        let rank0 = DistRank::init(0, &cfg, membership)?;
        // Seed the rejoin ledger from the workers' INIT fingerprints.
        let mut ledger: Vec<Option<u64>> = vec![None; world];
        if ft {
            for (r, slot) in ledger.iter_mut().enumerate().take(group) {
                if r == 0 {
                    continue;
                }
                let raw = t.recv_bytes(r)?;
                *slot = Some(R::new(&raw).u64()?);
            }
        }
        let faults = chaos
            .as_ref()
            .map(|c| c.plan.driver.clone())
            .unwrap_or_else(DriverFaults::quiet);
        Ok(DistDriver {
            t,
            rank0,
            world,
            spec,
            sharded,
            ft,
            dead: BTreeSet::new(),
            ledger,
            polls: 0,
            faults,
            taint_spent: false,
            rejoin_window_ms,
            ping_timeout_ms,
            timer: None,
            threads,
            children,
            _rz: rz,
            shm_dir,
            down: false,
            history: Vec::new(),
            phase_totals: vec![PhaseBreakdown::default(); world],
            measured_totals: vec![0.0; world],
            steps_timed: vec![0; world],
        })
    }

    /// Per-rank measured timing folded so far (active ranks only show
    /// non-zero steps). The measured side of the coordinator's
    /// planned-vs-measured skew report.
    pub fn rank_timings(&self) -> Vec<RankTiming> {
        (0..self.world)
            .map(|r| RankTiming {
                rank: r,
                steps: self.steps_timed[r],
                phases: self.phase_totals[r],
                measured_seconds: self.measured_totals[r],
            })
            .collect()
    }

    /// Attach simulated step durations (the `StepExecutor::step_seconds`
    /// hook for the dist path — keeps `--live` reports on modeled time).
    pub fn with_timer(mut self, timer: StepTimeModel) -> DistDriver {
        self.timer = Some(timer);
        self
    }

    /// Modeled per-rank step seconds for the CURRENT membership from
    /// the attached [`StepTimeModel`] — the PLANNED side of the
    /// coordinator's skew report. `None` without a timer.
    pub fn planned_rank_seconds(&self) -> Option<Vec<f64>> {
        self.timer.as_ref().map(|m| {
            let batches: Vec<usize> = self
                .rank0
                .membership()
                .iter()
                .map(|w| w.batch)
                .collect();
            m.per_rank_seconds(&batches)
        })
    }

    /// Total transport ranks (fixed for the fabric's lifetime).
    pub fn world(&self) -> usize {
        self.world
    }

    /// The fabric's short name ("tcp", "shm", ...).
    pub fn backend_label(&self) -> &'static str {
        self.spec.label()
    }

    /// The current membership (rank 0's copy).
    pub fn membership(&self) -> &[WorkerSpec] {
        self.rank0.membership()
    }

    /// Rank 0's resident full parameters. Panics on a fully-sharded
    /// run (no rank holds a full copy by design) — use
    /// [`DistDriver::gather_params`] for an explicit wire export.
    pub fn params(&self) -> &[Vec<f32>] {
        if self.sharded {
            panic!(
                "fully-sharded run holds no resident full parameters; \
                 use gather_params() (COLLECT export)"
            );
        }
        self.rank0.params()
    }

    /// True when the run shards its weights (no leader copy anywhere).
    pub fn is_sharded(&self) -> bool {
        self.sharded
    }

    /// Export the full parameters — rank 0's resident copy on a
    /// leader-resident run; on a fully-sharded run a COLLECT broadcast
    /// streams every active rank's weight slice to rank 0, which
    /// assembles the flat vector (the ONLY place a sharded run
    /// reconstitutes the weights outside a step).
    pub fn gather_params(&mut self) -> Result<Vec<Vec<f32>>> {
        if !self.sharded {
            return Ok(self.rank0.params().to_vec());
        }
        for r in self.live_workers() {
            self.t.send_bytes(r, &[OP_COLLECT])?;
        }
        let layout = self.rank0.layout().clone();
        let group = self.rank0.membership().len();
        let mut flat = vec![0f32; layout.len()];
        let mine = self.rank0.param_shard_view().ok_or_else(|| {
            anyhow!("rank 0 is active but holds no parameter shard")
        })?;
        flat[layout.range(0)].copy_from_slice(mine);
        for r in 1..group {
            if layout.size(r) == 0 {
                continue;
            }
            let s = self.t.recv_f32(r)?;
            if s.len() != layout.size(r) {
                return Err(anyhow!(
                    "rank {r} streamed {} weight elems, layout wants {}",
                    s.len(),
                    layout.size(r)
                ));
            }
            flat[layout.range(r)].copy_from_slice(&s);
        }
        Ok(unflatten(&flat, self.rank0.sizes()))
    }

    /// The current shard layout (rank 0's copy).
    pub fn layout(&self) -> &ShardLayout {
        self.rank0.layout()
    }

    /// Adam step counter of the running shards (all active ranks
    /// agree; rank 0 is always active).
    pub fn adam_step(&self) -> u64 {
        self.rank0.shard.as_ref().map(|s| s.step).unwrap_or(0)
    }

    /// True when the run keeps the rank-0 mirror and probes liveness.
    pub fn is_ft(&self) -> bool {
        self.ft
    }

    /// Ranks declared dead so far, ascending.
    pub fn dead_ranks(&self) -> Vec<usize> {
        self.dead.iter().copied().collect()
    }

    /// Worker ranks not declared dead, ascending.
    fn live_workers(&self) -> Vec<usize> {
        (1..self.world).filter(|r| !self.dead.contains(r)).collect()
    }

    /// Probe every live worker rank (active AND standby) and sort the
    /// unresponsive ones into DEAD and REJOINED. Only meaningful
    /// between steps — ft runs call this at step boundaries, when
    /// every live worker is blocked on `recv` and answers a `PING`
    /// immediately.
    ///
    /// The per-rank state machine: a missed echo (or a lane the
    /// transport merely *suspects*) raises a suspicion; with a rejoin
    /// window configured the rank is then probed with `REJOIN`
    /// handshakes under exponential backoff until the window closes —
    /// an answering rank is re-admitted (fingerprint hit → resume in
    /// place; miss → caller re-streams it), a silent one is declared
    /// dead. Hard evidence ([`Transport::peer_failed`]: a CLOSED lane,
    /// a failed send) skips the window — that lane can never carry a
    /// handshake. No-op unless `ft` is on.
    pub fn poll_failures(&mut self) -> PollReport {
        let mut report = PollReport::default();
        if !self.ft {
            return report;
        }
        self.polls += 1;
        if self.faults.poll_delay_ms > 0 {
            Self::record_driver_fault(&format!(
                "poll delay {}ms",
                self.faults.poll_delay_ms
            ));
            std::thread::sleep(Duration::from_millis(
                self.faults.poll_delay_ms,
            ));
        }
        for r in self.live_workers() {
            let probe = Instant::now();
            if self.t.peer_failed(r)
                || self.t.send_bytes(r, &[OP_PING]).is_err()
            {
                self.raise_suspicion(r);
                self.dead.insert(r);
                report.dead.push(r);
                continue;
            }
            let dropped = self.faults.drops_ping(r, self.polls);
            if dropped {
                Self::record_driver_fault(&format!(
                    "drop ping r{r} poll {}",
                    self.polls
                ));
            }
            let pong = matches!(
                self.t.recv_bytes_timeout(r, self.ping_timeout_ms),
                Ok(Some(ref pong)) if pong.as_slice() == [OP_PING]
            );
            if pong && !dropped {
                telemetry::counters()
                    .record_ping_rtt(probe.elapsed().as_micros() as u64);
                continue;
            }
            self.raise_suspicion(r);
            if self.rejoin_window_ms > 0 && !self.t.peer_failed(r) {
                if let Some(ev) = self.try_rejoin(r) {
                    if telemetry::on() {
                        telemetry::instant(
                            telemetry::CAT_RECOVER,
                            &format!(
                                "rejoin r{r} {}",
                                if ev.hit { "hit" } else { "restream" }
                            ),
                        );
                    }
                    report.rejoined.push(ev);
                    continue;
                }
            }
            self.dead.insert(r);
            report.dead.push(r);
        }
        report
    }

    fn raise_suspicion(&self, r: usize) {
        telemetry::counters()
            .suspicions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if telemetry::on() {
            telemetry::instant(
                telemetry::CAT_SUSPECT,
                &format!("suspect r{r}"),
            );
        }
    }

    /// A coordinator-side chaos fault fired: count it and mark the
    /// trace, exactly like [`crate::transport::ChaosTransport`] does
    /// for lane faults.
    fn record_driver_fault(name: &str) {
        telemetry::counters()
            .chaos_faults
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if telemetry::on() {
            telemetry::instant(telemetry::CAT_FAULT, name);
        }
    }

    /// Probe a suspected rank with `REJOIN` handshakes (exponential
    /// backoff, 50→400ms) until it answers or the rejoin window
    /// closes. `None` means the window expired (or the lane errored):
    /// declare it dead.
    fn try_rejoin(&mut self, r: usize) -> Option<RejoinEvent> {
        let deadline = Instant::now()
            + Duration::from_millis(self.rejoin_window_ms);
        let mut backoff = 50u64;
        let mut attempts = 0u64;
        while Instant::now() < deadline {
            attempts += 1;
            let mut w = W::default();
            w.u8(OP_REJOIN);
            w.u64(attempts);
            if self.t.send_bytes(r, &w.0).is_err() {
                return None;
            }
            let attempt_deadline = std::cmp::min(
                Instant::now() + Duration::from_millis(backoff),
                deadline,
            );
            loop {
                let now = Instant::now();
                if now >= attempt_deadline {
                    break;
                }
                let left =
                    (attempt_deadline - now).as_millis() as u64 + 1;
                match self.t.recv_bytes_timeout(r, left) {
                    // A stale pong from the PING that started all this.
                    Ok(Some(ref raw)) if raw.as_slice() == [OP_PING] => {
                        continue;
                    }
                    Ok(Some(raw)) => {
                        let mut rd = R::new(&raw);
                        let (Ok(op), Ok(nonce), Ok(step), Ok(fp)) =
                            (rd.u8(), rd.u64(), rd.u64(), rd.u64())
                        else {
                            return None;
                        };
                        if op != OP_REJOIN {
                            return None;
                        }
                        if nonce < attempts {
                            // Ack of an earlier probe that raced the
                            // backoff; the fresh one is behind it.
                            continue;
                        }
                        return self.admit_rejoin(r, attempts, step, fp);
                    }
                    Ok(None) => break,
                    Err(_) => return None,
                }
            }
            backoff = (backoff * 2).min(400);
        }
        None
    }

    /// A suspected rank answered the handshake: decide its fate. A
    /// step-count mismatch is fatal (its corpus position diverged —
    /// re-streaming state cannot fix that); otherwise the fingerprint
    /// against the ledger decides resume-in-place vs. re-stream.
    fn admit_rejoin(
        &mut self,
        r: usize,
        attempts: u64,
        step: u64,
        fp: u64,
    ) -> Option<RejoinEvent> {
        if step != self.history.len() as u64 {
            return None;
        }
        let mut fp = fp;
        if self.faults.taint_rank == Some(r) && !self.taint_spent {
            // Chaos: corrupt the reported digest once, forcing the
            // re-stream path on an otherwise-clean rejoin.
            self.taint_spent = true;
            Self::record_driver_fault(&format!("taint rejoin r{r}"));
            fp ^= 1;
        }
        let hit = match self.ledger[r] {
            Some(want) => want == fp,
            // No ledger entry: standby ranks carry no boundary state,
            // so a standby rejoin is always a hit; an active rank
            // without an entry is never trusted.
            None => r >= self.rank0.membership().len(),
        };
        Some(RejoinEvent { rank: r, attempts, hit })
    }

    /// Drive one global step: broadcast, run rank 0's share, fold in
    /// worker losses (rank order — the leader's f64 accumulation
    /// order). `step_idx` labels the returned stats; the wire carries
    /// the driver's own monotone step counter, which every worker
    /// checks against its local count (corpus-alignment desync guard).
    pub fn step(&mut self, step_idx: usize) -> Result<StepStats> {
        let t0 = Instant::now();
        let t0_us = telemetry::now_us();
        let group = self.rank0.membership().len();
        let batches: Vec<usize> =
            self.rank0.membership().iter().map(|w| w.batch).collect();
        let mut w = W::default();
        w.u8(OP_STEP);
        w.u64(self.history.len() as u64);
        for r in self.live_workers() {
            self.t.send_bytes(r, &w.0)?;
        }
        let (mut loss_sum, mut token_count) =
            self.rank0.step(self.t.as_mut())?;
        let rank0_measured = t0.elapsed().as_secs_f64();
        let rank0_phases = self.rank0.last_phases();
        self.phase_totals[0].add(&rank0_phases);
        self.measured_totals[0] += rank0_measured;
        self.steps_timed[0] += 1;
        telemetry::emit_rank_step(step_idx, 0, t0_us, &rank0_phases);
        for r in 1..group {
            let reply = self.t.recv_bytes(r)?;
            let mut rd = R::new(&reply);
            loss_sum += rd.f64()?;
            token_count += rd.f64()?;
            let mut pa = [0f64; PhaseBreakdown::WIRE_FIELDS];
            for slot in pa.iter_mut() {
                *slot = rd.f64()?;
            }
            let rp = PhaseBreakdown::from_array(pa);
            self.phase_totals[r].add(&rp);
            self.measured_totals[r] += rd.f64()?;
            self.steps_timed[r] += 1;
            if self.ft {
                self.ledger[r] = Some(rd.u64()?);
            }
            // Synthesize the cross-rank timeline: every rank's phases
            // laid from the driver's step start (replies carry
            // durations, not wall-clock anchors).
            telemetry::emit_rank_step(step_idx, r, t0_us, &rp);
        }
        self.rank0.ft_sync(self.t.as_mut())?;
        telemetry::drain();
        if token_count <= 0.0 {
            return Err(anyhow!("distributed step saw no tokens"));
        }
        let measured = t0.elapsed().as_secs_f64();
        let stats = StepStats {
            step: step_idx,
            mean_loss: loss_sum / token_count,
            tokens: token_count,
            wall_seconds: match &self.timer {
                Some(m) => m.step_seconds(&batches),
                None => measured,
            },
            measured_seconds: measured,
            phases: rank0_phases,
        };
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Broadcast and execute a membership change.
    pub fn migrate(
        &mut self,
        new_membership: Vec<WorkerSpec>,
        survivors: &[Option<usize>],
        transfers: &[Transfer],
    ) -> Result<()> {
        self.migrate_with(new_membership, survivors, transfers, &[])
    }

    /// [`DistDriver::migrate`] with a RESTREAM list: live ranks whose
    /// state is untrusted after a fingerprint-miss rejoin. Their
    /// transfers are served by mirror holders exactly as a dead rank's
    /// would be, but the ranks themselves stay in the fabric and are
    /// re-admitted by the migration.
    pub fn migrate_with(
        &mut self,
        new_membership: Vec<WorkerSpec>,
        survivors: &[Option<usize>],
        transfers: &[Transfer],
        restream: &[usize],
    ) -> Result<()> {
        if new_membership.len() > self.world {
            return Err(anyhow!(
                "membership of {} ranks does not fit a {}-rank world",
                new_membership.len(),
                self.world
            ));
        }
        let cmd = MigrateCmd {
            new_membership,
            survivors: survivors.to_vec(),
            transfers: transfers.to_vec(),
            adam_step: self.adam_step(),
            dead: self.dead_ranks(),
            restream: restream.to_vec(),
        };
        let frame = encode_migrate(&cmd);
        for r in self.live_workers() {
            self.t.send_bytes(r, &frame)?;
        }
        self.rank0.migrate(self.t.as_mut(), &cmd)?;
        // Ledger refresh: shards just moved, every pre-migration entry
        // is stale. Active ranks report their post-migration digest;
        // standby ranks carry no boundary state and report nothing.
        if self.ft {
            let group = self.rank0.membership().len();
            for slot in self.ledger.iter_mut() {
                *slot = None;
            }
            for r in 1..group {
                let raw = self.t.recv_bytes(r)?;
                self.ledger[r] = Some(R::new(&raw).u64()?);
            }
        }
        Ok(())
    }

    /// Stop every worker rank and reap threads/processes. Idempotent;
    /// also run on drop.
    ///
    /// Teardown is crash-proof by construction: `SHUTDOWN` goes
    /// best-effort to EVERY rank (dead included — a rank we declared
    /// dead may still be running on a half-broken lane), then the
    /// coordinator endpoint is CLOSED before any join. Closing cascades
    /// a hangup to every worker blocked on `recv`, so a rank that never
    /// got its `SHUTDOWN` frame exits on the transport error instead of
    /// wedging the join forever.
    pub fn shutdown(&mut self) {
        if !self.down {
            self.down = true;
            for r in 1..self.world {
                let _ = self.t.send_bytes(r, &[OP_SHUTDOWN]);
            }
            self.t.close();
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        for mut c in self.children.drain(..) {
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match c.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(50));
                    }
                    _ => {
                        let _ = c.kill();
                        let _ = c.wait();
                        break;
                    }
                }
            }
        }
        if let Some(dir) = self.shm_dir.take() {
            // Workers are gone; sweep lane files a killed rank never
            // unlinked. Rank 0's own mmaps stay valid (unlink does not
            // tear down live mappings).
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

impl Drop for DistDriver {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn member(batch: usize, ratio: f64) -> WorkerSpec {
        WorkerSpec { batch, state_ratio: ratio, name: "m".into() }
    }

    #[test]
    fn command_frames_round_trip() {
        let cfg = DistConfig {
            seed: 9,
            corpus_branch: 3,
            ft: true,
            mirror_leader: true,
            rejoin_window_ms: 1500,
            ping_timeout_ms: 250,
            fsdp_units: 5,
            hosts: Some(vec![4, 4, 9]),
            ..Default::default()
        };
        let membership = vec![member(3, 0.7), member(1, 0.3)];
        let frame = encode_init(&cfg, &membership);
        let mut r = R::new(&frame);
        assert_eq!(r.u8().unwrap(), OP_INIT);
        let (back, mem) = decode_init(&mut r).unwrap();
        assert_eq!(back.seed, 9);
        assert_eq!(back.corpus_branch, 3);
        assert_eq!(back.adam.lr, cfg.adam.lr);
        assert_eq!(back.surrogate.vocab, cfg.surrogate.vocab);
        assert!(back.ft);
        assert!(back.mirror_leader);
        assert_eq!(back.rejoin_window_ms, 1500);
        assert_eq!(back.ping_timeout_ms, 250);
        assert_eq!(back.fsdp_units, 5);
        assert_eq!(back.hosts.as_deref(), Some(&[4, 4, 9][..]));
        assert_eq!(mem.len(), 2);
        assert_eq!(mem[0].batch, 3);
        assert_eq!(mem[1].state_ratio, 0.3);

        // The absent host map round-trips as absent.
        let bare = DistConfig::default();
        let frame = encode_init(&bare, &membership);
        let mut r = R::new(&frame);
        assert_eq!(r.u8().unwrap(), OP_INIT);
        let (back, _) = decode_init(&mut r).unwrap();
        assert_eq!(back.hosts, None);

        let mc = MigrateCmd {
            new_membership: vec![member(4, 1.0)],
            survivors: vec![Some(0)],
            transfers: vec![
                Transfer { from: None, to: 0, start: 5, len: 7 },
                Transfer { from: Some(1), to: 0, start: 12, len: 1 },
            ],
            adam_step: 17,
            dead: vec![2, 3],
            restream: vec![1],
        };
        let frame = encode_migrate(&mc);
        let mut r = R::new(&frame);
        assert_eq!(r.u8().unwrap(), OP_MIGRATE);
        let back = decode_migrate(&mut r).unwrap();
        assert_eq!(back.adam_step, 17);
        assert_eq!(back.survivors, vec![Some(0)]);
        assert_eq!(back.transfers, mc.transfers);
        assert_eq!(back.new_membership.len(), 1);
        assert_eq!(back.dead, vec![2, 3]);
        assert_eq!(back.restream, vec![1]);

        // Truncated frames error instead of panicking.
        let mut r = R::new(&frame[..4]);
        let _ = r.u8();
        assert!(decode_migrate(&mut r).is_err());
    }

    #[test]
    fn mirror_layout_places_backups_on_ring_successors() {
        // Tiny groups fall back to rank 0 (a 2-rank ring's successor
        // is the peer that dies with you under a single host loss).
        for group in 1..=2 {
            let ml = MirrorLayout::new(group);
            for owner in 0..group {
                assert_eq!(ml.holder(owner), 0, "group {group}");
            }
        }
        // Larger groups: owner r backs up on (r + 1) % group, and
        // sources() is the exact inverse map.
        let ml = MirrorLayout::new(5);
        for owner in 0..5 {
            assert_eq!(ml.holder(owner), (owner + 1) % 5);
        }
        for holder in 0..5 {
            let srcs = ml.sources(holder);
            assert_eq!(srcs, vec![(holder + 4) % 5]);
            for s in srcs {
                assert_eq!(ml.holder(s), holder);
            }
        }
    }

    #[test]
    fn local_driver_matches_single_worker_reference() {
        use crate::exec::{NativeExecutor, SurrogateSpec};
        use crate::trainer::{TrainConfig, Trainer};

        let cfg = DistConfig { seed: 5, ..Default::default() };
        let membership = vec![member(3, 0.7), member(1, 0.3)];
        let mut driver =
            DistDriver::launch(FabricSpec::Local, 2, cfg, membership)
                .unwrap();

        let tcfg = TrainConfig {
            steps: 0,
            seed: 5,
            log_every: 0,
            ..Default::default()
        };
        let mut reference = Trainer::from_executor(
            Box::new(NativeExecutor::new(SurrogateSpec::default())),
            vec![member(4, 1.0)],
            tcfg,
        )
        .unwrap();

        assert_eq!(driver.params(), reference.params());
        for s in 0..3 {
            let st = driver.step(s).unwrap();
            reference.step(s).unwrap();
            assert!(st.mean_loss.is_finite() && st.mean_loss > 0.0);
            assert_eq!(
                driver.params(),
                reference.params(),
                "diverged at step {s}"
            );
        }
        driver.shutdown();
    }

    #[test]
    fn sharded_driver_matches_replicated_driver_bitwise() {
        // Fully-sharded SPMD ranks (head-of-step wire AllGather, no
        // resident full copy anywhere) ride the replicated trajectory
        // bit for bit; gather_params() is the COLLECT export.
        let membership = || vec![member(3, 0.7), member(1, 0.3)];
        let cfg = DistConfig { seed: 5, ..Default::default() };
        let shcfg = DistConfig {
            seed: 5,
            shard_params: true,
            ..Default::default()
        };
        let mut rep =
            DistDriver::launch(FabricSpec::Local, 2, cfg, membership())
                .unwrap();
        let mut sh =
            DistDriver::launch(FabricSpec::Local, 2, shcfg, membership())
                .unwrap();
        assert!(sh.is_sharded() && !rep.is_sharded());
        assert_eq!(sh.gather_params().unwrap(), rep.params());
        for s in 0..3 {
            rep.step(s).unwrap();
            sh.step(s).unwrap();
            assert_eq!(
                sh.gather_params().unwrap(),
                rep.params(),
                "sharded run diverged at step {s}"
            );
        }
        rep.shutdown();
        sh.shutdown();
    }

    #[test]
    fn overlap_scheduler_interleaves_gather_rounds_with_compute() {
        // The scheduler's contract, observed directly: with an N-rank
        // AllGather (N-1 rounds) prefetching behind N-1 compute
        // chunks, every chunk is followed by exactly one wire round —
        // the comm fully hides behind compute, no trailing drain.
        let layout = ShardLayout::by_ratios(8, &[0.25, 0.25, 0.25, 0.25]);
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|me| vec![(me * 10) as f32, (me * 10 + 1) as f32])
            .collect();
        let eps = LocalFabric::new(4);
        let results: Vec<(Vec<OverlapEvent>, Vec<f32>)> =
            std::thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .map(|mut ep| {
                        let shards = &shards;
                        let layout = &layout;
                        s.spawn(move || {
                            let t: &mut dyn Transport = &mut ep;
                            let mut op = wire::AllGatherOp::start(
                                &*t,
                                &shards[t.rank()],
                                layout,
                            )
                            .unwrap();
                            let mut events = Vec::new();
                            let mut computed = 0usize;
                            drive_overlapped(
                                t,
                                Some(&mut op),
                                3,
                                |_| {
                                    computed += 1;
                                    Ok(())
                                },
                                |e| events.push(e),
                            )
                            .unwrap();
                            assert_eq!(computed, 3);
                            (events, op.finish().unwrap())
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        let expect_full: Vec<f32> =
            vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0, 30.0, 31.0];
        for (events, full) in results {
            assert_eq!(
                events,
                vec![
                    OverlapEvent::Compute,
                    OverlapEvent::CommRound,
                    OverlapEvent::Compute,
                    OverlapEvent::CommRound,
                    OverlapEvent::Compute,
                    OverlapEvent::CommRound,
                ]
            );
            assert_eq!(full, expect_full);
        }
    }

    #[test]
    fn unit_sharded_driver_matches_whole_gather_bitwise() {
        // Invariant 13 at the driver level: the unit-pipelined step
        // (per-unit wire gathers overlapped with compute, per-unit
        // reduce-scatters) rides the whole-model-gather trajectory bit
        // for bit, across an elastic migration (unit boundaries are
        // rebuilt against the new layout).
        use crate::coordinator::elastic::plan_migration;

        let membership =
            || vec![member(2, 0.5), member(1, 0.3), member(1, 0.2)];
        let whole_cfg = DistConfig {
            seed: 7,
            shard_params: true,
            ..Default::default()
        };
        let unit_cfg = DistConfig { fsdp_units: 4, ..whole_cfg.clone() };
        let mut whole =
            DistDriver::launch(FabricSpec::Local, 3, whole_cfg, membership())
                .unwrap();
        let mut units =
            DistDriver::launch(FabricSpec::Local, 3, unit_cfg, membership())
                .unwrap();
        for s in 0..2 {
            whole.step(s).unwrap();
            units.step(s).unwrap();
            assert_eq!(
                units.gather_params().unwrap(),
                whole.gather_params().unwrap(),
                "unit-sharded run diverged at step {s}"
            );
        }
        let new_membership = vec![member(2, 0.6), member(2, 0.4)];
        let survivors = vec![Some(0), Some(1)];
        for d in [&mut whole, &mut units] {
            let old = d.layout().clone();
            let new = layout_of(&new_membership, old.len());
            let (transfers, _, _) = plan_migration(&old, &new, &survivors);
            d.migrate(new_membership.clone(), &survivors, &transfers)
                .unwrap();
        }
        for s in 2..4 {
            whole.step(s).unwrap();
            units.step(s).unwrap();
            assert_eq!(
                units.gather_params().unwrap(),
                whole.gather_params().unwrap(),
                "unit-sharded run diverged at step {s} (post-migration)"
            );
        }
        whole.shutdown();
        units.shutdown();
    }

    #[test]
    fn shm_and_hybrid_drivers_match_the_local_driver_bitwise() {
        // Invariant 10 over the new fabrics: the shm rings and the
        // locality-routed hybrid fabric (hosts [0,1,0] — same-host
        // ranks 0 and 2 adjacent in the ring, exercised with unit
        // pipelining) carry the SAME fully-sharded trajectory as the
        // in-process channel fabric, bit for bit, across an elastic
        // migration.
        use crate::coordinator::elastic::plan_migration;

        let membership =
            || vec![member(2, 0.5), member(1, 0.3), member(1, 0.2)];
        let cfg = DistConfig {
            seed: 13,
            shard_params: true,
            ..Default::default()
        };
        let hybrid_cfg = DistConfig {
            fsdp_units: 4,
            hosts: Some(vec![0, 1, 0]),
            ..cfg.clone()
        };
        let mut local = DistDriver::launch(
            FabricSpec::Local,
            3,
            cfg.clone(),
            membership(),
        )
        .unwrap();
        let mut shm = DistDriver::launch(
            FabricSpec::ShmThreads,
            3,
            cfg,
            membership(),
        )
        .unwrap();
        let mut hybrid = DistDriver::launch(
            FabricSpec::HybridThreads,
            3,
            hybrid_cfg,
            membership(),
        )
        .unwrap();
        assert_eq!(shm.backend_label(), "shm");
        assert_eq!(hybrid.backend_label(), "hybrid");
        for s in 0..2 {
            local.step(s).unwrap();
            shm.step(s).unwrap();
            hybrid.step(s).unwrap();
            let want = local.gather_params().unwrap();
            assert_eq!(
                shm.gather_params().unwrap(),
                want,
                "shm diverged at step {s}"
            );
            assert_eq!(
                hybrid.gather_params().unwrap(),
                want,
                "hybrid diverged at step {s}"
            );
        }
        let new_membership = vec![member(2, 0.6), member(2, 0.4)];
        let survivors = vec![Some(0), Some(1)];
        for d in [&mut local, &mut shm, &mut hybrid] {
            let old = d.layout().clone();
            let new = layout_of(&new_membership, old.len());
            let (transfers, _, _) = plan_migration(&old, &new, &survivors);
            d.migrate(new_membership.clone(), &survivors, &transfers)
                .unwrap();
        }
        for s in 2..4 {
            local.step(s).unwrap();
            shm.step(s).unwrap();
            hybrid.step(s).unwrap();
            let want = local.gather_params().unwrap();
            assert_eq!(
                shm.gather_params().unwrap(),
                want,
                "shm diverged at step {s} (post-migration)"
            );
            assert_eq!(
                hybrid.gather_params().unwrap(),
                want,
                "hybrid diverged at step {s} (post-migration)"
            );
        }
        local.shutdown();
        shm.shutdown();
        hybrid.shutdown();
    }

    #[test]
    fn hybrid_chaos_crash_recovery_matches_graceful_local_bitwise() {
        // Invariants 10 + 12 composed: chaos middleware over the
        // locality-routed fabric — the crashed rank shares a host with
        // the coordinator, so its death surfaces through the shm
        // closed flag and the TCP detector — recovers onto the SAME
        // bits as the graceful trajectory on the channel fabric.
        use crate::coordinator::elastic::plan_migration;
        use crate::transport::chaos::ChaosConfig;

        let membership =
            || vec![member(2, 0.5), member(1, 0.3), member(1, 0.2)];
        let cfg = DistConfig {
            seed: 11,
            shard_params: true,
            ft: true,
            ..Default::default()
        };
        let hybrid_cfg =
            DistConfig { hosts: Some(vec![0, 1, 0]), ..cfg.clone() };
        let plan = FaultPlan::generate(
            7,
            3,
            &ChaosConfig {
                crash_ranks: 1,
                first_crash_step: 1,
                crash_step_stride: 1,
                delay_prob: 0.0,
                max_delay_ms: 0,
                dup_prob: 0.0,
                ..Default::default()
            },
        );
        assert_eq!(plan.for_rank(2).crash_after_step, Some(1));
        let mut chaotic = DistDriver::launch_with_chaos(
            FabricSpec::HybridThreads,
            3,
            hybrid_cfg,
            membership(),
            Some(ChaosOpts { plan, cli_spec: None }),
        )
        .unwrap();
        let mut graceful =
            DistDriver::launch(FabricSpec::Local, 3, cfg, membership())
                .unwrap();
        for s in 0..2 {
            chaotic.step(s).unwrap();
            graceful.step(s).unwrap();
        }
        assert_eq!(chaotic.poll_failures().dead, vec![2]);
        assert!(graceful.poll_failures().is_empty());
        let new_membership = vec![member(2, 0.6), member(1, 0.4)];
        let survivors = vec![Some(0), Some(1)];
        for d in [&mut chaotic, &mut graceful] {
            let old = d.layout().clone();
            let new = layout_of(&new_membership, old.len());
            let (transfers, _, _) = plan_migration(&old, &new, &survivors);
            d.migrate(new_membership.clone(), &survivors, &transfers)
                .unwrap();
        }
        for s in 2..4 {
            chaotic.step(s).unwrap();
            graceful.step(s).unwrap();
        }
        assert_eq!(
            chaotic.gather_params().unwrap(),
            graceful.gather_params().unwrap(),
            "hybrid crash recovery diverged from the graceful local run"
        );
        chaotic.shutdown();
        graceful.shutdown();
    }

    #[test]
    fn bad_host_maps_and_shm_chaos_are_rejected_at_launch() {
        let membership = vec![member(2, 0.5), member(1, 0.5)];
        // Host map must cover the whole process world.
        let cfg = DistConfig {
            hosts: Some(vec![0]),
            ..Default::default()
        };
        assert!(DistDriver::launch(
            FabricSpec::HybridThreads,
            2,
            cfg,
            membership.clone(),
        )
        .is_err());
        // Pure shm has no liveness fabric for process-crash chaos.
        let err = DistDriver::launch_with_chaos(
            FabricSpec::ShmProcesses,
            2,
            DistConfig::default(),
            membership,
            Some(ChaosOpts {
                plan: FaultPlan::quiet(2),
                cli_spec: Some("seed=1".into()),
            }),
        )
        .unwrap_err();
        assert!(err.to_string().contains("liveness"), "{err}");
    }

    #[test]
    fn ft_crash_recovery_matches_the_graceful_departure_bitwise() {
        // Invariant 12 at the driver level: a rank-2 crash (chaos,
        // detected by poll_failures, state re-streamed from rank 0's
        // mirror) converges bitwise with the SAME membership change
        // executed gracefully (rank 2 alive as the standby source) —
        // leader-resident and fully-sharded.
        use crate::coordinator::elastic::plan_migration;
        use crate::transport::chaos::ChaosConfig;

        for shard_params in [false, true] {
            let membership =
                || vec![member(2, 0.5), member(1, 0.3), member(1, 0.2)];
            let cfg = DistConfig {
                seed: 11,
                shard_params,
                ft: true,
                ..Default::default()
            };
            // Rank 2 self-crashes on its first fetch after completing
            // step 1 (reply and mirror sync included).
            let plan = FaultPlan::generate(
                7,
                3,
                &ChaosConfig {
                    crash_ranks: 1,
                    first_crash_step: 1,
                    crash_step_stride: 1,
                    delay_prob: 0.0,
                    max_delay_ms: 0,
                    dup_prob: 0.0,
                    ..Default::default()
                },
            );
            assert_eq!(plan.for_rank(2).crash_after_step, Some(1));
            let mut chaotic = DistDriver::launch_with_chaos(
                FabricSpec::Local,
                3,
                cfg.clone(),
                membership(),
                Some(ChaosOpts { plan, cli_spec: None }),
            )
            .unwrap();
            let mut graceful =
                DistDriver::launch(FabricSpec::Local, 3, cfg, membership())
                    .unwrap();

            for s in 0..2 {
                chaotic.step(s).unwrap();
                graceful.step(s).unwrap();
            }
            assert_eq!(chaotic.poll_failures().dead, vec![2]);
            assert_eq!(chaotic.dead_ranks(), vec![2]);
            assert!(graceful.poll_failures().is_empty());

            let new_membership = vec![member(2, 0.6), member(1, 0.4)];
            let survivors = vec![Some(0), Some(1)];
            for d in [&mut chaotic, &mut graceful] {
                let old = d.layout().clone();
                let new = layout_of(&new_membership, old.len());
                let (transfers, _, _) =
                    plan_migration(&old, &new, &survivors);
                d.migrate(new_membership.clone(), &survivors, &transfers)
                    .unwrap();
            }
            for s in 2..4 {
                chaotic.step(s).unwrap();
                graceful.step(s).unwrap();
            }
            assert_eq!(
                chaotic.gather_params().unwrap(),
                graceful.gather_params().unwrap(),
                "crash recovery diverged (shard_params={shard_params})"
            );
            chaotic.shutdown();
            graceful.shutdown();
        }
    }

    #[test]
    fn shutdown_is_bounded_with_a_crashed_and_a_deaf_worker() {
        // Satellite 4 regression: rank 1 is already dead (crashed at
        // step 0) and rank 2 swallows its SHUTDOWN frame. The old
        // teardown joined forever on rank 2; closing the coordinator
        // endpoint now cascades a hangup that unblocks it.
        use crate::transport::chaos::RankFaults;

        let mut plan = FaultPlan::quiet(3);
        plan.faults[1].crash_after_step = Some(0);
        plan.faults[2] = RankFaults {
            drop_shutdown: true,
            ..RankFaults::quiet(2)
        };
        let cfg = DistConfig { seed: 3, ft: true, ..Default::default() };
        let membership =
            vec![member(2, 0.5), member(1, 0.3), member(1, 0.2)];
        let mut d = DistDriver::launch_with_chaos(
            FabricSpec::Local,
            3,
            cfg,
            membership,
            Some(ChaosOpts { plan, cli_spec: None }),
        )
        .unwrap();
        d.step(0).unwrap();
        assert_eq!(d.poll_failures().dead, vec![1]);
        let t0 = Instant::now();
        d.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "teardown must not hang on dead or deaf workers"
        );
    }

    #[test]
    fn timer_substitutes_modeled_step_time() {
        let cfg = DistConfig::default();
        let driver = DistDriver::launch(
            FabricSpec::Local,
            1,
            cfg,
            vec![member(2, 1.0)],
        )
        .unwrap();
        let mut driver = driver.with_timer(StepTimeModel {
            per_sample_seconds: vec![10.0],
            fixed_seconds: 1.0,
        });
        let st = driver.step(0).unwrap();
        assert_eq!(st.wall_seconds, 21.0); // 2 samples x 10s + 1s fixed
        assert!(st.measured_seconds < 5.0, "measured wall should be real");
    }
}
