//! Multi-process training: the SPMD rank engine, the `cephalo worker`
//! serving loop, and the coordinator-side driver.
//!
//! Every rank — the coordinator's resident rank 0 and each worker
//! thread/process — runs the SAME per-step pipeline as the in-process
//! [`crate::trainer::Trainer`], but against its own state and a
//! [`Transport`] endpoint:
//!
//! 1. sample the global batch from the shared-seed corpus (ALL ranks,
//!    standby included, so a rank that rejoins after churn is still on
//!    the same data stream);
//! 2. run the native backend on this rank's batch share only;
//! 3. ring ReduceScatter the gradients over the wire
//!    ([`super::collectives`]), scale by 1/tokens (Eq. 1);
//! 4. sharded Adam on this rank's `r_i` shard;
//! 5. ring AllGather the updated parameters.
//!
//! Because the native backend's gradient summation is exact (dyadic
//! quantization, `exec::native`) and the wire collectives are
//! bit-identical to the in-process rings, the distributed trajectory is
//! BITWISE the in-process (and single-worker) trajectory — asserted in
//! `tests/dist_session.rs`.
//!
//! Membership churn: the coordinator broadcasts a [`MigrateCmd`]
//! carrying the new membership and the `elastic::plan_migration`
//! transfer list; survivors keep their resident overlap, peers stream
//! moved ranges rank-to-rank, and ranges whose owner left the
//! membership are re-streamed by the (still running, now standby)
//! process that holds them — numerically identical to the in-process
//! session's checkpoint restore. Command/data frames are FIFO per
//! peer, so no barrier is needed between commands.

use std::time::{Duration, Instant};

use crate::coordinator::elastic::Transfer;
use crate::exec::native::MAX_STEP_TOKENS;
use crate::exec::{NativeExecutor, StepExecutor, StepTimeModel, SurrogateSpec};
use crate::sharding::ShardLayout;
use crate::trainer::adam::{AdamConfig, AdamShard};
use crate::trainer::data::{split_batch, Corpus};
use crate::trainer::{flatten, unflatten, StepStats, WorkerSpec};
use crate::transport::{collectives as wire, LocalFabric, Transport};
use crate::util::error::{anyhow, Result};

/// Which fabric a distributed run is built on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricSpec {
    /// In-process channels, worker ranks as threads (`--transport
    /// local`). Zero syscalls; the message plane is still real.
    Local,
    /// TCP loopback sockets, worker ranks as threads — the shape tests
    /// and benches use (real sockets, one process).
    TcpThreads,
    /// TCP sockets, worker ranks as SPAWNED `cephalo worker` processes
    /// (`--transport tcp`). Requires the running executable to BE the
    /// cephalo binary: workers are spawned as `current_exe() worker
    /// --rank i --connect addr --world n`.
    TcpProcesses,
}

impl FabricSpec {
    /// Parse a `--transport` CLI value; `None` for the in-process
    /// (transport-less) trainer.
    pub fn parse(s: &str) -> Result<Option<FabricSpec>> {
        match s {
            "inproc" => Ok(None),
            "local" => Ok(Some(FabricSpec::Local)),
            "tcp" => Ok(Some(FabricSpec::TcpProcesses)),
            other => Err(anyhow!(
                "unknown transport '{other}' (inproc | local | tcp)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FabricSpec::Local => "local",
            FabricSpec::TcpThreads => "tcp",
            FabricSpec::TcpProcesses => "tcp",
        }
    }
}

/// Everything a rank needs to stand itself up, broadcast in `INIT`.
#[derive(Debug, Clone)]
pub struct DistConfig {
    pub seed: u64,
    pub adam: AdamConfig,
    pub corpus_branch: usize,
    pub surrogate: SurrogateSpec,
    /// Fully-sharded parameters: every rank holds only its `r_i` slice
    /// of the weights, materializing the full vector per step with the
    /// wire AllGather (mirrors [`crate::trainer::TrainConfig`]'s flag;
    /// bitwise-identical either way).
    pub shard_params: bool,
}

impl Default for DistConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            adam: AdamConfig::default(),
            corpus_branch: 4,
            surrogate: SurrogateSpec::default(),
            shard_params: false,
        }
    }
}

/// A membership change, broadcast by the coordinator.
#[derive(Debug, Clone)]
pub struct MigrateCmd {
    pub new_membership: Vec<WorkerSpec>,
    /// `survivors[new_rank]` = the old rank of the same physical
    /// worker. Over a transport, memberships are prefixes of the fixed
    /// process world, so survivor entries must be identity (`Some(i)`
    /// at index `i`) or `None` for ranks entering the membership.
    pub survivors: Vec<Option<usize>>,
    pub transfers: Vec<Transfer>,
    /// Adam step counter carried onto rebuilt shards.
    pub adam_step: u64,
}

// ---- command wire codec (length-prefixed LE, no serde) --------------

const OP_INIT: u8 = 1;
const OP_STEP: u8 = 2;
const OP_MIGRATE: u8 = 3;
const OP_SHUTDOWN: u8 = 4;
/// Explicit parameter export (fully-sharded runs only): every active
/// rank streams its weight slice to rank 0, which assembles the full
/// vector — the wire counterpart of `Trainer::gather_params`.
const OP_COLLECT: u8 = 5;

#[derive(Default)]
struct W(Vec<u8>);

impl W {
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }
    fn u64(&mut self, x: u64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn i64(&mut self, x: i64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f64(&mut self, x: f64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
}

struct R<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> R<'a> {
    fn new(b: &'a [u8]) -> R<'a> {
        R { b, i: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(anyhow!("truncated command frame"));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn put_membership(w: &mut W, m: &[WorkerSpec]) {
    w.u64(m.len() as u64);
    for spec in m {
        w.u64(spec.batch as u64);
        w.f64(spec.state_ratio);
    }
}

fn get_membership(r: &mut R<'_>) -> Result<Vec<WorkerSpec>> {
    let n = r.u64()? as usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let batch = r.u64()? as usize;
        let state_ratio = r.f64()?;
        out.push(WorkerSpec { batch, state_ratio, name: format!("rank{i}") });
    }
    Ok(out)
}

fn encode_init(cfg: &DistConfig, membership: &[WorkerSpec]) -> Vec<u8> {
    let mut w = W::default();
    w.u8(OP_INIT);
    w.u64(cfg.seed);
    w.u64(cfg.corpus_branch as u64);
    w.u64(cfg.surrogate.vocab as u64);
    w.u64(cfg.surrogate.dim as u64);
    w.u64(cfg.surrogate.seq_len as u64);
    w.f64(cfg.adam.lr as f64);
    w.f64(cfg.adam.beta1 as f64);
    w.f64(cfg.adam.beta2 as f64);
    w.f64(cfg.adam.eps as f64);
    w.f64(cfg.adam.weight_decay as f64);
    w.u8(u8::from(cfg.shard_params));
    put_membership(&mut w, membership);
    w.0
}

fn decode_init(r: &mut R<'_>) -> Result<(DistConfig, Vec<WorkerSpec>)> {
    let seed = r.u64()?;
    let corpus_branch = r.u64()? as usize;
    let surrogate = SurrogateSpec {
        vocab: r.u64()? as usize,
        dim: r.u64()? as usize,
        seq_len: r.u64()? as usize,
    };
    let adam = AdamConfig {
        lr: r.f64()? as f32,
        beta1: r.f64()? as f32,
        beta2: r.f64()? as f32,
        eps: r.f64()? as f32,
        weight_decay: r.f64()? as f32,
    };
    let shard_params = r.u8()? != 0;
    let membership = get_membership(r)?;
    Ok((
        DistConfig { seed, adam, corpus_branch, surrogate, shard_params },
        membership,
    ))
}

fn encode_migrate(cmd: &MigrateCmd) -> Vec<u8> {
    let mut w = W::default();
    w.u8(OP_MIGRATE);
    w.u64(cmd.adam_step);
    put_membership(&mut w, &cmd.new_membership);
    w.u64(cmd.survivors.len() as u64);
    for s in &cmd.survivors {
        w.i64(s.map(|x| x as i64).unwrap_or(-1));
    }
    w.u64(cmd.transfers.len() as u64);
    for t in &cmd.transfers {
        w.i64(t.from.map(|x| x as i64).unwrap_or(-1));
        w.u64(t.to as u64);
        w.u64(t.start as u64);
        w.u64(t.len as u64);
    }
    w.0
}

fn decode_migrate(r: &mut R<'_>) -> Result<MigrateCmd> {
    let adam_step = r.u64()?;
    let new_membership = get_membership(r)?;
    let n = r.u64()? as usize;
    let mut survivors = Vec::with_capacity(n);
    for _ in 0..n {
        let s = r.i64()?;
        survivors.push(if s < 0 { None } else { Some(s as usize) });
    }
    let nt = r.u64()? as usize;
    let mut transfers = Vec::with_capacity(nt);
    for _ in 0..nt {
        let from = r.i64()?;
        transfers.push(Transfer {
            from: if from < 0 { None } else { Some(from as usize) },
            to: r.u64()? as usize,
            start: r.u64()? as usize,
            len: r.u64()? as usize,
        });
    }
    Ok(MigrateCmd { new_membership, survivors, transfers, adam_step })
}

/// The old-layout owner of flat position `pos` (the process that holds
/// the bytes, whether or not it is still in the membership).
fn owner_of(layout: &ShardLayout, pos: usize) -> Result<usize> {
    (0..layout.num_ranks())
        .find(|&r| layout.range(r).contains(&pos))
        .ok_or_else(|| anyhow!("flat position {pos} outside the layout"))
}

fn layout_of(membership: &[WorkerSpec], flat_len: usize) -> ShardLayout {
    // EXACTLY Trainer::from_executor's derivation, so the dist and
    // in-process shard boundaries agree bit for bit.
    let ratios: Vec<f64> =
        membership.iter().map(|w| w.state_ratio.max(0.0)).collect();
    ShardLayout::by_ratios(flat_len, &ratios)
}

/// One rank's SPMD training state.
pub struct DistRank {
    rank: usize,
    exec: NativeExecutor,
    corpus: Corpus,
    /// Leader-resident mode: the full parameters, rebuilt every step by
    /// the tail AllGather. EMPTY in fully-sharded mode (no rank holds a
    /// full copy between steps).
    params: Vec<Vec<f32>>,
    sizes: Vec<usize>,
    membership: Vec<WorkerSpec>,
    layout: ShardLayout,
    /// `None` while this rank is standby (outside the membership).
    shard: Option<AdamShard>,
    adam: AdamConfig,
    /// Fully-sharded weights: this rank's `layout.range(rank)` slice
    /// (`None` for standby ranks and in leader-resident mode).
    param_shard: Option<Vec<f32>>,
    shard_params: bool,
}

impl DistRank {
    pub fn init(
        rank: usize,
        cfg: &DistConfig,
        membership: Vec<WorkerSpec>,
    ) -> Result<DistRank> {
        if membership.is_empty() {
            return Err(anyhow!("need at least one member rank"));
        }
        let exec = NativeExecutor::new(cfg.surrogate.clone());
        let sizes = exec.param_sizes().to_vec();
        let flat_len: usize = sizes.iter().sum();
        let init = exec.init_params(cfg.seed);
        let corpus = Corpus::new(exec.vocab(), cfg.corpus_branch, cfg.seed);
        let layout = layout_of(&membership, flat_len);
        let active = rank < membership.len();
        let shard =
            active.then(|| AdamShard::new(layout.size(rank), cfg.adam));
        let (params, param_shard) = if cfg.shard_params {
            // Keep only this rank's slice of the deterministic init;
            // the full copy never survives init.
            let flat = crate::trainer::flatten(&init, flat_len);
            (
                Vec::new(),
                active.then(|| flat[layout.range(rank)].to_vec()),
            )
        } else {
            (init, None)
        };
        Ok(DistRank {
            rank,
            exec,
            corpus,
            params,
            sizes,
            membership,
            layout,
            shard,
            adam: cfg.adam,
            param_shard,
            shard_params: cfg.shard_params,
        })
    }

    pub fn membership(&self) -> &[WorkerSpec] {
        &self.membership
    }

    /// The leader-resident full parameters (empty in sharded mode —
    /// use the COLLECT path / `DistDriver::gather_params`).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// This rank's weight slice (`Some` only in fully-sharded mode on
    /// active ranks).
    pub fn param_shard_view(&self) -> Option<&[f32]> {
        self.param_shard.as_deref()
    }

    pub fn is_sharded(&self) -> bool {
        self.shard_params
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    fn flat_len(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// One SPMD step; returns this rank's `(loss_sum, token_count)`
    /// contribution (zeros for standby ranks, which only advance the
    /// corpus stream).
    pub fn step(&mut self, t: &mut dyn Transport) -> Result<(f64, f64)> {
        let seq = self.exec.seq_len();
        let b: usize = self.membership.iter().map(|w| w.batch).sum();
        if b == 0 {
            return Err(anyhow!("global batch is zero"));
        }
        // Every rank samples the SAME global batch (shared corpus
        // stream) — standby ranks too, so rejoining keeps alignment.
        let (tokens, targets) = self.corpus.sample_batch(b, seq);
        let group = self.membership.len();
        if self.rank >= group {
            return Ok((0.0, 0.0));
        }
        if b * seq > MAX_STEP_TOKENS {
            return Err(anyhow!(
                "{} tokens/step exceeds the exact-summation bound \
                 {MAX_STEP_TOKENS} (shrink batch or seq_len)",
                b * seq
            ));
        }
        let batches: Vec<usize> =
            self.membership.iter().map(|w| w.batch).collect();
        let parts = split_batch(&tokens, &targets, seq, &batches);
        let (my_tokens, my_targets) = parts
            .into_iter()
            .nth(self.rank)
            .expect("rank within membership");

        let flat_len = self.flat_len();
        // Materialize the full weights: resident in leader mode; in
        // fully-sharded mode a head-of-step wire AllGather of the
        // per-rank slices — bitwise the vector the leader path rebuilt
        // at the previous step's tail. Freed when the step returns.
        let materialized: Option<Vec<Vec<f32>>> = if self.shard_params {
            let mine = self.param_shard.as_deref().ok_or_else(|| {
                anyhow!("active rank {} has no parameter shard", self.rank)
            })?;
            let flat = wire::ring_allgather(t, mine, &self.layout)?;
            Some(unflatten(&flat, &self.sizes))
        } else {
            None
        };
        let full: &[Vec<f32>] = match &materialized {
            Some(m) => m,
            None => &self.params,
        };
        let (my_grad, my_loss, my_count) = if my_tokens.is_empty() {
            // A state-only rank (b_i = 0) contributes an exact zero
            // vector — bitwise what `worker_pass` returns on no rows.
            (vec![0f32; flat_len], 0.0, 0.0)
        } else {
            let part = vec![(my_tokens, my_targets)];
            let out = self.exec.run_step(full, &part)?;
            let g = out
                .worker_grads
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("backend returned no gradients"))?;
            (g, out.loss_sum, out.token_count)
        };

        // Eq.-1 denominator: the GLOBAL token count, known to all ranks
        // from the membership (sums of exact integers — identical to
        // the leader's f64 accumulation).
        let token_count = (b * seq) as f64;

        let mut grad_shard =
            wire::ring_reduce_scatter(t, &my_grad, &self.layout)?;
        let inv = 1.0 / token_count as f32;
        for g in grad_shard.iter_mut() {
            *g *= inv;
        }

        let range = self.layout.range(self.rank);
        let shard = self
            .shard
            .as_mut()
            .ok_or_else(|| anyhow!("active rank {} has no shard", self.rank))?;
        if self.shard_params {
            // Update the resident slice in place; no tail AllGather —
            // the next step's head gather re-materializes.
            let mut mine = self.param_shard.take().ok_or_else(|| {
                anyhow!("active rank {} has no parameter shard", self.rank)
            })?;
            shard.update(&mut mine, &grad_shard);
            self.param_shard = Some(mine);
        } else {
            let mut flat = flatten(&self.params, flat_len);
            shard.update(&mut flat[range.clone()], &grad_shard);
            let shard_view = flat[range].to_vec();
            let gathered =
                wire::ring_allgather(t, &shard_view, &self.layout)?;
            self.params = unflatten(&gathered, &self.sizes);
        }
        Ok((my_loss, my_count))
    }

    /// Ship this rank's weight slice to rank 0 — the worker half of the
    /// COLLECT export (fully-sharded runs only). Standby ranks and
    /// empty slices stay silent; the coordinator skips them by layout.
    pub fn send_param_shard(&self, t: &mut dyn Transport) -> Result<()> {
        if !self.shard_params {
            return Err(anyhow!("COLLECT on a leader-resident rank"));
        }
        if self.rank >= self.membership.len()
            || self.layout.size(self.rank) == 0
        {
            return Ok(());
        }
        let mine = self.param_shard.as_deref().ok_or_else(|| {
            anyhow!("active rank {} has no parameter shard", self.rank)
        })?;
        t.send_f32(0, mine)
    }

    /// Apply a membership change: local resident copy, peer transfers
    /// over the wire, params stream to ranks entering the membership.
    pub fn migrate(
        &mut self,
        t: &mut dyn Transport,
        cmd: &MigrateCmd,
    ) -> Result<()> {
        if cmd.new_membership.is_empty() {
            return Err(anyhow!("migration to an empty membership"));
        }
        if cmd.survivors.len() != cmd.new_membership.len() {
            return Err(anyhow!(
                "{} survivor entries for {} members",
                cmd.survivors.len(),
                cmd.new_membership.len()
            ));
        }
        for (i, s) in cmd.survivors.iter().enumerate() {
            if let Some(j) = s {
                if *j != i {
                    return Err(anyhow!(
                        "non-prefix survivor map (new rank {i} was old \
                         rank {j}): transport ranks are pinned to \
                         process ranks"
                    ));
                }
            }
        }
        let flat_len = self.flat_len();
        let old_layout = self.layout.clone();
        let new_layout = layout_of(&cmd.new_membership, flat_len);
        let new_group = cmd.new_membership.len();
        let is_active = self.rank < new_group;

        // Resident prefill: the overlap of my old and new ranges never
        // leaves this rank (mirrors `elastic::apply_migration`). In
        // fully-sharded mode the weight slice migrates exactly like the
        // moments — same ranges, same transfer list.
        let mut new_m = vec![0f32; if is_active { new_layout.size(self.rank) } else { 0 }];
        let mut new_v = vec![0f32; new_m.len()];
        let mut new_w =
            vec![0f32; if self.shard_params { new_m.len() } else { 0 }];
        if is_active && cmd.survivors[self.rank].is_some() {
            let old = self
                .shard
                .as_ref()
                .ok_or_else(|| anyhow!("survivor {} has no shard", self.rank))?;
            let nr = new_layout.range(self.rank);
            let or = old_layout.range(self.rank);
            let lo = nr.start.max(or.start);
            let hi = nr.end.min(or.end);
            if lo < hi {
                new_m[lo - nr.start..hi - nr.start]
                    .copy_from_slice(&old.m[lo - or.start..hi - or.start]);
                new_v[lo - nr.start..hi - nr.start]
                    .copy_from_slice(&old.v[lo - or.start..hi - or.start]);
                if self.shard_params {
                    let w = self.param_shard.as_ref().ok_or_else(|| {
                        anyhow!(
                            "survivor {} has no parameter shard",
                            self.rank
                        )
                    })?;
                    new_w[lo - nr.start..hi - nr.start].copy_from_slice(
                        &w[lo - or.start..hi - or.start],
                    );
                }
            }
        }

        // The transfer list, in list order on every rank (frames are
        // FIFO per pair, sends never block: deadlock-free by
        // induction on list position).
        for tr in &cmd.transfers {
            let src = owner_of(&old_layout, tr.start)?;
            if tr.start + tr.len > old_layout.range(src).end {
                return Err(anyhow!(
                    "transfer [{}, +{}) spans old-shard boundaries",
                    tr.start,
                    tr.len
                ));
            }
            if self.rank == src {
                let old = self.shard.as_ref().ok_or_else(|| {
                    anyhow!("transfer source {src} holds no shard")
                })?;
                let a = tr.start - old_layout.range(src).start;
                t.send_f32(tr.to, &old.m[a..a + tr.len])?;
                t.send_f32(tr.to, &old.v[a..a + tr.len])?;
                if self.shard_params {
                    let w = self.param_shard.as_ref().ok_or_else(|| {
                        anyhow!(
                            "transfer source {src} holds no parameter \
                             shard"
                        )
                    })?;
                    t.send_f32(tr.to, &w[a..a + tr.len])?;
                }
            }
            if is_active && self.rank == tr.to {
                let nr = new_layout.range(self.rank);
                if tr.start < nr.start || tr.start + tr.len > nr.end {
                    return Err(anyhow!(
                        "transfer [{}, +{}) outside rank {}'s new range",
                        tr.start,
                        tr.len,
                        self.rank
                    ));
                }
                let a = tr.start - nr.start;
                let m_in = t.recv_f32(src)?;
                let v_in = t.recv_f32(src)?;
                if m_in.len() != tr.len || v_in.len() != tr.len {
                    return Err(anyhow!(
                        "transfer payload mismatch: got {}+{} elems, \
                         wanted {}",
                        m_in.len(),
                        v_in.len(),
                        tr.len
                    ));
                }
                new_m[a..a + tr.len].copy_from_slice(&m_in);
                new_v[a..a + tr.len].copy_from_slice(&v_in);
                if self.shard_params {
                    let w_in = t.recv_f32(src)?;
                    if w_in.len() != tr.len {
                        return Err(anyhow!(
                            "weight transfer holds {} elems, wanted {}",
                            w_in.len(),
                            tr.len
                        ));
                    }
                    new_w[a..a + tr.len].copy_from_slice(&w_in);
                }
            }
        }

        // Leader-resident only: ranks ENTERING the membership receive
        // the current full parameters from rank 0 (bitwise-identical on
        // every active rank, so any source would do). Fully-sharded
        // ranks need no such stream — an entering rank's entire weight
        // slice is covered by the transfer list above (ownership of
        // every element it now holds changed by definition).
        if !self.shard_params {
            let flat = flatten(&self.params, flat_len);
            for (r, surv) in cmd.survivors.iter().enumerate() {
                if surv.is_some() {
                    continue;
                }
                if self.rank == 0 {
                    t.send_f32(r, &flat)?;
                }
                if self.rank == r {
                    let data = t.recv_f32(0)?;
                    if data.len() != flat_len {
                        return Err(anyhow!(
                            "param stream holds {} elems, wanted {flat_len}",
                            data.len()
                        ));
                    }
                    self.params = unflatten(&data, &self.sizes);
                }
            }
        }

        self.membership = cmd.new_membership.clone();
        self.layout = new_layout;
        self.shard = is_active.then(|| AdamShard {
            m: new_m,
            v: new_v,
            step: cmd.adam_step,
            cfg: self.adam,
        });
        self.param_shard = if self.shard_params && is_active {
            Some(new_w)
        } else {
            None
        };
        Ok(())
    }
}

/// The `cephalo worker` serving loop: execute coordinator commands
/// until `SHUTDOWN` (or the coordinator disconnects, which surfaces as
/// an error — fail-stop).
pub fn worker_loop(mut t: Box<dyn Transport>) -> Result<()> {
    let rank = t.rank();
    if rank == 0 {
        return Err(anyhow!("rank 0 is the coordinator, not a worker"));
    }
    let mut state: Option<DistRank> = None;
    let mut next_step: u64 = 0;
    loop {
        let cmd = t.recv_bytes(0)?;
        let mut r = R::new(&cmd);
        match r.u8()? {
            OP_INIT => {
                let (cfg, membership) = decode_init(&mut r)?;
                state = Some(DistRank::init(rank, &cfg, membership)?);
                next_step = 0;
            }
            OP_STEP => {
                // The step index doubles as a desync check: corpus
                // alignment requires EXACTLY one sample per step, so a
                // skipped or replayed command must fail loudly instead
                // of training on silently divergent batches.
                let idx = r.u64()?;
                if idx != next_step {
                    return Err(anyhow!(
                        "step desync at rank {rank}: coordinator says \
                         step {idx}, expected {next_step}"
                    ));
                }
                next_step += 1;
                let st = state
                    .as_mut()
                    .ok_or_else(|| anyhow!("STEP before INIT"))?;
                let active = rank < st.membership().len();
                let (loss, count) = st.step(t.as_mut())?;
                if active {
                    let mut w = W::default();
                    w.f64(loss);
                    w.f64(count);
                    t.send_bytes(0, &w.0)?;
                }
            }
            OP_MIGRATE => {
                let mc = decode_migrate(&mut r)?;
                state
                    .as_mut()
                    .ok_or_else(|| anyhow!("MIGRATE before INIT"))?
                    .migrate(t.as_mut(), &mc)?;
            }
            OP_COLLECT => {
                state
                    .as_ref()
                    .ok_or_else(|| anyhow!("COLLECT before INIT"))?
                    .send_param_shard(t.as_mut())?;
            }
            OP_SHUTDOWN => return Ok(()),
            op => return Err(anyhow!("unknown command op {op}")),
        }
    }
}

/// Coordinator-side handle on a distributed run: rank 0's own
/// [`DistRank`] plus the broadcast/collect plumbing and the worker
/// threads/processes behind it.
pub struct DistDriver {
    t: Box<dyn Transport>,
    rank0: DistRank,
    world: usize,
    spec: FabricSpec,
    sharded: bool,
    timer: Option<StepTimeModel>,
    threads: Vec<std::thread::JoinHandle<()>>,
    children: Vec<std::process::Child>,
    down: bool,
    pub history: Vec<StepStats>,
}

impl DistDriver {
    /// Stand up the fabric, spawn worker ranks, broadcast `INIT`.
    /// `membership` must have at most `world` entries (standby ranks
    /// idle until a migration admits them).
    pub fn launch(
        spec: FabricSpec,
        world: usize,
        cfg: DistConfig,
        membership: Vec<WorkerSpec>,
    ) -> Result<DistDriver> {
        if world < 1 {
            return Err(anyhow!("world size must be at least 1"));
        }
        if membership.is_empty() || membership.len() > world {
            return Err(anyhow!(
                "membership of {} ranks does not fit a {world}-rank world",
                membership.len()
            ));
        }
        let (t, threads, children) = match spec {
            FabricSpec::Local => {
                let mut eps = LocalFabric::new(world);
                let rest = eps.split_off(1);
                let t0: Box<dyn Transport> = Box::new(eps.remove(0));
                let threads = rest
                    .into_iter()
                    .map(|ep| {
                        std::thread::spawn(move || {
                            if let Err(e) = worker_loop(Box::new(ep)) {
                                crate::warn!("local worker exited: {e}");
                            }
                        })
                    })
                    .collect();
                (t0, threads, Vec::new())
            }
            FabricSpec::TcpThreads => {
                let rz = crate::transport::tcp::Rendezvous::bind(
                    "127.0.0.1:0",
                    world,
                )?;
                let addr = rz.local_addr()?;
                let threads = (1..world)
                    .map(|r| {
                        let addr = addr.clone();
                        std::thread::spawn(move || {
                            match crate::transport::tcp::connect(
                                &addr, r, world,
                            ) {
                                Ok(t) => {
                                    if let Err(e) = worker_loop(Box::new(t)) {
                                        crate::warn!(
                                            "tcp worker {r} exited: {e}"
                                        );
                                    }
                                }
                                Err(e) => crate::warn!(
                                    "tcp worker {r} never joined: {e}"
                                ),
                            }
                        })
                    })
                    .collect();
                let t0: Box<dyn Transport> = Box::new(rz.establish()?);
                (t0, threads, Vec::new())
            }
            FabricSpec::TcpProcesses => {
                let rz = crate::transport::tcp::Rendezvous::bind(
                    "127.0.0.1:0",
                    world,
                )?;
                let addr = rz.local_addr()?;
                let exe = std::env::current_exe()?;
                let children = (1..world)
                    .map(|r| {
                        std::process::Command::new(&exe)
                            .args([
                                "worker",
                                "--rank",
                                &r.to_string(),
                                "--connect",
                                &addr,
                                "--world",
                                &world.to_string(),
                            ])
                            .spawn()
                    })
                    .collect::<std::io::Result<Vec<_>>>()?;
                let t0: Box<dyn Transport> = Box::new(rz.establish()?);
                (t0, Vec::new(), children)
            }
        };
        let mut t = t;
        let init = encode_init(&cfg, &membership);
        for r in 1..world {
            t.send_bytes(r, &init)?;
        }
        let sharded = cfg.shard_params;
        let rank0 = DistRank::init(0, &cfg, membership)?;
        Ok(DistDriver {
            t,
            rank0,
            world,
            spec,
            sharded,
            timer: None,
            threads,
            children,
            down: false,
            history: Vec::new(),
        })
    }

    /// Attach simulated step durations (the `StepExecutor::step_seconds`
    /// hook for the dist path — keeps `--live` reports on modeled time).
    pub fn with_timer(mut self, timer: StepTimeModel) -> DistDriver {
        self.timer = Some(timer);
        self
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn backend_label(&self) -> &'static str {
        self.spec.label()
    }

    pub fn membership(&self) -> &[WorkerSpec] {
        self.rank0.membership()
    }

    /// Rank 0's resident full parameters. Panics on a fully-sharded
    /// run (no rank holds a full copy by design) — use
    /// [`DistDriver::gather_params`] for an explicit wire export.
    pub fn params(&self) -> &[Vec<f32>] {
        if self.sharded {
            panic!(
                "fully-sharded run holds no resident full parameters; \
                 use gather_params() (COLLECT export)"
            );
        }
        self.rank0.params()
    }

    /// True when the run shards its weights (no leader copy anywhere).
    pub fn is_sharded(&self) -> bool {
        self.sharded
    }

    /// Export the full parameters — rank 0's resident copy on a
    /// leader-resident run; on a fully-sharded run a COLLECT broadcast
    /// streams every active rank's weight slice to rank 0, which
    /// assembles the flat vector (the ONLY place a sharded run
    /// reconstitutes the weights outside a step).
    pub fn gather_params(&mut self) -> Result<Vec<Vec<f32>>> {
        if !self.sharded {
            return Ok(self.rank0.params().to_vec());
        }
        for r in 1..self.world {
            self.t.send_bytes(r, &[OP_COLLECT])?;
        }
        let layout = self.rank0.layout().clone();
        let group = self.rank0.membership().len();
        let mut flat = vec![0f32; layout.len()];
        let mine = self.rank0.param_shard_view().ok_or_else(|| {
            anyhow!("rank 0 is active but holds no parameter shard")
        })?;
        flat[layout.range(0)].copy_from_slice(mine);
        for r in 1..group {
            if layout.size(r) == 0 {
                continue;
            }
            let s = self.t.recv_f32(r)?;
            if s.len() != layout.size(r) {
                return Err(anyhow!(
                    "rank {r} streamed {} weight elems, layout wants {}",
                    s.len(),
                    layout.size(r)
                ));
            }
            flat[layout.range(r)].copy_from_slice(&s);
        }
        Ok(unflatten(&flat, self.rank0.sizes()))
    }

    pub fn layout(&self) -> &ShardLayout {
        self.rank0.layout()
    }

    /// Adam step counter of the running shards (all active ranks
    /// agree; rank 0 is always active).
    pub fn adam_step(&self) -> u64 {
        self.rank0.shard.as_ref().map(|s| s.step).unwrap_or(0)
    }

    /// Drive one global step: broadcast, run rank 0's share, fold in
    /// worker losses (rank order — the leader's f64 accumulation
    /// order). `step_idx` labels the returned stats; the wire carries
    /// the driver's own monotone step counter, which every worker
    /// checks against its local count (corpus-alignment desync guard).
    pub fn step(&mut self, step_idx: usize) -> Result<StepStats> {
        let t0 = Instant::now();
        let group = self.rank0.membership().len();
        let batches: Vec<usize> =
            self.rank0.membership().iter().map(|w| w.batch).collect();
        let mut w = W::default();
        w.u8(OP_STEP);
        w.u64(self.history.len() as u64);
        for r in 1..self.world {
            self.t.send_bytes(r, &w.0)?;
        }
        let (mut loss_sum, mut token_count) =
            self.rank0.step(self.t.as_mut())?;
        for r in 1..group {
            let reply = self.t.recv_bytes(r)?;
            let mut rd = R::new(&reply);
            loss_sum += rd.f64()?;
            token_count += rd.f64()?;
        }
        if token_count <= 0.0 {
            return Err(anyhow!("distributed step saw no tokens"));
        }
        let measured = t0.elapsed().as_secs_f64();
        let stats = StepStats {
            step: step_idx,
            mean_loss: loss_sum / token_count,
            tokens: token_count,
            wall_seconds: match &self.timer {
                Some(m) => m.step_seconds(&batches),
                None => measured,
            },
            measured_seconds: measured,
        };
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Broadcast and execute a membership change.
    pub fn migrate(
        &mut self,
        new_membership: Vec<WorkerSpec>,
        survivors: &[Option<usize>],
        transfers: &[Transfer],
    ) -> Result<()> {
        if new_membership.len() > self.world {
            return Err(anyhow!(
                "membership of {} ranks does not fit a {}-rank world",
                new_membership.len(),
                self.world
            ));
        }
        let cmd = MigrateCmd {
            new_membership,
            survivors: survivors.to_vec(),
            transfers: transfers.to_vec(),
            adam_step: self.adam_step(),
        };
        let frame = encode_migrate(&cmd);
        for r in 1..self.world {
            self.t.send_bytes(r, &frame)?;
        }
        self.rank0.migrate(self.t.as_mut(), &cmd)
    }

    /// Stop every worker rank and reap threads/processes. Idempotent;
    /// also run on drop.
    pub fn shutdown(&mut self) {
        if !self.down {
            self.down = true;
            for r in 1..self.world {
                let _ = self.t.send_bytes(r, &[OP_SHUTDOWN]);
            }
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        for mut c in self.children.drain(..) {
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match c.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(50));
                    }
                    _ => {
                        let _ = c.kill();
                        let _ = c.wait();
                        break;
                    }
                }
            }
        }
    }
}

impl Drop for DistDriver {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn member(batch: usize, ratio: f64) -> WorkerSpec {
        WorkerSpec { batch, state_ratio: ratio, name: "m".into() }
    }

    #[test]
    fn command_frames_round_trip() {
        let cfg = DistConfig { seed: 9, corpus_branch: 3, ..Default::default() };
        let membership = vec![member(3, 0.7), member(1, 0.3)];
        let frame = encode_init(&cfg, &membership);
        let mut r = R::new(&frame);
        assert_eq!(r.u8().unwrap(), OP_INIT);
        let (back, mem) = decode_init(&mut r).unwrap();
        assert_eq!(back.seed, 9);
        assert_eq!(back.corpus_branch, 3);
        assert_eq!(back.adam.lr, cfg.adam.lr);
        assert_eq!(back.surrogate.vocab, cfg.surrogate.vocab);
        assert_eq!(mem.len(), 2);
        assert_eq!(mem[0].batch, 3);
        assert_eq!(mem[1].state_ratio, 0.3);

        let mc = MigrateCmd {
            new_membership: vec![member(4, 1.0)],
            survivors: vec![Some(0)],
            transfers: vec![
                Transfer { from: None, to: 0, start: 5, len: 7 },
                Transfer { from: Some(1), to: 0, start: 12, len: 1 },
            ],
            adam_step: 17,
        };
        let frame = encode_migrate(&mc);
        let mut r = R::new(&frame);
        assert_eq!(r.u8().unwrap(), OP_MIGRATE);
        let back = decode_migrate(&mut r).unwrap();
        assert_eq!(back.adam_step, 17);
        assert_eq!(back.survivors, vec![Some(0)]);
        assert_eq!(back.transfers, mc.transfers);
        assert_eq!(back.new_membership.len(), 1);

        // Truncated frames error instead of panicking.
        let mut r = R::new(&frame[..4]);
        let _ = r.u8();
        assert!(decode_migrate(&mut r).is_err());
    }

    #[test]
    fn local_driver_matches_single_worker_reference() {
        use crate::exec::{NativeExecutor, SurrogateSpec};
        use crate::trainer::{TrainConfig, Trainer};

        let cfg = DistConfig { seed: 5, ..Default::default() };
        let membership = vec![member(3, 0.7), member(1, 0.3)];
        let mut driver =
            DistDriver::launch(FabricSpec::Local, 2, cfg, membership)
                .unwrap();

        let tcfg = TrainConfig {
            steps: 0,
            seed: 5,
            log_every: 0,
            ..Default::default()
        };
        let mut reference = Trainer::from_executor(
            Box::new(NativeExecutor::new(SurrogateSpec::default())),
            vec![member(4, 1.0)],
            tcfg,
        )
        .unwrap();

        assert_eq!(driver.params(), reference.params());
        for s in 0..3 {
            let st = driver.step(s).unwrap();
            reference.step(s).unwrap();
            assert!(st.mean_loss.is_finite() && st.mean_loss > 0.0);
            assert_eq!(
                driver.params(),
                reference.params(),
                "diverged at step {s}"
            );
        }
        driver.shutdown();
    }

    #[test]
    fn sharded_driver_matches_replicated_driver_bitwise() {
        // Fully-sharded SPMD ranks (head-of-step wire AllGather, no
        // resident full copy anywhere) ride the replicated trajectory
        // bit for bit; gather_params() is the COLLECT export.
        let membership = || vec![member(3, 0.7), member(1, 0.3)];
        let cfg = DistConfig { seed: 5, ..Default::default() };
        let shcfg = DistConfig {
            seed: 5,
            shard_params: true,
            ..Default::default()
        };
        let mut rep =
            DistDriver::launch(FabricSpec::Local, 2, cfg, membership())
                .unwrap();
        let mut sh =
            DistDriver::launch(FabricSpec::Local, 2, shcfg, membership())
                .unwrap();
        assert!(sh.is_sharded() && !rep.is_sharded());
        assert_eq!(sh.gather_params().unwrap(), rep.params());
        for s in 0..3 {
            rep.step(s).unwrap();
            sh.step(s).unwrap();
            assert_eq!(
                sh.gather_params().unwrap(),
                rep.params(),
                "sharded run diverged at step {s}"
            );
        }
        rep.shutdown();
        sh.shutdown();
    }

    #[test]
    fn timer_substitutes_modeled_step_time() {
        let cfg = DistConfig::default();
        let driver = DistDriver::launch(
            FabricSpec::Local,
            1,
            cfg,
            vec![member(2, 1.0)],
        )
        .unwrap();
        let mut driver = driver.with_timer(StepTimeModel {
            per_sample_seconds: vec![10.0],
            fixed_seconds: 1.0,
        });
        let st = driver.step(0).unwrap();
        assert_eq!(st.wall_seconds, 21.0); // 2 samples x 10s + 1s fixed
        assert!(st.measured_seconds < 5.0, "measured wall should be real");
    }
}
