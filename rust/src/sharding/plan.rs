//! Whole-model shard planning with greedy skew minimization (§3.3
//! Uneven Parameter Sharding).
//!
//! Target: per-GPU state ratios `r_i` over `units` identical FSDP units.
//! The planner assigns each unit either the even layout (no uneven
//! collective overhead) or a corrective uneven layout, such that the
//! cumulative assignment tracks the target ratios while minimizing the
//! number of uneven units — the paper's "3:1 over two GPUs -> one unit
//! 1:1 + one unit 1:0" construction.

use super::ShardLayout;

/// Layout decision for one FSDP unit.
#[derive(Debug, Clone)]
pub struct UnitShard {
    pub unit: usize,
    pub layout: ShardLayout,
    /// True if this unit pays the uneven-collective overhead.
    pub uneven: bool,
}

/// Shard layouts for every unit of the model.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub units: Vec<UnitShard>,
    pub unit_params: usize,
    pub n_gpus: usize,
}

impl ShardPlan {
    /// Greedy plan: for each unit in sequence, give every GPU either its
    /// even share or a corrective share, chosen so the *remaining*
    /// deficit (target minus assigned so far) shrinks fastest; a unit is
    /// sharded evenly whenever the even split keeps all cumulative
    /// assignments within one unit-share of target.
    pub fn plan(units: usize, unit_params: usize, ratios: &[f64])
        -> ShardPlan {
        let n = ratios.len();
        assert!(n > 0 && units > 0);
        let total: f64 = ratios.iter().sum();
        assert!(total > 0.0);
        let norm: Vec<f64> = ratios.iter().map(|r| r / total).collect();

        let total_params = units * unit_params;
        // Target cumulative parameters per GPU.
        let target: Vec<f64> =
            norm.iter().map(|r| r * total_params as f64).collect();
        let mut assigned = vec![0usize; n];
        let mut out = Vec::with_capacity(units);

        for u in 0..units {
            // Remaining units after this one.
            let remaining_after = (units - u - 1) * unit_params;
            // If giving every GPU the even share keeps everyone's
            // remaining deficit satisfiable by the remaining units
            // (deficit between 0 and remaining capacity), use even.
            let even = ShardLayout::even(unit_params, n);
            let even_ok = (0..n).all(|i| {
                let after = assigned[i] + even.size(i);
                let deficit = target[i] - after as f64;
                deficit >= -(unit_params as f64)
                    && deficit <= remaining_after as f64
            });
            let layout = if even_ok {
                even
            } else {
                // Corrective layout: give each GPU its remaining deficit
                // (clamped at 0), normalized over this unit.
                let deficits: Vec<f64> = (0..n)
                    .map(|i| (target[i] - assigned[i] as f64).max(0.0))
                    .collect();
                let dsum: f64 = deficits.iter().sum();
                if dsum <= 0.0 {
                    ShardLayout::even(unit_params, n)
                } else {
                    ShardLayout::by_ratios(unit_params, &deficits)
                }
            };
            let uneven = !layout.is_even();
            for i in 0..n {
                assigned[i] += layout.size(i);
            }
            out.push(UnitShard { unit: u, layout, uneven });
        }
        ShardPlan { units: out, unit_params, n_gpus: n }
    }

    /// Number of units paying the uneven-collective overhead.
    pub fn uneven_units(&self) -> usize {
        self.units.iter().filter(|u| u.uneven).count()
    }

    /// Total parameters assigned to `gpu` across all units.
    pub fn params_on(&self, gpu: usize) -> usize {
        self.units.iter().map(|u| u.layout.size(gpu)).sum()
    }

    /// Achieved ratio per GPU.
    pub fn achieved_ratios(&self) -> Vec<f64> {
        let total = (self.units.len() * self.unit_params) as f64;
        (0..self.n_gpus)
            .map(|g| self.params_on(g) as f64 / total)
            .collect()
    }

    /// Max absolute deviation from target ratios (in parameters).
    pub fn max_deviation_params(&self, ratios: &[f64]) -> f64 {
        let total: f64 = ratios.iter().sum();
        let total_params = (self.units.len() * self.unit_params) as f64;
        (0..self.n_gpus)
            .map(|g| {
                let target = ratios[g] / total * total_params;
                (self.params_on(g) as f64 - target).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    #[test]
    fn even_ratios_need_no_uneven_units() {
        let plan = ShardPlan::plan(12, 1000, &[0.25; 4]);
        assert_eq!(plan.uneven_units(), 0);
        for g in 0..4 {
            assert_eq!(plan.params_on(g), 3000);
        }
    }

    #[test]
    fn paper_3_to_1_example() {
        // Two identical units over two GPUs with a 3:1 target: the plan
        // must shard one unit evenly (1:1) and one 1:0 — exactly one
        // uneven unit.
        let plan = ShardPlan::plan(2, 1000, &[3.0, 1.0]);
        assert_eq!(plan.uneven_units(), 1);
        assert_eq!(plan.params_on(0), 1500);
        assert_eq!(plan.params_on(1), 500);
    }

    #[test]
    fn skewed_ratio_tracks_target() {
        let ratios = [0.5, 0.3, 0.15, 0.05];
        let plan = ShardPlan::plan(24, 12_000_000, &ratios);
        assert!(plan.max_deviation_params(&ratios) < 2.0 * 12_000_000.0);
        let achieved = plan.achieved_ratios();
        for (a, r) in achieved.iter().zip(&ratios) {
            assert!((a - r).abs() < 0.09, "achieved {a} target {r}");
        }
    }

    #[test]
    fn uneven_units_fewer_than_naive() {
        // Naively sharding EVERY unit by ratio makes all units uneven;
        // the greedy plan should do far better for mild skew.
        let ratios = [0.3, 0.3, 0.2, 0.2];
        let plan = ShardPlan::plan(32, 100_000, &ratios);
        assert!(
            plan.uneven_units() <= 32 / 2,
            "too many uneven units: {}",
            plan.uneven_units()
        );
    }

    #[test]
    fn prop_plan_conserves_parameters() {
        check("shardplan-conserves", 100, |g| {
            let n = g.usize_in(1, 8);
            let units = g.usize_in(1, 48);
            let unit_params = g.usize_in(1, 10_000) * 8;
            let ratios = g.ratios(n);
            let plan = ShardPlan::plan(units, unit_params, &ratios);
            let total: usize = (0..n).map(|gpu| plan.params_on(gpu)).sum();
            assert_eq!(total, units * unit_params);
            // Every unit's layout covers the unit exactly.
            for u in &plan.units {
                assert_eq!(u.layout.len(), unit_params);
            }
        });
    }

    #[test]
    fn prop_deviation_bounded_by_one_unit() {
        check("shardplan-deviation", 100, |g| {
            let n = g.usize_in(1, 8);
            let units = g.usize_in(2, 48);
            let unit_params = 9600;
            let ratios = g.ratios(n);
            let plan = ShardPlan::plan(units, unit_params, &ratios);
            // Cumulative tracking keeps each GPU within ~2 unit-shares
            // of its target.
            let dev = plan.max_deviation_params(&ratios);
            assert!(
                dev <= 2.0 * unit_params as f64,
                "deviation {dev} > 2 units"
            );
        });
    }
}
