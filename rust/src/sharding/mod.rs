//! Uneven FSDP sharding (§2.1 Training State Partitioning, §3.3).
//!
//! Each FSDP unit (one transformer layer) holds `unit_params`
//! parameters. Given per-GPU training-state ratios `r_i` (Σ r_i = 1),
//! this module computes per-unit shard layouts, applying the paper's
//! greedy skew-minimization: prefer sharding as many whole units evenly
//! (1/N each) as possible and concentrate the imbalance into as few
//! uneven units as possible — e.g. a 3:1 target over two GPUs becomes
//! one unit sharded 1:1 and one sharded 1:0, paying the +15% uneven
//! collective overhead on only one unit.

pub mod plan;

pub use plan::{ShardPlan, UnitShard};

/// Per-GPU element ranges for one FSDP unit of `len` elements.
/// `bounds[i]..bounds[i+1]` is GPU i's slice.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLayout {
    pub bounds: Vec<usize>,
}

impl ShardLayout {
    /// Even 1/N split with remainder spread over the first ranks —
    /// FSDP's default layout.
    pub fn even(len: usize, n: usize) -> ShardLayout {
        assert!(n > 0);
        let base = len / n;
        let rem = len % n;
        let mut bounds = Vec::with_capacity(n + 1);
        let mut acc = 0;
        bounds.push(0);
        for i in 0..n {
            acc += base + usize::from(i < rem);
            bounds.push(acc);
        }
        ShardLayout { bounds }
    }

    /// Split `len` elements by ratio vector (need not be normalized).
    /// Largest-remainder rounding keeps Σ shards == len exactly.
    pub fn by_ratios(len: usize, ratios: &[f64]) -> ShardLayout {
        assert!(!ratios.is_empty());
        let total: f64 = ratios.iter().sum();
        assert!(total > 0.0, "ratios must not all be zero");
        let ideal: Vec<f64> =
            ratios.iter().map(|r| r / total * len as f64).collect();
        let mut sizes: Vec<usize> =
            ideal.iter().map(|x| x.floor() as usize).collect();
        let mut deficit = len - sizes.iter().sum::<usize>();
        // Assign leftover elements to the largest fractional parts.
        let mut order: Vec<usize> = (0..ratios.len()).collect();
        order.sort_by(|&a, &b| {
            let fa = ideal[a] - ideal[a].floor();
            let fb = ideal[b] - ideal[b].floor();
            fb.partial_cmp(&fa).unwrap()
        });
        for &i in order.iter() {
            if deficit == 0 {
                break;
            }
            sizes[i] += 1;
            deficit -= 1;
        }
        let mut bounds = vec![0usize];
        let mut acc = 0;
        for s in sizes {
            acc += s;
            bounds.push(acc);
        }
        ShardLayout { bounds }
    }

    pub fn num_ranks(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn len(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn range(&self, rank: usize) -> std::ops::Range<usize> {
        self.bounds[rank]..self.bounds[rank + 1]
    }

    pub fn size(&self, rank: usize) -> usize {
        self.bounds[rank + 1] - self.bounds[rank]
    }

    pub fn sizes(&self) -> Vec<usize> {
        (0..self.num_ranks()).map(|r| self.size(r)).collect()
    }

    /// Is this the even FSDP layout (max size diff <= 1)?
    pub fn is_even(&self) -> bool {
        let sizes = self.sizes();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        max - min <= 1
    }

    /// Largest shard / total (Fig. 12 skew metric).
    pub fn skew(&self) -> f64 {
        if self.len() == 0 {
            return 0.0;
        }
        *self.sizes().iter().max().unwrap() as f64 / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    #[test]
    fn even_layout_covers_everything() {
        let l = ShardLayout::even(10, 3);
        assert_eq!(l.sizes(), vec![4, 3, 3]);
        assert_eq!(l.len(), 10);
        assert!(l.is_even());
        assert_eq!(l.range(0), 0..4);
        assert_eq!(l.range(2), 7..10);
    }

    #[test]
    fn ratio_layout_matches_targets() {
        let l = ShardLayout::by_ratios(100, &[0.5, 0.25, 0.25]);
        assert_eq!(l.sizes(), vec![50, 25, 25]);
        let l2 = ShardLayout::by_ratios(4, &[3.0, 1.0]);
        assert_eq!(l2.sizes(), vec![3, 1]);
        assert!(!l2.is_even());
    }

    #[test]
    fn zero_ratio_means_zero_shard() {
        let l = ShardLayout::by_ratios(10, &[1.0, 0.0]);
        assert_eq!(l.sizes(), vec![10, 0]);
        assert!((l.skew() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_ratio_layout_is_exact_partition() {
        check("shard-partition-exact", 200, |g| {
            let n = g.usize_in(1, 12);
            let len = g.usize_in(0, 10_000);
            let ratios = g.ratios(n);
            let l = ShardLayout::by_ratios(len, &ratios);
            assert_eq!(l.len(), len);
            assert_eq!(l.num_ranks(), n);
            // Ranges are contiguous and disjoint by construction; check
            // monotone bounds.
            for w in l.bounds.windows(2) {
                assert!(w[0] <= w[1]);
            }
        });
    }

    #[test]
    fn prop_rounding_error_bounded() {
        check("shard-rounding-error", 200, |g| {
            let n = g.usize_in(1, 8);
            let len = g.usize_in(n * 10, 100_000);
            let ratios = g.ratios(n);
            let l = ShardLayout::by_ratios(len, &ratios);
            for (i, r) in ratios.iter().enumerate() {
                let ideal = r * len as f64;
                let got = l.size(i) as f64;
                assert!(
                    (got - ideal).abs() <= 1.0,
                    "rank {i}: ideal {ideal}, got {got}"
                );
            }
        });
    }

    #[test]
    fn skew_of_even_is_one_over_n() {
        let l = ShardLayout::even(100, 4);
        assert!((l.skew() - 0.25).abs() < 1e-12);
    }
}
