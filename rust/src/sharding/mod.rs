//! Uneven FSDP sharding (§2.1 Training State Partitioning, §3.3).
//!
//! Each FSDP unit (one transformer layer) holds `unit_params`
//! parameters. Given per-GPU training-state ratios `r_i` (Σ r_i = 1),
//! this module computes per-unit shard layouts, applying the paper's
//! greedy skew-minimization: prefer sharding as many whole units evenly
//! (1/N each) as possible and concentrate the imbalance into as few
//! uneven units as possible — e.g. a 3:1 target over two GPUs becomes
//! one unit sharded 1:1 and one sharded 1:0, paying the +15% uneven
//! collective overhead on only one unit.

pub mod plan;

pub use plan::{ShardPlan, UnitShard};

/// Per-GPU element ranges for one FSDP unit of `len` elements.
/// `bounds[i]..bounds[i+1]` is GPU i's slice.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLayout {
    pub bounds: Vec<usize>,
}

impl ShardLayout {
    /// Even 1/N split with remainder spread over the first ranks —
    /// FSDP's default layout.
    pub fn even(len: usize, n: usize) -> ShardLayout {
        assert!(n > 0);
        let base = len / n;
        let rem = len % n;
        let mut bounds = Vec::with_capacity(n + 1);
        let mut acc = 0;
        bounds.push(0);
        for i in 0..n {
            acc += base + usize::from(i < rem);
            bounds.push(acc);
        }
        ShardLayout { bounds }
    }

    /// Split `len` elements by ratio vector (need not be normalized).
    /// Largest-remainder rounding keeps Σ shards == len exactly.
    pub fn by_ratios(len: usize, ratios: &[f64]) -> ShardLayout {
        assert!(!ratios.is_empty());
        let total: f64 = ratios.iter().sum();
        assert!(total > 0.0, "ratios must not all be zero");
        let ideal: Vec<f64> =
            ratios.iter().map(|r| r / total * len as f64).collect();
        let mut sizes: Vec<usize> =
            ideal.iter().map(|x| x.floor() as usize).collect();
        let mut deficit = len - sizes.iter().sum::<usize>();
        // Assign leftover elements to the largest fractional parts.
        let mut order: Vec<usize> = (0..ratios.len()).collect();
        order.sort_by(|&a, &b| {
            let fa = ideal[a] - ideal[a].floor();
            let fb = ideal[b] - ideal[b].floor();
            fb.partial_cmp(&fa).unwrap()
        });
        for &i in order.iter() {
            if deficit == 0 {
                break;
            }
            sizes[i] += 1;
            deficit -= 1;
        }
        let mut bounds = vec![0usize];
        let mut acc = 0;
        for s in sizes {
            acc += s;
            bounds.push(acc);
        }
        ShardLayout { bounds }
    }

    pub fn num_ranks(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn len(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn range(&self, rank: usize) -> std::ops::Range<usize> {
        self.bounds[rank]..self.bounds[rank + 1]
    }

    pub fn size(&self, rank: usize) -> usize {
        self.bounds[rank + 1] - self.bounds[rank]
    }

    pub fn sizes(&self) -> Vec<usize> {
        (0..self.num_ranks()).map(|r| self.size(r)).collect()
    }

    /// Is this the even FSDP layout (max size diff <= 1)?
    pub fn is_even(&self) -> bool {
        let sizes = self.sizes();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        max - min <= 1
    }

    /// Largest shard / total (Fig. 12 skew metric).
    pub fn skew(&self) -> f64 {
        if self.len() == 0 {
            return 0.0;
        }
        *self.sizes().iter().max().unwrap() as f64 / self.len() as f64
    }
}

/// A partition of the flat parameter vector into contiguous FSDP
/// units, each carrying a unit-local [`ShardLayout`] cut from the
/// global one. Rank `r`'s slices across all units concatenate to
/// exactly `global.range(r)`, so the resident shard is IDENTICAL
/// whether the step gathers the whole model or one unit at a time —
/// the structural half of DESIGN.md invariant 13 (checkpoints,
/// migration, and adoption never see the unit dimension).
#[derive(Debug, Clone, PartialEq)]
pub struct UnitLayout {
    /// Unit boundaries over the flat vector:
    /// `ubounds[u]..ubounds[u+1]` is unit u's element range.
    pub ubounds: Vec<usize>,
    /// Per-unit rank layouts, rebased to each unit's origin.
    pub units: Vec<ShardLayout>,
}

impl UnitLayout {
    /// Cut `global` into `units` contiguous, near-even units (the
    /// remainder spreads over the first units, mirroring
    /// [`ShardLayout::even`]). `units` is clamped to at least 1.
    pub fn split(global: &ShardLayout, units: usize) -> UnitLayout {
        let outer = ShardLayout::even(global.len(), units.max(1));
        UnitLayout::from_bounds(global, outer.bounds)
    }

    /// Build a unit layout from EXPLICIT unit boundaries over `global`
    /// (monotone, first 0, last `global.len()`). Backends with
    /// alignment constraints (embedding-row cuts) come through here.
    pub fn from_bounds(
        global: &ShardLayout,
        ubounds: Vec<usize>,
    ) -> UnitLayout {
        assert!(ubounds.first() == Some(&0), "unit bounds must start at 0");
        assert_eq!(
            *ubounds.last().unwrap(),
            global.len(),
            "unit bounds must end at the flat length"
        );
        assert!(
            ubounds.windows(2).all(|w| w[0] <= w[1]),
            "unit bounds must be monotone"
        );
        let mut unit_layouts = Vec::with_capacity(ubounds.len() - 1);
        for w in ubounds.windows(2) {
            let (s, e) = (w[0], w[1]);
            let bounds: Vec<usize> = global
                .bounds
                .iter()
                .map(|&b| b.clamp(s, e) - s)
                .collect();
            unit_layouts.push(ShardLayout { bounds });
        }
        UnitLayout { ubounds, units: unit_layouts }
    }

    /// The unit layout for a backend whose splittable PREFIX is
    /// `region` elements with cuts on multiples of `align` (see
    /// `exec::StepExecutor::unit_region`): up to `units` near-even
    /// aligned units over the prefix, plus — when `[region, len)` is
    /// non-empty — one final unit holding the resident tail (the
    /// trainer gathers it whole at the head of the step). Degenerates
    /// to [`UnitLayout::whole`] when the backend has no unit region or
    /// one unit is asked for.
    pub fn for_prefix(
        global: &ShardLayout,
        region: usize,
        align: usize,
        units: usize,
    ) -> UnitLayout {
        let len = global.len();
        if units <= 1 || region == 0 || align == 0 || region > len {
            return UnitLayout::whole(global);
        }
        let rows = region / align;
        if rows == 0 {
            return UnitLayout::whole(global);
        }
        let outer = ShardLayout::even(rows, units.min(rows));
        let mut ubounds: Vec<usize> =
            outer.bounds.iter().map(|&b| b * align).collect();
        // An unaligned region remainder folds into the last prefix unit.
        *ubounds.last_mut().unwrap() = region;
        if region < len {
            ubounds.push(len);
        }
        UnitLayout::from_bounds(global, ubounds)
    }

    /// The degenerate single-unit layout: one unit covering the whole
    /// vector (unit-pipelined execution of this layout IS whole-model
    /// gather).
    pub fn whole(global: &ShardLayout) -> UnitLayout {
        UnitLayout::split(global, 1)
    }

    pub fn num_units(&self) -> usize {
        self.units.len()
    }

    /// Unit u's element range in the GLOBAL flat vector.
    pub fn unit_range(&self, u: usize) -> std::ops::Range<usize> {
        self.ubounds[u]..self.ubounds[u + 1]
    }

    pub fn unit_len(&self, u: usize) -> usize {
        self.ubounds[u + 1] - self.ubounds[u]
    }

    /// The unit-local shard layout for unit u.
    pub fn unit_layout(&self, u: usize) -> &ShardLayout {
        &self.units[u]
    }

    /// Rank `rank`'s slice of unit u, in GLOBAL flat coordinates.
    pub fn rank_slice(&self, u: usize, rank: usize) -> std::ops::Range<usize> {
        let local = self.units[u].range(rank);
        let base = self.ubounds[u];
        base + local.start..base + local.end
    }

    /// Elements in the largest unit — the per-rank transient
    /// materialization peak is `2 × 4 B ×` this (current + prefetched).
    pub fn largest_unit(&self) -> usize {
        (0..self.num_units()).map(|u| self.unit_len(u)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    #[test]
    fn even_layout_covers_everything() {
        let l = ShardLayout::even(10, 3);
        assert_eq!(l.sizes(), vec![4, 3, 3]);
        assert_eq!(l.len(), 10);
        assert!(l.is_even());
        assert_eq!(l.range(0), 0..4);
        assert_eq!(l.range(2), 7..10);
    }

    #[test]
    fn ratio_layout_matches_targets() {
        let l = ShardLayout::by_ratios(100, &[0.5, 0.25, 0.25]);
        assert_eq!(l.sizes(), vec![50, 25, 25]);
        let l2 = ShardLayout::by_ratios(4, &[3.0, 1.0]);
        assert_eq!(l2.sizes(), vec![3, 1]);
        assert!(!l2.is_even());
    }

    #[test]
    fn zero_ratio_means_zero_shard() {
        let l = ShardLayout::by_ratios(10, &[1.0, 0.0]);
        assert_eq!(l.sizes(), vec![10, 0]);
        assert!((l.skew() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_ratio_layout_is_exact_partition() {
        check("shard-partition-exact", 200, |g| {
            let n = g.usize_in(1, 12);
            let len = g.usize_in(0, 10_000);
            let ratios = g.ratios(n);
            let l = ShardLayout::by_ratios(len, &ratios);
            assert_eq!(l.len(), len);
            assert_eq!(l.num_ranks(), n);
            // Ranges are contiguous and disjoint by construction; check
            // monotone bounds.
            for w in l.bounds.windows(2) {
                assert!(w[0] <= w[1]);
            }
        });
    }

    #[test]
    fn prop_rounding_error_bounded() {
        check("shard-rounding-error", 200, |g| {
            let n = g.usize_in(1, 8);
            let len = g.usize_in(n * 10, 100_000);
            let ratios = g.ratios(n);
            let l = ShardLayout::by_ratios(len, &ratios);
            for (i, r) in ratios.iter().enumerate() {
                let ideal = r * len as f64;
                let got = l.size(i) as f64;
                assert!(
                    (got - ideal).abs() <= 1.0,
                    "rank {i}: ideal {ideal}, got {got}"
                );
            }
        });
    }

    #[test]
    fn skew_of_even_is_one_over_n() {
        let l = ShardLayout::even(100, 4);
        assert!((l.skew() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn unit_layout_partitions_both_axes_exactly() {
        let global = ShardLayout::by_ratios(100, &[0.6, 0.3, 0.1]);
        let ul = UnitLayout::split(&global, 4);
        assert_eq!(ul.num_units(), 4);
        // Units tile the flat vector.
        let total: usize = (0..4).map(|u| ul.unit_len(u)).sum();
        assert_eq!(total, 100);
        assert_eq!(ul.largest_unit(), 25);
        // Each rank's per-unit slices concatenate to its global range.
        for rank in 0..3 {
            let mut covered = Vec::new();
            for u in 0..ul.num_units() {
                let s = ul.rank_slice(u, rank);
                assert_eq!(
                    s.len(),
                    ul.unit_layout(u).size(rank),
                    "unit {u} rank {rank}"
                );
                covered.extend(s);
            }
            let expect: Vec<usize> = global.range(rank).collect();
            assert_eq!(covered, expect, "rank {rank} slices disagree");
        }
    }

    #[test]
    fn whole_unit_layout_is_the_global_layout() {
        let global = ShardLayout::by_ratios(37, &[0.5, 0.5]);
        let ul = UnitLayout::whole(&global);
        assert_eq!(ul.num_units(), 1);
        assert_eq!(ul.unit_range(0), 0..37);
        assert_eq!(ul.unit_layout(0), &global);
        assert_eq!(ul.largest_unit(), 37);
    }

    #[test]
    fn prefix_unit_layout_keeps_cuts_aligned_and_tail_whole() {
        // 8 rows of width 4 plus a 5-element tail.
        let global = ShardLayout::by_ratios(37, &[0.7, 0.3]);
        let ul = UnitLayout::for_prefix(&global, 32, 4, 3);
        // 3 prefix units + the tail unit.
        assert_eq!(ul.num_units(), 4);
        for u in 0..3 {
            assert_eq!(ul.unit_range(u).start % 4, 0, "unit {u} cut");
        }
        assert_eq!(ul.unit_range(3), 32..37);
        // Rank slices still concatenate to the global ranges.
        for rank in 0..2 {
            let covered: Vec<usize> = (0..ul.num_units())
                .flat_map(|u| ul.rank_slice(u, rank))
                .collect();
            let expect: Vec<usize> = global.range(rank).collect();
            assert_eq!(covered, expect, "rank {rank}");
        }
        // Degenerate asks collapse to the whole layout.
        assert_eq!(
            UnitLayout::for_prefix(&global, 32, 4, 1),
            UnitLayout::whole(&global)
        );
        assert_eq!(
            UnitLayout::for_prefix(&global, 0, 4, 3),
            UnitLayout::whole(&global)
        );
    }

    #[test]
    fn prop_unit_layout_covers_every_rank_range() {
        check("unit-layout-cover", 200, |g| {
            let n = g.usize_in(1, 6);
            let len = g.usize_in(0, 5_000);
            let units = g.usize_in(1, 12);
            let global = ShardLayout::by_ratios(len.max(1), &g.ratios(n));
            let ul = UnitLayout::split(&global, units);
            assert_eq!(ul.num_units(), units);
            for rank in 0..n {
                let sum: usize = (0..units)
                    .map(|u| ul.unit_layout(u).size(rank))
                    .sum();
                assert_eq!(sum, global.size(rank));
            }
        });
    }
}
