//! Event-driven execution simulation.
//!
//! * `engine` — generic multi-stream op-graph scheduler (CUDA-stream
//!   semantics: per-stream FIFO + cross-stream dependencies).
//! * `fsdp` — FSDP / gradient-accumulation schedules, including the
//!   Fig.-8 optimization ladder (FSDP-GA, LGA, +CO, +S, +O).
//! * `pipeline` — GPipe-style pipeline schedules for the baselines.
//! * `cephalo` — glue that evaluates a full Cephalo `Assignment`
//!   against a ground-truth oracle (the "actual" side of Fig. 10).

pub mod cephalo;
pub mod engine;
pub mod fsdp;
pub mod pipeline;

pub use engine::{Engine, Op, OpId, Stream, Timeline};
pub use fsdp::{simulate_iteration, FsdpWorkload, GaVariant, SimResult};
pub use pipeline::{simulate_pipeline, PipelineWorkload, StageSpec};
