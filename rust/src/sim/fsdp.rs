//! FSDP execution schedules (Fig. 4) and the gradient-accumulation
//! optimization ladder (Fig. 8): FSDP-GA -> LGA -> +CO -> +S -> +O.
//!
//! Each builder assembles an `Engine` op graph for one training
//! iteration and returns latency + per-GPU peak-memory estimates.

use super::engine::{Engine, OpId, Stream, Timeline};

/// Calibration constants for the un-optimized variants. The paper
/// reports LGA+CO ~= +22% over LGA, and S+O together a further ~11%
/// (§4.5); the split between S and O below reproduces that ladder.
///
/// Without compute-stream synchronization (§3.3), PyTorch schedules
/// multiple microbatches concurrently: allocator thrash + fragmentation
/// slow compute and can OOM below 50% nominal usage.
pub const NO_SYNC_COMPUTE_PENALTY: f64 = 1.06;
/// Without offloading, activation residency pressures the caching
/// allocator (more cudaMalloc/Free in steady state).
pub const NO_OFFLOAD_COMPUTE_PENALTY: f64 = 1.05;
/// Fragmentation multiplier on compute memory without synchronization.
pub const NO_SYNC_FRAGMENTATION: f64 = 1.9;

/// Inputs describing one iteration's work on every GPU.
#[derive(Debug, Clone)]
pub struct FsdpWorkload {
    /// FSDP units (transformer layers).
    pub units: usize,
    /// Per GPU: (microbatch size m_i, microbatch count l_i).
    pub micro: Vec<(usize, usize)>,
    /// Per GPU: latency of ONE fwd microbatch through ONE unit.
    pub fwd_micro: Vec<f64>,
    /// Per GPU: latency of ONE bwd (incl. recompute) microbatch.
    pub bwd_micro: Vec<f64>,
    /// Per unit: AllGather duration (uneven-adjusted where applicable).
    pub ag_unit: Vec<f64>,
    /// Per unit: ReduceScatter duration.
    pub rs_unit: Vec<f64>,
    /// Per GPU: PCIe transfer time of one microbatch's boundary
    /// activation (offload or prefetch direction).
    pub offload_micro: Vec<f64>,
}

impl FsdpWorkload {
    pub fn n_gpus(&self) -> usize {
        self.micro.len()
    }

    fn validate(&self) {
        let n = self.n_gpus();
        assert!(n > 0 && self.units > 0);
        assert_eq!(self.fwd_micro.len(), n);
        assert_eq!(self.bwd_micro.len(), n);
        assert_eq!(self.offload_micro.len(), n);
        assert_eq!(self.ag_unit.len(), self.units);
        assert_eq!(self.rs_unit.len(), self.units);
        assert!(self.micro.iter().all(|&(m, l)| m >= 1 && l >= 1));
    }
}

/// The Fig.-8 ladder switches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaVariant {
    /// Layered gradient accumulation (all microbatches per unit) vs
    /// FSDP's per-microbatch full passes.
    pub layered: bool,
    /// Communication/computation overlap (AllGather prefetch).
    pub comm_overlap: bool,
    /// Compute-stream synchronization (one microbatch at a time).
    pub compute_sync: bool,
    /// Asynchronous activation offload to CPU.
    pub offload: bool,
}

impl GaVariant {
    pub const FSDP_GA: GaVariant = GaVariant {
        layered: false,
        comm_overlap: true,
        compute_sync: false,
        offload: false,
    };
    pub const LGA: GaVariant = GaVariant {
        layered: true,
        comm_overlap: false,
        compute_sync: false,
        offload: false,
    };
    pub const LGA_CO: GaVariant = GaVariant {
        comm_overlap: true,
        ..Self::LGA
    };
    pub const LGA_CO_S: GaVariant = GaVariant {
        compute_sync: true,
        ..Self::LGA_CO
    };
    pub const LGA_CO_S_O: GaVariant = GaVariant {
        offload: true,
        ..Self::LGA_CO_S
    };

    /// Multiplier applied to per-microbatch compute time.
    pub fn compute_penalty(&self) -> f64 {
        let mut p = 1.0;
        if !self.compute_sync {
            p *= NO_SYNC_COMPUTE_PENALTY;
        }
        if !self.offload {
            p *= NO_OFFLOAD_COMPUTE_PENALTY;
        }
        p
    }
}

/// Result of simulating one iteration.
#[derive(Debug)]
pub struct SimResult {
    pub latency: f64,
    pub ag_count: usize,
    pub rs_count: usize,
    pub timeline: Timeline,
}

/// Build + run the schedule for one iteration under `variant`.
pub fn simulate_iteration(w: &FsdpWorkload, variant: GaVariant) -> SimResult {
    w.validate();
    if variant.layered {
        simulate_lga(w, variant)
    } else {
        simulate_fsdp_ga(w, variant)
    }
}

/// Layered gradient accumulation (Fig. 4 bottom): per unit, gather once,
/// run all microbatches, prefetching the next unit's AllGather.
fn simulate_lga(w: &FsdpWorkload, v: GaVariant) -> SimResult {
    let n = w.n_gpus();
    let pen = v.compute_penalty();
    let mut e = Engine::new();
    // Last compute op per device (across unit boundaries).
    let mut last_compute: Vec<Option<OpId>> = vec![None; n];
    // Last compute ops of the PREVIOUS unit on every device (for
    // non-overlapped AG issue).
    let mut prev_unit_tail: Vec<OpId> = Vec::new();
    // Forward activations' offload ops, needed as prefetch deps in bwd.
    let mut ag_count = 0usize;
    let mut rs_count = 0usize;

    // ---- forward ----
    let mut fwd_tails_per_unit: Vec<Vec<OpId>> = Vec::with_capacity(w.units);
    for u in 0..w.units {
        let deps: Vec<OpId> = if v.comm_overlap {
            Vec::new() // prefetched: only comm-stream order applies
        } else {
            prev_unit_tail.clone() // issued after previous unit computes
        };
        let ag = e.add(Stream::Comm, w.ag_unit[u], &deps, "AG");
        ag_count += 1;
        let mut tails = Vec::with_capacity(n);
        for d in 0..n {
            let (_, l) = w.micro[d];
            let mut last = last_compute[d];
            for _ in 0..l {
                let mut cdeps = vec![ag];
                if let Some(p) = last {
                    cdeps.push(p);
                }
                let c = e.add(
                    Stream::Compute(d),
                    w.fwd_micro[d] * pen,
                    &cdeps,
                    "fwd",
                );
                if v.offload {
                    // async offload of this microbatch's boundary act.
                    e.add(Stream::Offload(d), w.offload_micro[d], &[c],
                          "off");
                }
                last = Some(c);
            }
            last_compute[d] = last;
            tails.push(last.unwrap());
        }
        prev_unit_tail = tails.clone();
        fwd_tails_per_unit.push(tails);
    }

    // ---- backward ----
    // FSDP's BACKWARD_PRE prefetch: the AllGather for unit u-1 is issued
    // on the comm stream BEFORE unit u's ReduceScatter, so the RS never
    // blocks the next unit's parameter fetch. `pending_rs` holds the RS
    // of the previous unit until after this unit's AG is issued.
    let mut pending_rs: Option<(f64, Vec<OpId>)> = None;
    for u in (0..w.units).rev() {
        let deps: Vec<OpId> = if v.comm_overlap {
            Vec::new()
        } else {
            prev_unit_tail.clone()
        };
        let ag = e.add(Stream::Comm, w.ag_unit[u], &deps, "AG");
        ag_count += 1;
        if let Some((dur, deps)) = pending_rs.take() {
            e.add(Stream::Comm, dur, &deps, "RS");
            rs_count += 1;
        }
        let mut unit_tails = Vec::with_capacity(n);
        for d in 0..n {
            let (_, l) = w.micro[d];
            let mut last = last_compute[d];
            for _ in 0..l {
                let mut cdeps = vec![ag];
                if let Some(p) = last {
                    cdeps.push(p);
                }
                if v.offload {
                    // prefetch the checkpointed activation back from CPU
                    // before recompute (Fig. 11); async on offload
                    // stream, bwd compute depends on it.
                    let pf = e.add(
                        Stream::Offload(d),
                        w.offload_micro[d],
                        &[],
                        "pf",
                    );
                    cdeps.push(pf);
                }
                let c = e.add(
                    Stream::Compute(d),
                    w.bwd_micro[d] * pen,
                    &cdeps,
                    "bwd",
                );
                last = Some(c);
            }
            last_compute[d] = last;
            unit_tails.push(last.unwrap());
        }
        // ReduceScatter of the unit's accumulated gradient: needs every
        // device's last bwd microbatch of this unit; deferred past the
        // next unit's AG (prefetch priority).
        pending_rs = Some((w.rs_unit[u], unit_tails.clone()));
        rs_count += 0;
        prev_unit_tail = unit_tails;
    }
    if let Some((dur, deps)) = pending_rs.take() {
        e.add(Stream::Comm, dur, &deps, "RS");
        rs_count += 1;
    }

    let timeline = e.run();
    SimResult { latency: timeline.makespan(), ag_count, rs_count, timeline }
}

/// Traditional FSDP gradient accumulation (Fig. 4 top): a full
/// fwd+bwd pass per microbatch — AllGathers scale with l.
fn simulate_fsdp_ga(w: &FsdpWorkload, v: GaVariant) -> SimResult {
    let n = w.n_gpus();
    let pen = v.compute_penalty();
    let l_max = w.micro.iter().map(|&(_, l)| l).max().unwrap();
    let mut e = Engine::new();
    let mut last_compute: Vec<Option<OpId>> = vec![None; n];
    let mut ag_count = 0usize;
    let mut rs_count = 0usize;

    for j in 0..l_max {
        // forward pass of microbatch j
        for u in 0..w.units {
            let ag = e.add(Stream::Comm, w.ag_unit[u], &[], "AG");
            ag_count += 1;
            for d in 0..n {
                let (_, l) = w.micro[d];
                if j >= l {
                    continue;
                }
                let mut cdeps = vec![ag];
                if let Some(p) = last_compute[d] {
                    cdeps.push(p);
                }
                let c = e.add(
                    Stream::Compute(d),
                    w.fwd_micro[d] * pen,
                    &cdeps,
                    "fwd",
                );
                last_compute[d] = Some(c);
            }
        }
        // backward pass of microbatch j
        for u in (0..w.units).rev() {
            let ag = e.add(Stream::Comm, w.ag_unit[u], &[], "AG");
            ag_count += 1;
            let mut unit_tails = Vec::new();
            for d in 0..n {
                let (_, l) = w.micro[d];
                if j >= l {
                    continue;
                }
                let mut cdeps = vec![ag];
                if let Some(p) = last_compute[d] {
                    cdeps.push(p);
                }
                let c = e.add(
                    Stream::Compute(d),
                    w.bwd_micro[d] * pen,
                    &cdeps,
                    "bwd",
                );
                last_compute[d] = Some(c);
                unit_tails.push(c);
            }
            e.add(Stream::Comm, w.rs_unit[u], &unit_tails, "RS");
            rs_count += 1;
        }
    }
    let timeline = e.run();
    SimResult { latency: timeline.makespan(), ag_count, rs_count, timeline }
}

/// Per-GPU peak *compute* memory (bytes) under a variant, excluding the
/// training state (which the caller adds from the shard plan).
///
/// `mem_base(m)` is the fitted M_compute model; `act_bytes` the boundary
/// activation per sample per layer; `layers` the checkpoint count.
pub fn peak_compute_memory(
    m: usize,
    l: usize,
    mem_base: f64,
    act_bytes: f64,
    layers: usize,
    variant: GaVariant,
) -> f64 {
    let checkpoints = if variant.offload {
        // Double-buffered staging only.
        2.0 * act_bytes * m as f64
    } else if variant.layered {
        // All microbatches' boundary activations live until backward.
        act_bytes * (m * l * layers) as f64
    } else {
        // One microbatch's checkpoints across layers.
        act_bytes * (m * layers) as f64
    };
    let frag = if variant.compute_sync { 1.0 } else { NO_SYNC_FRAGMENTATION };
    (mem_base + checkpoints) * frag
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4 homogeneous GPUs, 3 units.
    fn workload(l: usize) -> FsdpWorkload {
        FsdpWorkload {
            units: 3,
            micro: vec![(2, l); 4],
            fwd_micro: vec![0.010; 4],
            bwd_micro: vec![0.030; 4],
            ag_unit: vec![0.008; 3],
            rs_unit: vec![0.008; 3],
            offload_micro: vec![0.002; 4],
        }
    }

    #[test]
    fn lga_allgather_count_is_per_unit_not_per_microbatch() {
        let w = workload(4);
        let lga = simulate_iteration(&w, GaVariant::LGA_CO_S_O);
        let ga = simulate_iteration(&w, GaVariant::FSDP_GA);
        assert_eq!(lga.ag_count, 2 * w.units);
        assert_eq!(ga.ag_count, 2 * w.units * 4);
        assert_eq!(lga.rs_count, w.units);
        assert_eq!(ga.rs_count, w.units * 4);
    }

    #[test]
    fn fig8_ladder_is_monotone() {
        // Comm-heavy regime: big collectives relative to compute.
        let w = FsdpWorkload {
            units: 8,
            micro: vec![(1, 16); 4],
            fwd_micro: vec![0.004; 4],
            bwd_micro: vec![0.012; 4],
            ag_unit: vec![0.050; 8],
            rs_unit: vec![0.050; 8],
            offload_micro: vec![0.001; 4],
        };
        let t = |v| simulate_iteration(&w, v).latency;
        let fsdp_ga = t(GaVariant::FSDP_GA);
        let lga = t(GaVariant::LGA);
        let lga_co = t(GaVariant::LGA_CO);
        let lga_co_s = t(GaVariant::LGA_CO_S);
        let full = t(GaVariant::LGA_CO_S_O);
        assert!(lga < fsdp_ga, "LGA {lga} !< FSDP-GA {fsdp_ga}");
        assert!(lga_co < lga, "CO should help: {lga_co} vs {lga}");
        assert!(lga_co_s <= lga_co);
        assert!(full <= lga_co_s);
        // In this comm-bound setup the LGA speedup is large (paper: 6x).
        assert!(
            fsdp_ga / lga > 3.0,
            "speedup too small: {}",
            fsdp_ga / lga
        );
    }

    #[test]
    fn overlap_hides_communication() {
        // Compute-dominant: with overlap, comm should vanish from the
        // critical path; without, it serializes between units.
        let w = FsdpWorkload {
            units: 4,
            micro: vec![(4, 4); 2],
            fwd_micro: vec![0.020; 2],
            bwd_micro: vec![0.060; 2],
            ag_unit: vec![0.010; 4],
            rs_unit: vec![0.010; 4],
            offload_micro: vec![0.001; 2],
        };
        let no = simulate_iteration(&w, GaVariant::LGA).latency;
        let yes = simulate_iteration(&w, GaVariant::LGA_CO).latency;
        let compute_only: f64 = (0.020 + 0.060) * 4.0 * 4.0
            * GaVariant::LGA_CO.compute_penalty();
        assert!(yes < no);
        // With overlap, latency is within ~15% of pure compute + the
        // first AG that cannot be hidden.
        assert!(yes < compute_only * 1.15 + 0.010);
    }

    #[test]
    fn heterogeneous_microbatch_counts() {
        // GPU 0 does 4 microbatches, GPU 1 does 1: the iteration waits
        // for the straggler only as long as eq. 2/3 dictate.
        let w = FsdpWorkload {
            units: 2,
            micro: vec![(1, 4), (1, 1)],
            fwd_micro: vec![0.010, 0.040],
            bwd_micro: vec![0.030, 0.120],
            ag_unit: vec![0.001; 2],
            rs_unit: vec![0.001; 2],
            offload_micro: vec![0.001; 2],
        };
        let r = simulate_iteration(&w, GaVariant::LGA_CO_S_O);
        // Both GPUs do 0.04 fwd + 0.12 bwd per unit; near-equal finish.
        let ideal = 2.0 * (0.040 + 0.120);
        assert!(r.latency >= ideal);
        assert!(r.latency < ideal * 1.2 + 0.01);
    }

    #[test]
    fn offload_stream_does_not_block_compute_when_fast() {
        let w = workload(4);
        let with = simulate_iteration(&w, GaVariant::LGA_CO_S_O).latency;
        let without = simulate_iteration(&w, GaVariant::LGA_CO_S).latency;
        // Offload is async; with fast PCIe it must not slow us more
        // than a few percent, and removing the no-offload penalty should
        // actually make it FASTER.
        assert!(with <= without * 1.02, "with={with} without={without}");
    }

    #[test]
    fn peak_memory_ladder() {
        let base = 2e9;
        let act = 4e6;
        let layers = 32;
        let m = 1;
        let l = 16;
        let fsdp_ga =
            peak_compute_memory(m, l, base, act, layers, GaVariant::FSDP_GA);
        let lga_no_o =
            peak_compute_memory(m, l, base, act, layers, GaVariant::LGA_CO_S);
        let full =
            peak_compute_memory(m, l, base, act, layers,
                                GaVariant::LGA_CO_S_O);
        // LGA without offload holds l x the checkpoints.
        assert!(lga_no_o > fsdp_ga);
        // Full variant holds only the double buffer and no fragmentation.
        assert!(full < fsdp_ga);
        assert!(full < lga_no_o / 2.0);
    }
}
