//! Event-driven multi-stream execution engine.
//!
//! Models the paper's execution substrate: every device owns a
//! *compute* stream, an *offload* (PCIe copy) stream, and the cluster
//! owns a shared *communication* channel on which NCCL collectives for
//! the data-parallel group serialize (Fig. 4's two rows, plus the
//! offload row of Fig. 11).
//!
//! Ops declare a stream, a duration and dependencies on earlier ops.
//! Within a stream, ops run in issue (program) order — exactly CUDA
//! stream semantics. An op starts at
//! `max(stream predecessor finish, max(dep finishes))`.

use std::collections::HashMap;

pub type OpId = usize;

/// Stream identity: per-device compute/offload, or the global comm
/// channel shared by the DP group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    Compute(usize),
    Offload(usize),
    /// Cluster-wide NCCL channel for DP-group collectives.
    Comm,
    /// Point-to-point link channel (pipeline parallel), keyed by
    /// (src_device, dst_device).
    Link(usize, usize),
}

#[derive(Debug, Clone)]
pub struct Op {
    pub stream: Stream,
    pub duration: f64,
    pub deps: Vec<OpId>,
    pub label: &'static str,
}

/// Completed timeline.
#[derive(Debug)]
pub struct Timeline {
    pub start: Vec<f64>,
    pub finish: Vec<f64>,
    labels: Vec<&'static str>,
    streams: Vec<Stream>,
}

impl Timeline {
    pub fn makespan(&self) -> f64 {
        self.finish.iter().copied().fold(0.0, f64::max)
    }

    /// Total busy time on a stream (for utilization reports).
    pub fn busy_time(&self, stream: Stream) -> f64 {
        self.streams
            .iter()
            .zip(self.start.iter().zip(&self.finish))
            .filter(|(s, _)| **s == stream)
            .map(|(_, (s, f))| f - s)
            .sum()
    }

    /// Count ops with a given label (e.g. "AG") — used to assert the
    /// LGA-reduces-AllGathers invariant.
    pub fn count_label(&self, label: &str) -> usize {
        self.labels.iter().filter(|l| **l == label).count()
    }

    pub fn finish_of(&self, id: OpId) -> f64 {
        self.finish[id]
    }
}

/// Builder + single-pass scheduler.
#[derive(Debug, Default)]
pub struct Engine {
    ops: Vec<Op>,
}

impl Engine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an op; `deps` must reference previously added ops.
    pub fn add(&mut self, stream: Stream, duration: f64, deps: &[OpId],
               label: &'static str) -> OpId {
        let id = self.ops.len();
        for &d in deps {
            assert!(d < id, "dependency {d} added after op {id}");
        }
        assert!(duration >= 0.0, "negative duration on '{label}'");
        self.ops.push(Op {
            stream,
            duration,
            deps: deps.to_vec(),
            label,
        });
        id
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Run the schedule: ops execute in issue order per stream, gated by
    /// dependency completion. Single forward pass suffices because deps
    /// point backwards.
    pub fn run(&self) -> Timeline {
        let n = self.ops.len();
        let mut start = vec![0f64; n];
        let mut finish = vec![0f64; n];
        let mut stream_tail: HashMap<Stream, f64> = HashMap::new();
        for (i, op) in self.ops.iter().enumerate() {
            let dep_ready = op
                .deps
                .iter()
                .map(|&d| finish[d])
                .fold(0.0, f64::max);
            let stream_ready =
                stream_tail.get(&op.stream).copied().unwrap_or(0.0);
            start[i] = dep_ready.max(stream_ready);
            finish[i] = start[i] + op.duration;
            stream_tail.insert(op.stream, finish[i]);
        }
        Timeline {
            start,
            finish,
            labels: self.ops.iter().map(|o| o.label).collect(),
            streams: self.ops.iter().map(|o| o.stream).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_ops_on_one_stream() {
        let mut e = Engine::new();
        let a = e.add(Stream::Compute(0), 1.0, &[], "a");
        let b = e.add(Stream::Compute(0), 2.0, &[], "b");
        let t = e.run();
        assert_eq!(t.finish_of(a), 1.0);
        assert_eq!(t.start[b], 1.0);
        assert_eq!(t.makespan(), 3.0);
    }

    #[test]
    fn parallel_streams_overlap() {
        let mut e = Engine::new();
        e.add(Stream::Compute(0), 3.0, &[], "c0");
        e.add(Stream::Compute(1), 2.0, &[], "c1");
        e.add(Stream::Comm, 2.5, &[], "ag");
        let t = e.run();
        assert_eq!(t.makespan(), 3.0);
    }

    #[test]
    fn dependencies_gate_start() {
        let mut e = Engine::new();
        let ag = e.add(Stream::Comm, 1.0, &[], "ag");
        let c = e.add(Stream::Compute(0), 2.0, &[ag], "fwd");
        let rs = e.add(Stream::Comm, 1.0, &[c], "rs");
        let t = e.run();
        assert_eq!(t.start[c], 1.0);
        assert_eq!(t.start[rs], 3.0);
        assert_eq!(t.makespan(), 4.0);
    }

    #[test]
    fn stream_order_even_without_deps() {
        // Comm ops serialize even if independent (NCCL channel).
        let mut e = Engine::new();
        let a = e.add(Stream::Comm, 1.0, &[], "ag1");
        let b = e.add(Stream::Comm, 1.0, &[], "ag2");
        let t = e.run();
        assert_eq!(t.start[b], t.finish[a]);
    }

    #[test]
    fn diamond_dependency() {
        let mut e = Engine::new();
        let root = e.add(Stream::Compute(0), 1.0, &[], "r");
        let left = e.add(Stream::Compute(1), 5.0, &[root], "l");
        let right = e.add(Stream::Compute(2), 2.0, &[root], "rg");
        let join = e.add(Stream::Compute(0), 1.0, &[left, right], "j");
        let t = e.run();
        assert_eq!(t.start[join], 6.0);
        assert_eq!(t.makespan(), 7.0);
    }

    #[test]
    fn busy_time_and_label_count() {
        let mut e = Engine::new();
        e.add(Stream::Comm, 1.0, &[], "AG");
        e.add(Stream::Comm, 2.0, &[], "AG");
        e.add(Stream::Compute(0), 4.0, &[], "fwd");
        let t = e.run();
        assert_eq!(t.busy_time(Stream::Comm), 3.0);
        assert_eq!(t.count_label("AG"), 2);
        assert_eq!(t.count_label("fwd"), 1);
    }

    #[test]
    #[should_panic]
    fn forward_dependency_rejected() {
        let mut e = Engine::new();
        e.add(Stream::Compute(0), 1.0, &[5], "bad");
    }

    #[test]
    fn link_streams_are_independent_channels() {
        let mut e = Engine::new();
        let a = e.add(Stream::Link(0, 1), 2.0, &[], "p2p01");
        let b = e.add(Stream::Link(1, 2), 2.0, &[], "p2p12");
        let c = e.add(Stream::Link(0, 1), 2.0, &[], "p2p01b");
        let t = e.run();
        assert_eq!(t.start[b], 0.0); // different link: parallel
        assert_eq!(t.start[c], t.finish[a]); // same link: serial
    }
}
