//! Pipeline-parallel schedule simulation (GPipe-style) for the
//! Megatron-Het and FlashFlex baselines.
//!
//! Stages process microbatches in order; activations travel between
//! consecutive stages over point-to-point links. The schedule is the
//! classic all-forward-then-all-backward GPipe wave; stage times already
//! fold in any tensor parallelism inside the stage (computed by the
//! baseline planners).

use super::engine::{Engine, OpId, Stream};

/// One pipeline stage.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Simulator device id (unique per stage per pipeline).
    pub device: usize,
    /// Forward latency of one microbatch through this stage.
    pub fwd_micro: f64,
    /// Backward latency of one microbatch.
    pub bwd_micro: f64,
}

#[derive(Debug, Clone)]
pub struct PipelineWorkload {
    pub stages: Vec<StageSpec>,
    pub microbatches: usize,
    /// Activation/gradient transfer time between adjacent stages per
    /// microbatch.
    pub p2p_time: f64,
}

/// Simulated latency of one pipeline iteration (fwd+bwd all
/// microbatches). Returns (latency, bubble_fraction).
pub fn simulate_pipeline(w: &PipelineWorkload) -> (f64, f64) {
    assert!(!w.stages.is_empty() && w.microbatches > 0);
    let s = w.stages.len();
    let l = w.microbatches;
    let mut e = Engine::new();

    // Forward wave.
    let mut fwd: Vec<Vec<OpId>> = vec![Vec::with_capacity(l); s];
    for j in 0..l {
        for (si, stage) in w.stages.iter().enumerate() {
            let mut deps: Vec<OpId> = Vec::new();
            if si > 0 {
                // activation hop from previous stage
                let link = e.add(
                    Stream::Link(w.stages[si - 1].device, stage.device),
                    w.p2p_time,
                    &[fwd[si - 1][j]],
                    "p2p",
                );
                deps.push(link);
            }
            let op =
                e.add(Stream::Compute(stage.device), stage.fwd_micro, &deps,
                      "F");
            fwd[si].push(op);
        }
    }
    // Backward wave (reverse stage order).
    let mut bwd: Vec<Vec<Option<OpId>>> = vec![vec![None; l]; s];
    for j in 0..l {
        for si in (0..s).rev() {
            let stage = &w.stages[si];
            let mut deps: Vec<OpId> = vec![fwd[si][j]];
            if si + 1 < s {
                let link = e.add(
                    Stream::Link(w.stages[si + 1].device, stage.device),
                    w.p2p_time,
                    &[bwd[si + 1][j].unwrap()],
                    "p2pg",
                );
                deps.push(link);
            }
            let op =
                e.add(Stream::Compute(stage.device), stage.bwd_micro, &deps,
                      "B");
            bwd[si][j] = Some(op);
        }
    }
    let t = e.run();
    let latency = t.makespan();

    // Bubble fraction: idle time on the busiest stage.
    let busiest: f64 = w
        .stages
        .iter()
        .map(|st| (st.fwd_micro + st.bwd_micro) * l as f64)
        .fold(0.0, f64::max);
    let bubble = 1.0 - busiest / latency;
    (latency, bubble.max(0.0))
}

/// Analytic GPipe bound for cross-checking the simulator:
/// (l + s - 1) * per-stage time when stages are balanced.
pub fn gpipe_bound(stage_fwd: f64, stage_bwd: f64, stages: usize, l: usize)
    -> f64 {
    (l + stages - 1) as f64 * (stage_fwd + stage_bwd)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced(stages: usize, l: usize) -> PipelineWorkload {
        PipelineWorkload {
            stages: (0..stages)
                .map(|i| StageSpec {
                    device: i,
                    fwd_micro: 0.010,
                    bwd_micro: 0.020,
                })
                .collect(),
            microbatches: l,
            p2p_time: 0.0,
        }
    }

    #[test]
    fn single_stage_is_serial_compute() {
        let w = balanced(1, 4);
        let (lat, bubble) = simulate_pipeline(&w);
        assert!((lat - 4.0 * 0.030).abs() < 1e-9);
        assert!(bubble < 1e-9);
    }

    #[test]
    fn balanced_pipeline_close_to_gpipe_bound() {
        let w = balanced(4, 8);
        let (lat, _) = simulate_pipeline(&w);
        let bound = gpipe_bound(0.010, 0.020, 4, 8);
        // GPipe-style waves: within ~20% of the analytic bound.
        assert!(lat <= bound * 1.2, "lat {lat} vs bound {bound}");
        assert!(lat >= 8.0 * 0.030); // can't beat serial best stage
    }

    #[test]
    fn bubble_shrinks_with_more_microbatches() {
        let (_, bubble_small) = simulate_pipeline(&balanced(4, 2));
        let (_, bubble_large) = simulate_pipeline(&balanced(4, 32));
        assert!(bubble_large < bubble_small);
        assert!(bubble_large < 0.2);
    }

    #[test]
    fn slowest_stage_dominates() {
        // Stage 1 is 3x slower: latency ~ l * slow_stage for large l.
        let mut w = balanced(3, 16);
        w.stages[1].fwd_micro = 0.030;
        w.stages[1].bwd_micro = 0.060;
        let (lat, _) = simulate_pipeline(&w);
        let slow_serial = 16.0 * 0.090;
        assert!(lat >= slow_serial);
        assert!(lat < slow_serial * 1.4);
    }

    #[test]
    fn p2p_adds_latency() {
        let w0 = balanced(4, 4);
        let mut w1 = balanced(4, 4);
        w1.p2p_time = 0.005;
        let (l0, _) = simulate_pipeline(&w0);
        let (l1, _) = simulate_pipeline(&w1);
        assert!(l1 > l0);
    }
}
