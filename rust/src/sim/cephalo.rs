//! Evaluate a full Cephalo `Assignment` on the event simulator — the
//! "actual" side of Fig. 10 (the optimizer's Eqs. 2/3 prediction is the
//! other side) and the engine behind every throughput table.

use super::fsdp::{peak_compute_memory, simulate_iteration, FsdpWorkload,
                  GaVariant};
use crate::memory::state_bytes;
use crate::model::TransformerSpec;
use crate::optimizer::Assignment;
use crate::perfmodel::{CollectiveModel, ComputeOracle};
use crate::sharding::ShardPlan;

/// PCIe host-link bandwidth for activation offload (bytes/s).
pub const PCIE_BYTES_PER_SEC: f64 = 16e9;

/// Result of simulating one full iteration of an assignment.
#[derive(Debug)]
pub struct IterStats {
    /// End-to-end iteration latency (seconds).
    pub latency: f64,
    /// Throughput in samples/s.
    pub throughput: f64,
    /// Per-GPU total memory (state + compute peak), bytes.
    pub per_gpu_mem: Vec<f64>,
    /// AllGather count for the iteration.
    pub ag_count: usize,
}

/// Simulate one training iteration of `asg` with ground-truth latencies
/// from `oracle`, under the full Cephalo execution variant
/// (LGA + CO + S + O) unless overridden.
pub fn simulate_assignment(
    model: &TransformerSpec,
    oracle: &dyn ComputeOracle,
    collective: &CollectiveModel,
    asg: &Assignment,
    variant: GaVariant,
) -> IterStats {
    let n = asg.per_gpu.len();
    assert_eq!(n, oracle.num_gpus());

    // Shard plan from the state ratios decides which units pay the
    // uneven collective overhead.
    let ratios: Vec<f64> = asg.per_gpu.iter().map(|g| g.state_ratio).collect();
    let plan = ShardPlan::plan(model.layers, model.params_per_layer(),
                               &ratios);
    let unit_bytes = model.params_per_layer() as f64 * 4.0;
    let ag_unit: Vec<f64> = plan
        .units
        .iter()
        .map(|u| {
            if u.uneven {
                collective.allgather_uneven(unit_bytes)
            } else {
                collective.allgather(unit_bytes)
            }
        })
        .collect();
    let rs_unit: Vec<f64> = plan
        .units
        .iter()
        .map(|u| {
            if u.uneven {
                collective.reduce_scatter_uneven(unit_bytes)
            } else {
                collective.reduce_scatter(unit_bytes)
            }
        })
        .collect();

    // Idle GPUs (m=0) still join collectives; give them zero compute.
    let micro: Vec<(usize, usize)> = asg
        .per_gpu
        .iter()
        .map(|g| (g.microbatch.max(1), g.num_micro.max(1)))
        .collect();
    let fwd: Vec<f64> = asg
        .per_gpu
        .iter()
        .enumerate()
        .map(|(i, g)| {
            if g.microbatch > 0 {
                oracle.fwd_latency(i, g.microbatch)
            } else {
                0.0
            }
        })
        .collect();
    let bwd: Vec<f64> = asg
        .per_gpu
        .iter()
        .enumerate()
        .map(|(i, g)| {
            if g.microbatch > 0 {
                oracle.bwd_latency(i, g.microbatch)
            } else {
                0.0
            }
        })
        .collect();
    let offload: Vec<f64> = asg
        .per_gpu
        .iter()
        .map(|g| {
            model.boundary_activation_bytes() * g.microbatch as f64
                / PCIE_BYTES_PER_SEC
        })
        .collect();

    let w = FsdpWorkload {
        units: model.layers,
        micro,
        fwd_micro: fwd,
        bwd_micro: bwd,
        ag_unit,
        rs_unit,
        offload_micro: offload,
    };
    let sim = simulate_iteration(&w, variant);

    let total_state = state_bytes(model.total_params() as f64);
    let per_gpu_mem: Vec<f64> = asg
        .per_gpu
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let base = if g.microbatch > 0 {
                oracle.compute_mem(i, g.microbatch)
            } else {
                0.0
            };
            let compute = peak_compute_memory(
                g.microbatch.max(1),
                g.num_micro.max(1),
                base,
                model.boundary_activation_bytes(),
                model.layers,
                variant,
            );
            g.state_ratio * total_state + compute
        })
        .collect();

    IterStats {
        latency: sim.latency,
        throughput: asg.global_batch() as f64 / sim.latency,
        per_gpu_mem,
        ag_count: sim.ag_count,
    }
}

/// Evaluate ANY planner's `PlanOutcome` on the shared event simulator.
///
/// Strategies that expose a full per-GPU `Assignment` (Cephalo, the
/// ablations, FSDP) are re-simulated under `variant`, so comparisons
/// across planners use ONE execution model instead of each planner's
/// optimistic internal estimate. Pipeline/TP strategies without an
/// assignment keep their own simulated latency (they already ran the
/// pipeline simulator); their `per_gpu_mem` is reported empty.
pub fn evaluate_outcome(
    model: &TransformerSpec,
    oracle: &dyn ComputeOracle,
    collective: &CollectiveModel,
    outcome: &crate::plan::PlanOutcome,
    variant: GaVariant,
) -> IterStats {
    match &outcome.assignment {
        Some(asg) => {
            simulate_assignment(model, oracle, collective, asg, variant)
        }
        None => IterStats {
            latency: outcome.iter_latency,
            throughput: outcome.throughput,
            per_gpu_mem: Vec::new(),
            ag_count: 0,
        },
    }
}

/// Model FLOPs throughput (TFLOP/s) of an iteration — Fig. 6's metric.
pub fn tflops(model: &TransformerSpec, batch: usize, latency: f64) -> f64 {
    model.iter_flops(batch, true) / latency / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::find_model;
    use crate::optimizer::DpOptimizer;
    use crate::perfmodel::{CollectiveModel, Profiler, SyntheticOracle};

    fn setup() -> (TransformerSpec, SyntheticOracle, CollectiveModel,
                   Assignment) {
        let cluster = Cluster::cluster_a();
        let model = find_model("BERT-Large").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &model, 42);
        let profile = Profiler::default().profile(&cluster, &model, &oracle);
        let (asg, _) = DpOptimizer::default().solve(&profile, 128).unwrap();
        let coll = CollectiveModel::from_cluster(&cluster);
        (model, oracle, coll, asg)
    }

    #[test]
    fn simulated_latency_close_to_prediction() {
        // Fig. 10: the performance model tracks the simulator within
        // ~10%.
        let (model, oracle, coll, asg) = setup();
        let stats = simulate_assignment(&model, &oracle, &coll, &asg,
                                        GaVariant::LGA_CO_S_O);
        let rel = (stats.latency - asg.iter_latency).abs()
            / stats.latency;
        assert!(
            rel < 0.15,
            "sim {} vs model {} (rel {rel})",
            stats.latency,
            asg.iter_latency
        );
    }

    #[test]
    fn throughput_positive_and_consistent() {
        let (model, oracle, coll, asg) = setup();
        let stats = simulate_assignment(&model, &oracle, &coll, &asg,
                                        GaVariant::LGA_CO_S_O);
        assert!(stats.throughput > 0.0);
        assert!((stats.throughput - 128.0 / stats.latency).abs() < 1e-9);
        assert_eq!(stats.per_gpu_mem.len(), 8);
        let _ = tflops(&model, 128, stats.latency);
    }

    #[test]
    fn memory_respects_capacity() {
        let (model, oracle, coll, asg) = setup();
        let stats = simulate_assignment(&model, &oracle, &coll, &asg,
                                        GaVariant::LGA_CO_S_O);
        let cluster = Cluster::cluster_a();
        for (mem, slot) in stats.per_gpu_mem.iter().zip(cluster.gpus()) {
            assert!(
                *mem <= slot.spec.mem_bytes(),
                "{}: {mem} > {}",
                slot.spec.name,
                slot.spec.mem_bytes()
            );
        }
    }

    #[test]
    fn evaluate_outcome_resimulates_assignments() {
        use crate::plan::{PlanContext, Planner};
        let cluster = Cluster::cluster_a();
        let model = find_model("BERT-Large").unwrap();
        let oracle = SyntheticOracle::new(&cluster, &model, 42);
        let profile = Profiler::default().profile(&cluster, &model, &oracle);
        let coll = CollectiveModel::from_cluster(&cluster);
        let ctx = PlanContext::new(&cluster, &model, &profile, &oracle, 128);
        // With an assignment: evaluation == simulate_assignment.
        let cephalo =
            crate::plan::CephaloPlanner::default().plan(&ctx).unwrap();
        let stats = evaluate_outcome(&model, &oracle, &coll, &cephalo,
                                     GaVariant::LGA_CO_S_O);
        assert_eq!(stats.latency, cephalo.iter_latency);
        assert_eq!(stats.per_gpu_mem.len(), 8);
        // Without one (Whale): the outcome's own numbers pass through.
        let whale = crate::baselines::whale::Whale.plan(&ctx).unwrap();
        assert!(whale.assignment.is_none());
        let stats = evaluate_outcome(&model, &oracle, &coll, &whale,
                                     GaVariant::LGA_CO_S_O);
        assert_eq!(stats.latency, whale.iter_latency);
        assert!(stats.per_gpu_mem.is_empty());
    }

    #[test]
    fn ag_count_is_two_per_unit() {
        let (model, oracle, coll, asg) = setup();
        let stats = simulate_assignment(&model, &oracle, &coll, &asg,
                                        GaVariant::LGA_CO_S_O);
        assert_eq!(stats.ag_count, 2 * model.layers);
    }
}
