//! TOML-subset configuration parser.
//!
//! Cephalo's launcher reads cluster/model/training configs from `.toml`
//! files (see `configs/`). The offline build has no `toml`/`serde`, so we
//! parse the subset the configs actually use:
//!
//! * `[section]` and `[section.sub]` headers
//! * `[[array-of-tables]]` headers (e.g. repeated `[[node]]` blocks)
//! * `key = value` with string / integer / float / bool / array values
//! * `#` comments, blank lines
//!
//! Values keep their section path as `section.sub.key`; array-of-table
//! instances are indexed `section[i].key`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// Parsed config: flat map from dotted path to value, plus the list of
/// array-of-table instance counts for iteration.
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
    pub table_counts: BTreeMap<String, usize>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[") {
                let name = name.strip_suffix("]]").ok_or(ConfigError {
                    line: lineno,
                    msg: "unterminated [[table]]".into(),
                })?;
                let count =
                    cfg.table_counts.entry(name.to_string()).or_insert(0);
                section = format!("{name}[{count}]");
                *count += 1;
            } else if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or(ConfigError {
                    line: lineno,
                    msg: "unterminated [section]".into(),
                })?;
                section = name.to_string();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(ConfigError {
                        line: lineno,
                        msg: "empty key".into(),
                    });
                }
                let val = parse_value(line[eq + 1..].trim(), lineno)?;
                let path = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                cfg.values.insert(path, val);
            } else {
                return Err(ConfigError {
                    line: lineno,
                    msg: format!("cannot parse: '{line}'"),
                });
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.values.get(path)
    }

    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    pub fn usize(&self, path: &str) -> Option<usize> {
        self.get(path).and_then(Value::as_usize)
    }

    pub fn f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_f64)
    }

    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// Number of `[[name]]` instances.
    pub fn table_count(&self, name: &str) -> usize {
        self.table_counts.get(name).copied().unwrap_or(0)
    }

    /// Required-field accessors with descriptive errors.
    pub fn require_usize(&self, path: &str) -> Result<usize, ConfigError> {
        self.usize(path).ok_or(ConfigError {
            line: 0,
            msg: format!("missing/invalid integer field '{path}'"),
        })
    }

    pub fn require_f64(&self, path: &str) -> Result<f64, ConfigError> {
        self.f64(path).ok_or(ConfigError {
            line: 0,
            msg: format!("missing/invalid float field '{path}'"),
        })
    }

    pub fn require_str(&self, path: &str) -> Result<&str, ConfigError> {
        self.str(path).ok_or(ConfigError {
            line: 0,
            msg: format!("missing/invalid string field '{path}'"),
        })
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<Value, ConfigError> {
    let t = text.trim();
    if t.is_empty() {
        return Err(ConfigError { line, msg: "empty value".into() });
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or(ConfigError {
            line,
            msg: "unterminated string".into(),
        })?;
        return Ok(Value::Str(inner.replace("\\n", "\n").replace("\\\"", "\"")));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or(ConfigError {
            line,
            msg: "unterminated array".into(),
        })?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for piece in split_top_level(inner) {
                items.push(parse_value(piece.trim(), line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ConfigError { line, msg: format!("cannot parse value '{t}'") })
}

/// Split an array body on commas not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# training config
name = "cephalo-demo"

[model]
d_model = 256
layers = 4         # identical transformer layers
lr = 3.0e-4
use_pallas = true

[cluster]
inter_bw_gbps = 50.0

[[node]]
gpus = ["L4", "L4", "A6000", "P40"]
intra_bw_gbps = 64.0

[[node]]
gpus = ["P40", "P40", "P100", "P100"]
intra_bw_gbps = 64.0
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name"), Some("cephalo-demo"));
        assert_eq!(c.usize("model.d_model"), Some(256));
        assert_eq!(c.usize("model.layers"), Some(4));
        assert!((c.f64("model.lr").unwrap() - 3.0e-4).abs() < 1e-12);
        assert_eq!(c.bool("model.use_pallas"), Some(true));
        assert_eq!(c.f64("cluster.inter_bw_gbps"), Some(50.0));
    }

    #[test]
    fn array_of_tables() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.table_count("node"), 2);
        let gpus0 = c.get("node[0].gpus").unwrap().as_array().unwrap();
        assert_eq!(gpus0.len(), 4);
        assert_eq!(gpus0[2].as_str(), Some("A6000"));
        assert_eq!(c.f64("node[1].intra_bw_gbps"), Some(64.0));
    }

    #[test]
    fn comments_and_strings() {
        let c = Config::parse("s = \"a # not comment\" # real comment")
            .unwrap();
        assert_eq!(c.str("s"), Some("a # not comment"));
    }

    #[test]
    fn nested_arrays() {
        let c = Config::parse("m = [[1, 2], [3, 4]]").unwrap();
        let outer = c.get("m").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Config::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Config::parse("x = \"unterminated").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn int_vs_float() {
        let c = Config::parse("a = 3\nb = 3.0").unwrap();
        assert_eq!(c.get("a"), Some(&Value::Int(3)));
        assert_eq!(c.get("b"), Some(&Value::Float(3.0)));
        assert_eq!(c.f64("a"), Some(3.0)); // ints coerce to f64
    }

    #[test]
    fn require_errors_are_descriptive() {
        let c = Config::parse("a = 1").unwrap();
        let e = c.require_usize("missing.key").unwrap_err();
        assert!(e.msg.contains("missing.key"));
        assert!(c.require_usize("a").is_ok());
    }
}
