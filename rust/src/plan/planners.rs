//! `Planner` adapters for the Cephalo DP solver and the §4.4 ablation
//! variants. (The five baseline systems implement the trait in their
//! own modules under `baselines::`.)

use std::time::Instant;

use super::{PlanContext, PlanDiagnostics, PlanOutcome, Planner};
use crate::optimizer::{ablations, Assignment, DpOptimizer, PlanError};
use crate::perfmodel::CollectiveModel;
use crate::sim::cephalo::simulate_assignment;
use crate::sim::GaVariant;

/// The full Cephalo system: DP compute division + greedy state
/// partition, evaluated on the event simulator under the complete
/// gradient-accumulation ladder (LGA + CO + S + O) — the same numbers
/// the paper's tables report for "Cephalo".
#[derive(Debug, Clone)]
pub struct CephaloPlanner {
    pub opts: DpOptimizer,
    /// Evaluate the solved assignment on the event simulator (default).
    /// When false the outcome carries the optimizer's Eqs.-2/3
    /// prediction instead — the Fig.-10 "predicted" side.
    pub simulate: bool,
    pub variant: GaVariant,
}

impl Default for CephaloPlanner {
    fn default() -> Self {
        Self {
            opts: DpOptimizer::default(),
            simulate: true,
            variant: GaVariant::LGA_CO_S_O,
        }
    }
}

impl Planner for CephaloPlanner {
    fn name(&self) -> &'static str {
        "Cephalo"
    }

    fn cache_signature(&self) -> String {
        format!(
            "Cephalo/g={}/mm={}/res={}/sim={}/{:?}",
            self.opts.granularity,
            self.opts.max_microbatch,
            self.opts.residency.label(),
            self.simulate,
            self.variant
        )
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let (asg, stats) = self
            .opts
            .solve(ctx.profile, ctx.batch)
            .map_err(|e| e.tagged(self.name()))?;
        let (iter_latency, throughput) = if self.simulate {
            let collective = CollectiveModel::from_cluster(ctx.cluster);
            let sim = simulate_assignment(
                ctx.model,
                ctx.oracle,
                &collective,
                &asg,
                self.variant,
            );
            (sim.latency, sim.throughput)
        } else {
            (asg.iter_latency, asg.throughput())
        };
        let batches: Vec<usize> =
            asg.per_gpu.iter().map(|g| g.batch()).collect();
        Ok(PlanOutcome {
            planner: self.name().into(),
            iter_latency,
            throughput,
            config: format!("b={batches:?}"),
            assignment: Some(asg),
            diagnostics: PlanDiagnostics {
                solve_seconds: t0.elapsed().as_secs_f64(),
                states_visited: stats.states_visited,
                transitions: stats.transitions,
                candidates: 0,
                cache_hit: false,
            },
        })
    }
}

/// Shared tail for the ablation adapters: wrap a solved `Assignment`
/// into an outcome carrying the Eqs.-2/3 prediction.
fn ablation_outcome(
    name: &'static str,
    config: String,
    asg: Assignment,
    t0: Instant,
) -> PlanOutcome {
    PlanOutcome {
        planner: name.into(),
        iter_latency: asg.iter_latency,
        throughput: asg.throughput(),
        config,
        assignment: Some(asg),
        diagnostics: PlanDiagnostics {
            solve_seconds: t0.elapsed().as_secs_f64(),
            ..Default::default()
        },
    }
}

/// Cephalo-CB (§4.4): compute balancing only — speed-proportional
/// batches, no accumulation, EVEN training state.
pub struct CephaloCb;

impl Planner for CephaloCb {
    fn name(&self) -> &'static str {
        "Cephalo-CB"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let asg = ablations::compute_balanced_only(ctx.profile, ctx.batch)
            .map_err(|e| e.tagged(self.name()))?;
        Ok(ablation_outcome(
            self.name(),
            "speed-proportional b_i, even state".into(),
            asg,
            t0,
        ))
    }
}

/// Cephalo-MB (§4.4): memory balancing only — even batch, microbatch 1,
/// UNEVEN state via the greedy partitioner.
pub struct CephaloMb;

impl Planner for CephaloMb {
    fn name(&self) -> &'static str {
        "Cephalo-MB"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let asg = ablations::memory_balanced_only(ctx.profile, ctx.batch)
            .map_err(|e| e.tagged(self.name()))?;
        Ok(ablation_outcome(
            self.name(),
            "even b_i, m=1, greedy state".into(),
            asg,
            t0,
        ))
    }
}

/// The even-everything FSDP plan on Cephalo's own memory model — the
/// Fig.-7 "FSDP" ablation row (distinct from `baselines::fsdp`, which
/// models the PyTorch allocator).
pub struct FsdpEven;

impl Planner for FsdpEven {
    fn name(&self) -> &'static str {
        "FSDP-even"
    }

    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError> {
        let t0 = Instant::now();
        let asg = ablations::fsdp_even(ctx.profile, ctx.batch)
            .map_err(|e| e.tagged(self.name()))?;
        Ok(ablation_outcome(
            self.name(),
            "even b_i, no accumulation, even state".into(),
            asg,
            t0,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::Workload;

    #[test]
    fn cephalo_adapter_matches_direct_solve_byte_for_byte() {
        let w =
            Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
                .unwrap();
        let direct = DpOptimizer::default().solve(&w.profile, 128).unwrap().0;
        let out = CephaloPlanner::default().plan(&w.ctx(128)).unwrap();
        assert_eq!(out.assignment.as_ref(), Some(&direct));
        assert!(out.diagnostics.transitions > 0);
        assert!(!out.diagnostics.cache_hit);
    }

    #[test]
    fn predicted_vs_simulated_within_model_error() {
        // Fig. 10: prediction tracks the simulator within ~15%.
        let w =
            Workload::prepare(Cluster::cluster_a(), "BERT-Large", 42)
                .unwrap();
        let sim = CephaloPlanner::default().plan(&w.ctx(128)).unwrap();
        let pred = CephaloPlanner {
            simulate: false,
            ..Default::default()
        }
        .plan(&w.ctx(128))
        .unwrap();
        let rel =
            (sim.iter_latency - pred.iter_latency).abs() / sim.iter_latency;
        assert!(rel < 0.15, "sim {} pred {}", sim.iter_latency,
                pred.iter_latency);
    }

    #[test]
    fn ablation_adapters_tag_their_errors() {
        let w = Workload::prepare(Cluster::cluster_a(), "GPT 2.7B", 42)
            .unwrap();
        let err = CephaloCb.plan(&w.ctx(256)).unwrap_err();
        assert_eq!(err.planner(), Some("Cephalo-CB"));
        assert!(err.is_oom(), "{err}");
        let ok = CephaloMb.plan(&w.ctx(256)).unwrap();
        assert_eq!(ok.assignment.unwrap().global_batch(), 256);
    }
}
