//! Parallel (planner x batch) sweeps over scoped threads.
//!
//! Every solve is independent — same read-only context, different
//! (planner, batch) — so the grid is embarrassingly parallel. Workers
//! pull tasks off a shared atomic counter (work stealing), which keeps
//! cores busy even though solve times vary by 100x between a baseline's
//! config search and the 64-GPU DP table.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::{PlanCache, PlanContext, PlanOutcome, Planner};
use crate::optimizer::PlanError;

/// One cell of a sweep grid.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// `Planner::name` of the planner that produced this cell.
    pub planner: String,
    pub batch: usize,
    pub result: Result<PlanOutcome, PlanError>,
}

impl SweepCell {
    /// Throughput for feasible cells, `None` for planning failures.
    pub fn throughput(&self) -> Option<f64> {
        self.result.as_ref().ok().map(|o| o.throughput)
    }
}

/// Solve every (planner, batch) pair in parallel and return the cells
/// in deterministic planner-major order:
/// `cells[p * batches.len() + b]` is `planners[p]` at `batches[b]`.
///
/// `base.batch` is ignored (overridden per cell). With a cache, cells
/// are resolved through [`PlanCache::get_or_plan`], so repeated sweeps
/// — e.g. elastic re-plans over recurring memberships — skip solved
/// work.
pub fn sweep(
    base: &PlanContext<'_>,
    planners: &[Arc<dyn Planner>],
    batches: &[usize],
    cache: Option<&PlanCache>,
) -> Vec<SweepCell> {
    let tasks: Vec<(usize, usize)> = (0..planners.len())
        .flat_map(|p| batches.iter().map(move |&b| (p, b)))
        .collect();
    if tasks.is_empty() {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(tasks.len());
    let next = AtomicUsize::new(0);
    let cells: Vec<Mutex<Option<SweepCell>>> =
        tasks.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= tasks.len() {
                    break;
                }
                let (p, batch) = tasks[i];
                let ctx = PlanContext { batch, ..*base };
                let planner = &*planners[p];
                let result = match cache {
                    Some(c) => c.get_or_plan(planner, &ctx),
                    None => planner.plan(&ctx),
                };
                *cells[i].lock().unwrap() = Some(SweepCell {
                    planner: planner.name().into(),
                    batch,
                    result,
                });
            });
        }
    });

    cells
        .into_iter()
        .map(|c| {
            c.into_inner()
                .unwrap()
                .expect("sweep worker left a cell unfilled")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Workload;
    use crate::plan::PlannerRegistry;
    use crate::testkit::tiny_cluster;

    #[test]
    fn parallel_sweep_matches_serial_solves() {
        let w = Workload::prepare(tiny_cluster(), "BERT-Large", 42)
            .unwrap();
        let reg = PlannerRegistry::with_defaults();
        let batches = [4usize, 8];
        let cells = sweep(&w.ctx(0), reg.planners(), &batches, None);
        assert_eq!(cells.len(), reg.len() * batches.len());
        for (p, planner) in reg.planners().iter().enumerate() {
            for (b, &batch) in batches.iter().enumerate() {
                let cell = &cells[p * batches.len() + b];
                assert_eq!(cell.planner, planner.name());
                assert_eq!(cell.batch, batch);
                let serial = planner.plan(&w.ctx(batch));
                match (&cell.result, &serial) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a.throughput, b.throughput);
                        assert_eq!(a.config, b.config);
                    }
                    (Err(a), Err(b)) => assert_eq!(a, b),
                    (a, b) => panic!(
                        "{} @{batch}: parallel {a:?} vs serial {b:?}",
                        planner.name()
                    ),
                }
            }
        }
    }

    #[test]
    fn sweep_through_cache_records_misses_then_hits() {
        let w = Workload::prepare(tiny_cluster(), "BERT-Large", 42)
            .unwrap();
        let reg = PlannerRegistry::with_defaults();
        let cache = PlanCache::new();
        let n = reg.len() as u64;
        let first = sweep(&w.ctx(0), reg.planners(), &[8], Some(&cache));
        assert_eq!(cache.misses(), n);
        assert_eq!(cache.hits(), 0);
        let second = sweep(&w.ctx(0), reg.planners(), &[8], Some(&cache));
        assert_eq!(cache.misses(), n);
        assert_eq!(cache.hits(), n);
        for (a, b) in first.iter().zip(&second) {
            match (&a.result, &b.result) {
                (Ok(x), Ok(y)) => {
                    assert!(!x.diagnostics.cache_hit);
                    assert!(y.diagnostics.cache_hit);
                    assert_eq!(x.throughput, y.throughput);
                }
                (Err(x), Err(y)) => assert_eq!(x, y),
                (x, y) => panic!("{}: {x:?} vs {y:?}", a.planner),
            }
        }
    }

    #[test]
    fn empty_grid_is_fine() {
        let w = Workload::prepare(tiny_cluster(), "BERT-Large", 42)
            .unwrap();
        let reg = PlannerRegistry::with_defaults();
        assert!(sweep(&w.ctx(0), reg.planners(), &[], None).is_empty());
        assert!(sweep(&w.ctx(0), &[], &[8], None).is_empty());
    }
}
