//! Content-addressed plan memoization, LRU-bounded and persistable.
//!
//! A plan depends only on (cluster + fitted profile, model, batch,
//! planner): all of it deterministic, so outcomes — including failures,
//! OOM is a property of the inputs — can be cached. Keys fingerprint
//! the cluster topology and the fitted `ClusterPerfProfile` contents
//! (the profile is itself a deterministic function of the oracle seed,
//! so it proxies the oracle too). The elastic coordinator keeps one
//! cache across membership changes: returning to a previously seen
//! membership makes re-planning near-free.
//!
//! Live sessions over long traces accumulate one entry per
//! (membership, batch), so the cache is bounded: least-recently-USED
//! entries are evicted once `capacity` is exceeded (default
//! [`DEFAULT_CAPACITY`]; 0 = unbounded). Entries persist to JSON
//! ([`PlanCache::save`] / [`PlanCache::load`]) so a RESUMED session
//! starts with its recurring-membership plans warm instead of
//! re-solving the DP — successes AND clean failure verdicts (OOM,
//! infeasible): a reloaded cache must not re-run configurations it
//! already knows cannot fit. Internal errors are never persisted (they
//! describe the solver, not the inputs).

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::{PlanContext, PlanDiagnostics, PlanOutcome, Planner};
use crate::cluster::Cluster;
use crate::optimizer::{Assignment, GpuAssign, PlanError};
use crate::perfmodel::ClusterPerfProfile;
use crate::util::json::Json;

use crate::util::fnv1a;

/// Default LRU bound: comfortably above any observed live-trace
/// working set (memberships × batches), small enough that eviction
/// scans stay trivial.
pub const DEFAULT_CAPACITY: usize = 64;

/// Content fingerprint of everything a planner reads about the cluster:
/// the topology (GPU specs, per-node grouping, bandwidths) and the
/// fitted per-GPU latency/memory models + collective constants. Uses
/// the canonical `Debug` rendering, which covers every field —
/// including the profiled latency points fitted from the noisy oracle,
/// so different oracle seeds fingerprint differently.
pub fn fingerprint(cluster: &Cluster, profile: &ClusterPerfProfile) -> u64 {
    let c = fnv1a(format!("{cluster:?}").as_bytes());
    let p = fnv1a(format!("{profile:?}").as_bytes());
    c ^ p.rotate_left(17)
}

/// Cache key: (cluster/profile fingerprint, model, batch, planner
/// cache signature — name PLUS configuration, so two differently
/// configured planners sharing a name never share entries).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub cluster_fingerprint: u64,
    pub model: String,
    pub batch: usize,
    pub planner: String,
}

impl PlanKey {
    /// Key for `ctx` + a planner's `cache_signature()`. Uses the
    /// context's precomputed fingerprint — no profile re-render.
    pub fn for_ctx(ctx: &PlanContext<'_>, signature: &str) -> PlanKey {
        PlanKey {
            cluster_fingerprint: ctx.cluster_fingerprint,
            model: ctx.model.name.clone(),
            batch: ctx.batch,
            planner: signature.to_string(),
        }
    }
}

struct Entry {
    result: Result<PlanOutcome, PlanError>,
    /// Recency stamp (monotone ticks); smallest = evict first.
    last_used: u64,
}

/// Thread-safe memoization of plan results (hits from `sweep` workers
/// and the elastic coordinator are counted).
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Entry>>,
    tick: AtomicU64,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    stale_evictions: AtomicU64,
}

impl PlanCache {
    /// An empty cache with the default LRU bound.
    pub fn new() -> PlanCache {
        PlanCache::with_capacity(DEFAULT_CAPACITY)
    }

    /// An empty cache bounded to `capacity` entries (0 = unbounded).
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            map: Mutex::new(HashMap::new()),
            tick: AtomicU64::new(0),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            stale_evictions: AtomicU64::new(0),
        }
    }

    fn stamp(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    /// Serve from cache or run the planner and remember the result
    /// (successes AND clean failures). Cache hits are marked in
    /// `diagnostics.cache_hit` with `solve_seconds` zeroed. The solve
    /// runs outside the lock, so concurrent misses on the same key may
    /// both solve (last insert wins — results are deterministic, so
    /// both are identical).
    pub fn get_or_plan(
        &self,
        planner: &dyn Planner,
        ctx: &PlanContext<'_>,
    ) -> Result<PlanOutcome, PlanError> {
        let key = PlanKey::for_ctx(ctx, &planner.cache_signature());
        if let Some(found) = self.map.lock().unwrap().get_mut(&key) {
            found.last_used = self.stamp();
            self.hits.fetch_add(1, Ordering::Relaxed);
            return match &found.result {
                Ok(outcome) => {
                    let mut out = outcome.clone();
                    out.diagnostics.cache_hit = true;
                    out.diagnostics.solve_seconds = 0.0;
                    Ok(out)
                }
                Err(e) => Err(e.clone()),
            };
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let result = planner.plan(ctx);
        let mut map = self.map.lock().unwrap();
        map.insert(
            key,
            Entry { result: result.clone(), last_used: self.stamp() },
        );
        if self.capacity > 0 {
            while map.len() > self.capacity {
                let Some(oldest) = map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone())
                else {
                    break;
                };
                map.remove(&oldest);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        result
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped by the LRU bound so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Entries dropped by [`PlanCache::retain_fingerprints`] so far —
    /// counted apart from LRU eviction so logs can attribute WHY an
    /// entry left the cache (recency pressure vs. unreachable cluster).
    pub fn stale_evictions(&self) -> u64 {
        self.stale_evictions.load(Ordering::Relaxed)
    }

    /// Age-out: drop every entry whose cluster fingerprint is not in
    /// `live` — the memberships the session's remaining trace window
    /// can still produce (dead ranks are never re-admitted, so plans
    /// for larger memberships can never be served again). Returns the
    /// number dropped; they count as stale evictions, not LRU ones.
    pub fn retain_fingerprints(&self, live: &[u64]) -> usize {
        let mut map = self.map.lock().unwrap();
        let before = map.len();
        map.retain(|k, _| live.contains(&k.cluster_fingerprint));
        let dropped = before - map.len();
        self.stale_evictions
            .fetch_add(dropped as u64, Ordering::Relaxed);
        dropped
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    /// Persist entries as JSON: every success, plus clean FAILURE
    /// verdicts (OOM / infeasible — properties of the inputs, so a
    /// reloaded cache must not re-solve known-infeasible configs).
    /// Internal errors are skipped. Entries are sorted for
    /// deterministic output.
    pub fn save(&self, path: &Path) -> crate::util::error::Result<()> {
        use std::collections::BTreeMap;
        let map = self.map.lock().unwrap();
        let mut rows: Vec<(&PlanKey, &Result<PlanOutcome, PlanError>)> =
            map.iter()
                .filter(|(_, e)| match &e.result {
                    Ok(_) => true,
                    Err(err) => is_clean_failure(err),
                })
                .map(|(k, e)| (k, &e.result))
                .collect();
        rows.sort_by(|(a, _), (b, _)| {
            (&a.model, a.batch, &a.planner, a.cluster_fingerprint).cmp(&(
                &b.model,
                b.batch,
                &b.planner,
                b.cluster_fingerprint,
            ))
        });
        let entries: Vec<Json> = rows
            .into_iter()
            .map(|(k, res)| {
                let mut e = BTreeMap::new();
                e.insert(
                    "fingerprint".into(),
                    Json::Str(format!("{:#x}", k.cluster_fingerprint)),
                );
                e.insert("model".into(), Json::Str(k.model.clone()));
                e.insert("batch".into(), Json::Num(k.batch as f64));
                e.insert("planner".into(), Json::Str(k.planner.clone()));
                match res {
                    Ok(o) => {
                        e.insert("outcome".into(), outcome_to_json(o));
                    }
                    Err(err) => {
                        e.insert("error".into(), error_to_json(err));
                    }
                }
                Json::Obj(e)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("version".into(), Json::Num(2.0));
        root.insert("capacity".into(), Json::Num(self.capacity as f64));
        root.insert("entries".into(), Json::Arr(entries));
        // Write-then-rename so a crash mid-save can never leave a
        // truncated file behind (the cache is an optimization; a
        // corrupt one must not brick future sessions).
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, Json::Obj(root).render())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a cache previously written by [`PlanCache::save`]. Loaded
    /// entries count as neither hits nor misses until touched.
    pub fn load(path: &Path) -> crate::util::error::Result<PlanCache> {
        use crate::util::error::anyhow;
        let text = std::fs::read_to_string(path)?;
        let root = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("plan cache file missing version"))?;
        // v1 carried successes only; v2 adds persisted failure verdicts.
        if version != 1 && version != 2 {
            return Err(anyhow!("unsupported plan cache version {version}"));
        }
        let capacity = root
            .get("capacity")
            .and_then(Json::as_usize)
            .unwrap_or(DEFAULT_CAPACITY);
        let cache = PlanCache::with_capacity(capacity);
        {
            let mut map = cache.map.lock().unwrap();
            for e in root
                .get("entries")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
            {
                let fp_text = e
                    .get("fingerprint")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing fingerprint"))?;
                let fp = u64::from_str_radix(
                    fp_text.trim_start_matches("0x"),
                    16,
                )
                .map_err(|_| anyhow!("bad fingerprint '{fp_text}'"))?;
                let key = PlanKey {
                    cluster_fingerprint: fp,
                    model: e
                        .get("model")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("entry missing model"))?
                        .to_string(),
                    batch: e
                        .get("batch")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("entry missing batch"))?,
                    planner: e
                        .get("planner")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("entry missing planner"))?
                        .to_string(),
                };
                let result = match (e.get("outcome"), e.get("error")) {
                    (Some(o), _) => Ok(outcome_from_json(o)?),
                    (None, Some(err)) => Err(error_from_json(err)?),
                    (None, None) => {
                        return Err(anyhow!(
                            "entry carries neither outcome nor error"
                        ))
                    }
                };
                let stamp = cache.tick.fetch_add(1, Ordering::Relaxed);
                map.insert(key, Entry { result, last_used: stamp });
            }
        }
        Ok(cache)
    }
}

/// Failures worth persisting: verdicts about the INPUTS (OOM,
/// infeasible), possibly planner-tagged. Internal errors describe the
/// solver and must be re-derived fresh.
fn is_clean_failure(e: &PlanError) -> bool {
    matches!(
        e.untagged(),
        PlanError::OutOfMemory { .. } | PlanError::Infeasible(_)
    )
}

/// Finite numbers round-trip as JSON numbers; the DP's sentinel
/// infinities render as `null` and decode back to infinity.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn error_to_json(e: &PlanError) -> Json {
    use std::collections::BTreeMap;
    let mut m = BTreeMap::new();
    match e {
        PlanError::OutOfMemory { gpu, needed, capacity, config } => {
            m.insert("kind".into(), Json::Str("oom".into()));
            m.insert("gpu".into(), Json::Num(*gpu as f64));
            m.insert("needed".into(), num_or_null(*needed));
            m.insert("capacity".into(), num_or_null(*capacity));
            if let Some(c) = config {
                m.insert("config".into(), Json::Str(c.clone()));
            }
        }
        PlanError::Infeasible(s) => {
            m.insert("kind".into(), Json::Str("infeasible".into()));
            m.insert("message".into(), Json::Str(s.clone()));
        }
        PlanError::Internal(s) => {
            m.insert("kind".into(), Json::Str("internal".into()));
            m.insert("message".into(), Json::Str(s.clone()));
        }
        PlanError::Tagged { planner, inner } => {
            m.insert("kind".into(), Json::Str("tagged".into()));
            m.insert("planner".into(), Json::Str(planner.clone()));
            m.insert("inner".into(), error_to_json(inner));
        }
    }
    Json::Obj(m)
}

fn error_from_json(j: &Json) -> crate::util::error::Result<PlanError> {
    use crate::util::error::anyhow;
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("error entry missing kind"))?;
    let msg = |j: &Json| {
        j.get("message")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("error entry missing message"))
    };
    Ok(match kind {
        "oom" => PlanError::OutOfMemory {
            gpu: j
                .get("gpu")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("oom entry missing gpu"))?,
            needed: j
                .get("needed")
                .and_then(Json::as_f64)
                .unwrap_or(f64::INFINITY),
            capacity: j
                .get("capacity")
                .and_then(Json::as_f64)
                .unwrap_or(f64::INFINITY),
            config: j
                .get("config")
                .and_then(Json::as_str)
                .map(str::to_string),
        },
        "infeasible" => PlanError::Infeasible(msg(j)?),
        "internal" => PlanError::Internal(msg(j)?),
        "tagged" => PlanError::Tagged {
            planner: j
                .get("planner")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tagged entry missing planner"))?
                .to_string(),
            inner: Box::new(error_from_json(
                j.get("inner")
                    .ok_or_else(|| anyhow!("tagged entry missing inner"))?,
            )?),
        },
        other => return Err(anyhow!("unknown error kind '{other}'")),
    })
}

fn outcome_to_json(o: &PlanOutcome) -> Json {
    use std::collections::BTreeMap;
    let mut m = BTreeMap::new();
    m.insert("planner".into(), Json::Str(o.planner.clone()));
    m.insert("iter_latency".into(), Json::Num(o.iter_latency));
    m.insert("throughput".into(), Json::Num(o.throughput));
    m.insert("config".into(), Json::Str(o.config.clone()));
    m.insert(
        "assignment".into(),
        match &o.assignment {
            None => Json::Null,
            Some(a) => {
                let mut am = BTreeMap::new();
                am.insert(
                    "layer_latency".into(),
                    Json::Num(a.layer_latency),
                );
                am.insert("iter_latency".into(), Json::Num(a.iter_latency));
                am.insert(
                    "per_gpu".into(),
                    Json::Arr(
                        a.per_gpu
                            .iter()
                            .map(|g| {
                                let mut gm = BTreeMap::new();
                                gm.insert(
                                    "microbatch".into(),
                                    Json::Num(g.microbatch as f64),
                                );
                                gm.insert(
                                    "num_micro".into(),
                                    Json::Num(g.num_micro as f64),
                                );
                                gm.insert(
                                    "state_ratio".into(),
                                    Json::Num(g.state_ratio),
                                );
                                Json::Obj(gm)
                            })
                            .collect(),
                    ),
                );
                Json::Obj(am)
            }
        },
    );
    Json::Obj(m)
}

fn outcome_from_json(j: &Json) -> crate::util::error::Result<PlanOutcome> {
    use crate::util::error::anyhow;
    let field_f64 = |k: &str| {
        j.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("outcome missing {k}"))
    };
    let field_str = |k: &str| {
        j.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("outcome missing {k}"))
    };
    let assignment = match j.get("assignment") {
        None | Some(Json::Null) => None,
        Some(a) => {
            let per_gpu = a
                .get("per_gpu")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("assignment missing per_gpu"))?
                .iter()
                .map(|g| {
                    Ok(GpuAssign {
                        microbatch: g
                            .get("microbatch")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("gpu missing microbatch"))?,
                        num_micro: g
                            .get("num_micro")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("gpu missing num_micro"))?,
                        state_ratio: g
                            .get("state_ratio")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| {
                                anyhow!("gpu missing state_ratio")
                            })?,
                    })
                })
                .collect::<crate::util::error::Result<Vec<_>>>()?;
            Some(Assignment {
                per_gpu,
                layer_latency: a
                    .get("layer_latency")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("assignment missing latency"))?,
                iter_latency: a
                    .get("iter_latency")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("assignment missing latency"))?,
            })
        }
    };
    Ok(PlanOutcome {
        planner: field_str("planner")?.to_string(),
        iter_latency: field_f64("iter_latency")?,
        throughput: field_f64("throughput")?,
        config: field_str("config")?.to_string(),
        assignment,
        diagnostics: PlanDiagnostics::default(),
    })
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Workload;
    use crate::plan::planners::CephaloPlanner;
    use crate::testkit::tiny_cluster;

    fn workload() -> Workload {
        Workload::prepare(tiny_cluster(), "BERT-Large", 42).unwrap()
    }

    #[test]
    fn hit_reproduces_miss_exactly() {
        let w = workload();
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        let miss = cache.get_or_plan(&planner, &w.ctx(8)).unwrap();
        let hit = cache.get_or_plan(&planner, &w.ctx(8)).unwrap();
        assert!(!miss.diagnostics.cache_hit);
        assert!(hit.diagnostics.cache_hit);
        assert_eq!(hit.assignment, miss.assignment);
        assert_eq!(hit.iter_latency, miss.iter_latency);
        assert_eq!(hit.throughput, miss.throughput);
        assert_eq!(hit.config, miss.config);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.capacity(), DEFAULT_CAPACITY);
    }

    #[test]
    fn failures_are_cached_too() {
        // Llama 7B state (~107 GB) >> the tiny cluster: deterministic
        // failure, so the second call must not re-solve.
        let w = Workload::prepare(tiny_cluster(), "Llama 7B", 42).unwrap();
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        let e1 = cache.get_or_plan(&planner, &w.ctx(8)).unwrap_err();
        let e2 = cache.get_or_plan(&planner, &w.ctx(8)).unwrap_err();
        assert_eq!(e1, e2);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn differently_configured_planners_do_not_collide() {
        // Same name, different configuration (simulated vs predicted
        // evaluation): cache_signature keeps their entries apart.
        let w = workload();
        let cache = PlanCache::new();
        let simulated = CephaloPlanner::default();
        let predicted =
            CephaloPlanner { simulate: false, ..Default::default() };
        let a = cache.get_or_plan(&simulated, &w.ctx(8)).unwrap();
        let b = cache.get_or_plan(&predicted, &w.ctx(8)).unwrap();
        assert!(
            !b.diagnostics.cache_hit,
            "distinct configs must not share a cache entry"
        );
        assert_eq!(cache.len(), 2);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        // Same underlying solve, different evaluation path.
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn keys_separate_batch_planner_and_cluster() {
        let w = workload();
        let k8 = PlanKey::for_ctx(&w.ctx(8), "Cephalo");
        let k16 = PlanKey::for_ctx(&w.ctx(16), "Cephalo");
        let kw = PlanKey::for_ctx(&w.ctx(8), "Whale");
        assert_ne!(k8, k16);
        assert_ne!(k8, kw);

        // Different oracle seed -> different fitted profile -> different
        // fingerprint, even with identical topology.
        let w2 =
            Workload::prepare(tiny_cluster(), "BERT-Large", 43).unwrap();
        assert_ne!(
            fingerprint(&w.cluster, &w.profile),
            fingerprint(&w2.cluster, &w2.profile)
        );
        // Same seed reproduces the fingerprint.
        let w3 =
            Workload::prepare(tiny_cluster(), "BERT-Large", 42).unwrap();
        assert_eq!(
            fingerprint(&w.cluster, &w.profile),
            fingerprint(&w3.cluster, &w3.profile)
        );
    }

    #[test]
    fn lru_evicts_the_coldest_entry_and_rehits_after_refill() {
        // Satellite: evict-then-rehit. Capacity 2, three distinct keys
        // (two batches of the simulated planner + the predicted
        // variant): touching batch 8 keeps it warm, so inserting the
        // third key evicts batch 16 — the least recently USED, not the
        // least recently inserted. Re-planning 16 is a fresh miss that
        // repopulates, after which it hits again.
        let w = workload();
        let cache = PlanCache::with_capacity(2);
        let sim = CephaloPlanner::default();
        let pred = CephaloPlanner { simulate: false, ..Default::default() };
        cache.get_or_plan(&sim, &w.ctx(8)).unwrap(); // miss
        cache.get_or_plan(&sim, &w.ctx(16)).unwrap(); // miss
        cache.get_or_plan(&sim, &w.ctx(8)).unwrap(); // hit (8 warm)
        assert_eq!(cache.evictions(), 0);
        cache.get_or_plan(&pred, &w.ctx(8)).unwrap(); // miss, evicts 16
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        let h8 = cache.get_or_plan(&sim, &w.ctx(8)).unwrap();
        assert!(h8.diagnostics.cache_hit, "batch 8 should have survived");
        let m16 = cache.get_or_plan(&sim, &w.ctx(16)).unwrap();
        assert!(!m16.diagnostics.cache_hit, "batch 16 was evicted");
        let h16 = cache.get_or_plan(&sim, &w.ctx(16)).unwrap();
        assert!(h16.diagnostics.cache_hit, "refilled entry must re-hit");
        assert!(cache.evictions() >= 2);
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let w = workload();
        let cache = PlanCache::with_capacity(0);
        let sim = CephaloPlanner::default();
        let pred = CephaloPlanner { simulate: false, ..Default::default() };
        for batch in [8usize, 16] {
            cache.get_or_plan(&sim, &w.ctx(batch)).unwrap();
            cache.get_or_plan(&pred, &w.ctx(batch)).unwrap();
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn save_load_round_trip_serves_warm_hits() {
        // Satellite: a resumed session keeps recurring-membership
        // plans warm — save after solving, load into a fresh cache,
        // and the same context is a HIT with a byte-equal assignment.
        let w = workload();
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        let solved = cache.get_or_plan(&planner, &w.ctx(8)).unwrap();
        let path = std::env::temp_dir().join("ceph_plan_cache.json");
        cache.save(&path).unwrap();

        let warm = PlanCache::load(&path).unwrap();
        assert_eq!(warm.len(), 1);
        assert_eq!(warm.capacity(), DEFAULT_CAPACITY);
        let hit = warm.get_or_plan(&planner, &w.ctx(8)).unwrap();
        assert!(hit.diagnostics.cache_hit, "loaded entry must hit");
        assert_eq!(hit.assignment, solved.assignment);
        assert_eq!(hit.iter_latency, solved.iter_latency);
        assert_eq!(hit.throughput, solved.throughput);
        assert_eq!(hit.config, solved.config);
        assert_eq!((warm.hits(), warm.misses()), (1, 0));

        // A different batch still misses (and then caches normally).
        let other = warm.get_or_plan(&planner, &w.ctx(16)).unwrap();
        assert!(!other.diagnostics.cache_hit);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn oom_verdicts_persist_and_reload_without_resolving() {
        // Satellite (ROADMAP follow-up): clean failures are properties
        // of the inputs, so a reloaded cache serves them as hits — a
        // known-infeasible config is never re-run.
        let w = Workload::prepare(tiny_cluster(), "Llama 7B", 42).unwrap();
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        let verdict = cache.get_or_plan(&planner, &w.ctx(8)).unwrap_err();
        let path =
            std::env::temp_dir().join("ceph_plan_cache_verdicts.json");
        cache.save(&path).unwrap();

        let warm = PlanCache::load(&path).unwrap();
        assert_eq!(warm.len(), 1);
        let again = warm.get_or_plan(&planner, &w.ctx(8)).unwrap_err();
        assert_eq!(again, verdict, "reloaded verdict must be identical");
        assert_eq!(
            (warm.hits(), warm.misses()),
            (1, 0),
            "a persisted verdict must be a hit, not a re-solve"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fingerprint_age_out_drops_stale_entries_across_reload() {
        // Satellite: entries for memberships the trace window can no
        // longer produce are aged out — and STAY gone after a
        // save/load cycle, so a resumed session never reloads plans
        // for unreachable clusters.
        let w2 = workload();
        // Same topology, different oracle seed: a fingerprint-distinct
        // cluster standing in for a membership that left the window.
        let w1 =
            Workload::prepare(tiny_cluster(), "BERT-Large", 43).unwrap();
        assert_ne!(w1.fingerprint, w2.fingerprint);
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        cache.get_or_plan(&planner, &w2.ctx(8)).unwrap();
        cache.get_or_plan(&planner, &w1.ctx(8)).unwrap();
        assert_eq!(cache.len(), 2);

        // The w2 membership leaves the trace window for good.
        let dropped = cache.retain_fingerprints(&[w1.fingerprint]);
        assert_eq!(dropped, 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stale_evictions(), 1);
        assert_eq!(cache.evictions(), 0, "not an LRU eviction");

        let path = std::env::temp_dir().join("ceph_plan_cache_aged.json");
        cache.save(&path).unwrap();
        let warm = PlanCache::load(&path).unwrap();
        assert_eq!(warm.len(), 1);
        let hit = warm.get_or_plan(&planner, &w1.ctx(8)).unwrap();
        assert!(hit.diagnostics.cache_hit, "live entry survives reload");
        let stale = warm.get_or_plan(&planner, &w2.ctx(8)).unwrap();
        assert!(
            !stale.diagnostics.cache_hit,
            "aged-out fingerprint must be gone after reload"
        );
        // Retaining everything currently live drops nothing.
        assert_eq!(
            warm.retain_fingerprints(&[w1.fingerprint, w2.fingerprint]),
            0
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn error_json_round_trips_every_clean_kind() {
        for e in [
            PlanError::oom(3, f64::INFINITY, 0.0),
            PlanError::oom_in(1, 20e9, 10e9, "micro=16 x 2"),
            PlanError::Infeasible("batch 7 not divisible".into()),
            PlanError::oom(0, 5e9, 4e9).tagged("Whale"),
            PlanError::Infeasible("x".into()).tagged("HAP"),
        ] {
            let back = error_from_json(&Json::parse(
                &error_to_json(&e).render(),
            )
            .unwrap())
            .unwrap();
            assert_eq!(back, e, "round trip changed {e}");
            assert!(is_clean_failure(&e));
        }
        assert!(!is_clean_failure(&PlanError::Internal("bug".into())));
        assert!(!is_clean_failure(
            &PlanError::Internal("bug".into()).tagged("Cephalo")
        ));
    }

    #[test]
    fn load_rejects_malformed_files() {
        let dir = std::env::temp_dir();
        let bad = dir.join("ceph_plan_cache_bad.json");
        std::fs::write(&bad, "{\"version\": 99, \"entries\": []}").unwrap();
        assert!(PlanCache::load(&bad).is_err());
        std::fs::write(&bad, "not json").unwrap();
        assert!(PlanCache::load(&bad).is_err());
        let _ = std::fs::remove_file(&bad);
    }
}
