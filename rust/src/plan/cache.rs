//! Content-addressed plan memoization.
//!
//! A plan depends only on (cluster + fitted profile, model, batch,
//! planner): all of it deterministic, so outcomes — including failures,
//! OOM is a property of the inputs — can be cached. Keys fingerprint
//! the cluster topology and the fitted `ClusterPerfProfile` contents
//! (the profile is itself a deterministic function of the oracle seed,
//! so it proxies the oracle too). The elastic coordinator keeps one
//! cache across membership changes: returning to a previously seen
//! membership makes re-planning near-free.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::{PlanContext, PlanOutcome, Planner};
use crate::cluster::Cluster;
use crate::optimizer::PlanError;
use crate::perfmodel::ClusterPerfProfile;

use crate::util::fnv1a;

/// Content fingerprint of everything a planner reads about the cluster:
/// the topology (GPU specs, per-node grouping, bandwidths) and the
/// fitted per-GPU latency/memory models + collective constants. Uses
/// the canonical `Debug` rendering, which covers every field —
/// including the profiled latency points fitted from the noisy oracle,
/// so different oracle seeds fingerprint differently.
pub fn fingerprint(cluster: &Cluster, profile: &ClusterPerfProfile) -> u64 {
    let c = fnv1a(format!("{cluster:?}").as_bytes());
    let p = fnv1a(format!("{profile:?}").as_bytes());
    c ^ p.rotate_left(17)
}

/// Cache key: (cluster/profile fingerprint, model, batch, planner
/// cache signature — name PLUS configuration, so two differently
/// configured planners sharing a name never share entries).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub cluster_fingerprint: u64,
    pub model: String,
    pub batch: usize,
    pub planner: String,
}

impl PlanKey {
    /// Key for `ctx` + a planner's `cache_signature()`. Uses the
    /// context's precomputed fingerprint — no profile re-render.
    pub fn for_ctx(ctx: &PlanContext<'_>, signature: &str) -> PlanKey {
        PlanKey {
            cluster_fingerprint: ctx.cluster_fingerprint,
            model: ctx.model.name.clone(),
            batch: ctx.batch,
            planner: signature.to_string(),
        }
    }
}

/// Thread-safe memoization of plan results (hits from `sweep` workers
/// and the elastic coordinator are counted).
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Result<PlanOutcome, PlanError>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Serve from cache or run the planner and remember the result
    /// (successes AND clean failures). Cache hits are marked in
    /// `diagnostics.cache_hit` with `solve_seconds` zeroed. The solve
    /// runs outside the lock, so concurrent misses on the same key may
    /// both solve (last insert wins — results are deterministic, so
    /// both are identical).
    pub fn get_or_plan(
        &self,
        planner: &dyn Planner,
        ctx: &PlanContext<'_>,
    ) -> Result<PlanOutcome, PlanError> {
        let key = PlanKey::for_ctx(ctx, &planner.cache_signature());
        if let Some(found) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return match found {
                Ok(outcome) => {
                    let mut out = outcome.clone();
                    out.diagnostics.cache_hit = true;
                    out.diagnostics.solve_seconds = 0.0;
                    Ok(out)
                }
                Err(e) => Err(e.clone()),
            };
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let result = planner.plan(ctx);
        self.map.lock().unwrap().insert(key, result.clone());
        result
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Workload;
    use crate::plan::planners::CephaloPlanner;
    use crate::testkit::tiny_cluster;

    fn workload() -> Workload {
        Workload::prepare(tiny_cluster(), "BERT-Large", 42).unwrap()
    }

    #[test]
    fn hit_reproduces_miss_exactly() {
        let w = workload();
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        let miss = cache.get_or_plan(&planner, &w.ctx(8)).unwrap();
        let hit = cache.get_or_plan(&planner, &w.ctx(8)).unwrap();
        assert!(!miss.diagnostics.cache_hit);
        assert!(hit.diagnostics.cache_hit);
        assert_eq!(hit.assignment, miss.assignment);
        assert_eq!(hit.iter_latency, miss.iter_latency);
        assert_eq!(hit.throughput, miss.throughput);
        assert_eq!(hit.config, miss.config);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn failures_are_cached_too() {
        // Llama 7B state (~107 GB) >> the tiny cluster: deterministic
        // failure, so the second call must not re-solve.
        let w = Workload::prepare(tiny_cluster(), "Llama 7B", 42).unwrap();
        let cache = PlanCache::new();
        let planner = CephaloPlanner::default();
        let e1 = cache.get_or_plan(&planner, &w.ctx(8)).unwrap_err();
        let e2 = cache.get_or_plan(&planner, &w.ctx(8)).unwrap_err();
        assert_eq!(e1, e2);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn differently_configured_planners_do_not_collide() {
        // Same name, different configuration (simulated vs predicted
        // evaluation): cache_signature keeps their entries apart.
        let w = workload();
        let cache = PlanCache::new();
        let simulated = CephaloPlanner::default();
        let predicted =
            CephaloPlanner { simulate: false, ..Default::default() };
        let a = cache.get_or_plan(&simulated, &w.ctx(8)).unwrap();
        let b = cache.get_or_plan(&predicted, &w.ctx(8)).unwrap();
        assert!(
            !b.diagnostics.cache_hit,
            "distinct configs must not share a cache entry"
        );
        assert_eq!(cache.len(), 2);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        // Same underlying solve, different evaluation path.
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn keys_separate_batch_planner_and_cluster() {
        let w = workload();
        let k8 = PlanKey::for_ctx(&w.ctx(8), "Cephalo");
        let k16 = PlanKey::for_ctx(&w.ctx(16), "Cephalo");
        let kw = PlanKey::for_ctx(&w.ctx(8), "Whale");
        assert_ne!(k8, k16);
        assert_ne!(k8, kw);

        // Different oracle seed -> different fitted profile -> different
        // fingerprint, even with identical topology.
        let w2 =
            Workload::prepare(tiny_cluster(), "BERT-Large", 43).unwrap();
        assert_ne!(
            fingerprint(&w.cluster, &w.profile),
            fingerprint(&w2.cluster, &w2.profile)
        );
        // Same seed reproduces the fingerprint.
        let w3 =
            Workload::prepare(tiny_cluster(), "BERT-Large", 42).unwrap();
        assert_eq!(
            fingerprint(&w.cluster, &w.profile),
            fingerprint(&w3.cluster, &w3.profile)
        );
    }
}
