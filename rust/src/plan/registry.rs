//! Name-based planner lookup: every strategy the repo implements —
//! Cephalo, the five baseline systems, and the ablation variants — is
//! reachable through `PlannerRegistry::get("name")`, so the CLI,
//! benches and the elastic coordinator never hardwire a planner list.

use std::sync::Arc;

use super::planners::{CephaloCb, CephaloMb, CephaloPlanner, FsdpEven};
use super::Planner;
use crate::baselines;

/// Ordered collection of planners with normalized-name lookup.
pub struct PlannerRegistry {
    entries: Vec<Arc<dyn Planner>>,
}

/// Lookup normalization: case-insensitive, punctuation-insensitive
/// ("Megatron-Het" == "megatron_het" == "megatronhet").
fn normalize(name: &str) -> String {
    name.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

impl PlannerRegistry {
    /// An empty registry (register your own strategies).
    pub fn new() -> PlannerRegistry {
        PlannerRegistry { entries: Vec::new() }
    }

    /// Every planner the repo ships: Cephalo (DP), the five baselines,
    /// and the three ablation variants, in table order.
    pub fn with_defaults() -> PlannerRegistry {
        let mut r = PlannerRegistry::new();
        r.register(Arc::new(CephaloPlanner::default()));
        r.register(Arc::new(baselines::megatron::MegatronHet));
        r.register(Arc::new(baselines::flashflex::FlashFlex));
        r.register(Arc::new(baselines::whale::Whale));
        r.register(Arc::new(baselines::hap::Hap));
        r.register(Arc::new(baselines::fsdp::FsdpBaseline));
        r.register(Arc::new(CephaloCb));
        r.register(Arc::new(CephaloMb));
        r.register(Arc::new(FsdpEven));
        r
    }

    /// Add (or shadow) a planner. Later registrations win lookups for
    /// the same normalized name.
    pub fn register(&mut self, planner: Arc<dyn Planner>) {
        self.entries.push(planner);
    }

    /// Look up by name: exact normalized match first, then substring
    /// match ("megatron" -> "Megatron-Het"). Later registrations
    /// shadow earlier ones on exact ties. The substring fallback
    /// requires at least 4 characters so short typos (an "al" for
    /// "all", a stray "a") error instead of resolving to whatever
    /// name happens to contain them.
    pub fn get(&self, name: &str) -> Option<Arc<dyn Planner>> {
        let want = normalize(name);
        if want.is_empty() {
            return None;
        }
        if let Some(p) = self
            .entries
            .iter()
            .rev()
            .find(|p| normalize(p.name()) == want)
        {
            return Some(Arc::clone(p));
        }
        if want.len() < 4 {
            return None;
        }
        self.entries
            .iter()
            .find(|p| normalize(p.name()).contains(&want))
            .map(Arc::clone)
    }

    /// Registered display names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|p| p.name()).collect()
    }

    /// All planners, in registration order (the `sweep` input).
    pub fn planners(&self) -> &[Arc<dyn Planner>] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for PlannerRegistry {
    /// Empty, matching `new()` (Rust convention). The fully populated
    /// registry is the EXPLICIT `with_defaults()`.
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_every_system() {
        let r = PlannerRegistry::with_defaults();
        assert_eq!(r.len(), 9);
        for name in [
            "cephalo",
            "Megatron-Het",
            "flashflex",
            "whale",
            "HAP",
            "fsdp",
            "cephalo-cb",
            "Cephalo-MB",
            "fsdp-even",
        ] {
            assert!(r.get(name).is_some(), "missing {name}");
        }
    }

    #[test]
    fn exact_match_beats_substring() {
        let r = PlannerRegistry::with_defaults();
        // "cephalo" must resolve to the DP planner, not Cephalo-CB.
        assert_eq!(r.get("cephalo").unwrap().name(), "Cephalo");
        assert_eq!(r.get("fsdp").unwrap().name(), "FSDP");
        // Substring fallback still works.
        assert_eq!(r.get("megatron").unwrap().name(), "Megatron-Het");
    }

    #[test]
    fn unknown_names_miss() {
        let r = PlannerRegistry::with_defaults();
        assert!(r.get("alpa").is_none());
        assert!(r.get("").is_none());
        // Short fragments must not substring-resolve: "al" (a typo'd
        // "all") would otherwise match "ceph[al]o".
        assert!(r.get("al").is_none());
        assert!(r.get("a").is_none());
        // ...but short EXACT names still resolve.
        assert_eq!(r.get("hap").unwrap().name(), "HAP");
        // And Default is the empty registry, matching new().
        assert!(PlannerRegistry::default().is_empty());
    }

    #[test]
    fn normalization_is_punctuation_blind() {
        assert_eq!(normalize("Megatron-Het"), "megatronhet");
        assert_eq!(normalize("cephalo_mb"), "cephalomb");
    }
}
