//! The plan subsystem: ONE interface over every planning strategy.
//!
//! Cephalo's contribution is decoupling compute distribution from
//! training-state assignment and re-solving that joint plan as cluster
//! conditions change. This module makes "a way to produce a plan" a
//! first-class object so the coordinator, CLI, benches and the elastic
//! re-planner all speak to the Cephalo DP solver, the five baseline
//! systems and the ablation variants through the same trait:
//!
//! * [`Planner`] — name + `plan(ctx) -> PlanOutcome`; implemented by
//!   [`planners::CephaloPlanner`], every `baselines::*` system, and the
//!   §4.4 ablations ([`planners::CephaloCb`] / [`planners::CephaloMb`]
//!   / [`planners::FsdpEven`]).
//! * [`PlanContext`] — the shared inputs (cluster, model, fitted
//!   profile, ground-truth oracle, global batch), promoted out of
//!   `baselines::mod`.
//! * [`PlanOutcome`] — the full [`Assignment`] (when the strategy
//!   produces an FSDP-style per-GPU division) plus latency, throughput,
//!   a human-readable configuration and solver diagnostics.
//! * [`PlannerRegistry`] — name-based lookup ("cephalo", "whale",
//!   "cephalo-mb", ...) so new strategies are one `register` away.
//! * [`PlanCache`] — content-addressed memoization keyed on (cluster
//!   fingerprint, model, batch, planner); elastic re-planning over a
//!   previously seen membership is served from cache.
//! * [`sweep`] — solve (planner x batch) grids in parallel with scoped
//!   threads; the engine behind `cephalo plan --system all` and the
//!   table benches.

pub mod cache;
pub mod planners;
pub mod registry;
pub mod sweep;

pub use cache::{fingerprint, PlanCache, PlanKey};
pub use planners::{CephaloCb, CephaloMb, CephaloPlanner, FsdpEven};
pub use registry::PlannerRegistry;
pub use sweep::{sweep, SweepCell};

use crate::cluster::Cluster;
use crate::model::TransformerSpec;
use crate::optimizer::{Assignment, PlanError};
use crate::perfmodel::{ClusterPerfProfile, ComputeOracle};

/// Inputs shared by every planner. `oracle` must be `Sync` so contexts
/// can be shared across the [`sweep`] worker threads.
///
/// Prefer [`PlanContext::new`] (or `Workload::ctx`, which memoizes):
/// `cluster_fingerprint` MUST be `fingerprint(cluster, profile)` or
/// the [`PlanCache`] will serve stale entries.
#[derive(Clone, Copy)]
pub struct PlanContext<'a> {
    pub cluster: &'a Cluster,
    pub model: &'a TransformerSpec,
    pub profile: &'a ClusterPerfProfile,
    pub oracle: &'a (dyn ComputeOracle + Sync),
    pub batch: usize,
    /// Content fingerprint of (cluster, profile), precomputed so cache
    /// lookups are a hash probe instead of an O(profile) re-render.
    pub cluster_fingerprint: u64,
    /// Same-host fabric bandwidth in Gbps (slowest intra-node link) —
    /// the per-edge rate of the runtime's shm fast path, for planners
    /// that charge comm by edge class.
    pub intra_gbps: f64,
    /// Cross-host fabric bandwidth in Gbps (the inter-node link).
    pub inter_gbps: f64,
}

impl<'a> PlanContext<'a> {
    pub fn new(
        cluster: &'a Cluster,
        model: &'a TransformerSpec,
        profile: &'a ClusterPerfProfile,
        oracle: &'a (dyn ComputeOracle + Sync),
        batch: usize,
    ) -> PlanContext<'a> {
        PlanContext {
            cluster,
            model,
            profile,
            oracle,
            batch,
            cluster_fingerprint: fingerprint(cluster, profile),
            intra_gbps: cluster.intra_bw_min_gbps(),
            inter_gbps: cluster.inter_bw_gbps,
        }
    }
}

/// Solver diagnostics carried by every outcome (Table 7 reporting and
/// the cache/elastic instrumentation).
#[derive(Debug, Clone, Default)]
pub struct PlanDiagnostics {
    /// Wall-clock planning time (zero when served from cache).
    pub solve_seconds: f64,
    /// DP states visited (Cephalo) — 0 for search-based baselines.
    pub states_visited: u64,
    /// DP transitions relaxed (Cephalo) — 0 for baselines.
    pub transitions: u64,
    /// Candidate configurations evaluated by search-based planners.
    pub candidates: u64,
    /// True when this outcome was served from a [`PlanCache`].
    pub cache_hit: bool,
}

/// A planner's chosen configuration and its predicted performance.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    /// The planner that produced this outcome (`Planner::name`).
    pub planner: String,
    /// Predicted end-to-end iteration latency (seconds).
    pub iter_latency: f64,
    /// Predicted throughput (samples/second).
    pub throughput: f64,
    /// Human-readable description of the winning configuration.
    pub config: String,
    /// The full per-GPU compute/state division, for strategies that map
    /// onto the FSDP-style `Assignment` (Cephalo, ablations, FSDP).
    /// Pipeline/TP baselines (Megatron-Het, FlashFlex, HAP) and
    /// replication (Whale) have no such division and return `None`.
    pub assignment: Option<Assignment>,
    pub diagnostics: PlanDiagnostics,
}

/// A strategy that turns a [`PlanContext`] into a [`PlanOutcome`].
///
/// Implementations must be `Send + Sync`: the registry shares them via
/// `Arc` and [`sweep`] calls them from multiple threads. Errors should
/// be tagged with the planner name (`PlanError::tagged`) so table cells
/// and logs can attribute OOMs.
pub trait Planner: Send + Sync {
    fn name(&self) -> &'static str;
    fn plan(&self, ctx: &PlanContext<'_>)
        -> Result<PlanOutcome, PlanError>;

    /// Cache discriminator. Two planner INSTANCES that can produce
    /// different outcomes for the same context must return different
    /// signatures, or the [`PlanCache`] will conflate them. The
    /// default suits stateless planners; configurable ones must
    /// include their configuration (see `CephaloPlanner`).
    fn cache_signature(&self) -> String {
        self.name().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::tiny_cluster;

    #[test]
    fn context_is_copy_and_sendable_across_threads() {
        let holder = crate::coordinator::Workload::prepare(
            tiny_cluster(),
            "BERT-Large",
            42,
        )
        .unwrap();
        let ctx = holder.ctx(8);
        let ctx2 = ctx; // Copy
        let both = std::thread::scope(|s| {
            let a = s.spawn(move || ctx.batch);
            let b = s.spawn(move || ctx2.profile.num_gpus());
            (a.join().unwrap(), b.join().unwrap())
        });
        assert_eq!(both, (8, 2));
        // Edge-class bandwidths mirror the cluster's links.
        assert_eq!((ctx.intra_gbps, ctx.inter_gbps), (64.0, 50.0));
    }
}
