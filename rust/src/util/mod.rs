//! Shared low-level utilities: PRNG, statistics, JSON, table formatting,
//! and byte-size helpers. These substitute for the external crates
//! (`rand`, `serde`, `prettytable`) that the offline build cannot use.

pub mod json;
pub mod prng;
pub mod stats;
pub mod tablefmt;

/// Bytes in one GiB.
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Bytes in one GB (decimal — GPU marketing units, Table 3).
pub const GB: f64 = 1e9;

/// Convert GiB to bytes.
pub fn gib(x: f64) -> f64 {
    x * GIB
}

/// Duration of an f64-second value as human text.
pub fn human_secs(s: f64) -> String {
    tablefmt::fmt_secs(s)
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn unit_conversions() {
        assert_eq!(gib(1.0), GIB);
        assert!(GB < GIB);
    }
}
