//! Shared low-level utilities: PRNG, statistics, JSON, table formatting,
//! error handling, and byte-size helpers. These substitute for the
//! external crates (`rand`, `serde`, `prettytable`, `anyhow`) that the
//! offline build cannot use.

pub mod error;
pub mod json;
pub mod prng;
pub mod stats;
pub mod tablefmt;

/// Bytes in one GiB.
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Bytes in one GB (decimal — GPU marketing units, Table 3).
pub const GB: f64 = 1e9;

/// Convert GiB to bytes.
pub fn gib(x: f64) -> f64 {
    x * GIB
}

/// Duration of an f64-second value as human text.
pub fn human_secs(s: f64) -> String {
    tablefmt::fmt_secs(s)
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    assert!(b > 0);
    a.div_ceil(b)
}

/// FNV-1a over a byte stream — the one hash shared by testkit seed
/// derivation, checkpoint checksums, and plan-cache fingerprints.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn unit_conversions() {
        assert_eq!(gib(1.0), GIB);
        assert!(GB < GIB);
    }
}
