//! Plain-text table rendering for the benchmark harness.
//!
//! Every `benches/table*.rs` / `benches/fig*.rs` binary prints its result
//! in the same row/column layout the paper reports, via this formatter.

/// A simple column-aligned table with a title and optional units row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Column widths: max of header and cell widths.
    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let header: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>width$}", h, width = w[i]))
            .collect();
        out.push_str(&header.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(header.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect();
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored markdown (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with `digits` decimals, or "OOM"/"-" markers.
pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

/// Throughput cell: finite -> 2 decimals, NaN/inf -> "OOM".
pub fn fmt_throughput(x: f64) -> String {
    if x.is_finite() && x > 0.0 {
        format!("{:.2}", x)
    } else {
        "OOM".to_string()
    }
}

/// Human bytes (GiB with 1 decimal).
pub fn fmt_gib(bytes: f64) -> String {
    format!("{:.1} GiB", bytes / (1024.0 * 1024.0 * 1024.0))
}

/// Seconds with ms precision for latencies.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["sys", "x"]);
        t.add_row(vec!["Cephalo".into(), "6.38".into()]);
        t.add_row(vec!["FSDP".into(), "OOM".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("Cephalo"));
        assert!(s.contains("OOM"));
        // rows have equal rendered width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_width() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("m", &["a", "b"]);
        t.add_row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_throughput(6.381), "6.38");
        assert_eq!(fmt_throughput(f64::NAN), "OOM");
        assert_eq!(fmt_secs(0.0005), "500.0 us");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert!(fmt_gib(3.5 * 1024.0 * 1024.0 * 1024.0).starts_with("3.5"));
    }
}
