//! Minimal JSON parser — enough to read `artifacts/manifest.json`.
//!
//! No serde in the offline dependency closure, so the runtime carries a
//! small recursive-descent parser producing a dynamic `Json` value. It
//! handles the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null); it does not aim for serde's performance.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors descriptively — manifest reading.
    pub fn field(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError {
            msg: format!("missing field '{key}'"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Render to compact JSON text. Numbers use Rust's shortest
    /// round-trip float formatting, so `parse(render(x)) == x` for
    /// every finite value (non-finite floats render as `null`, which
    /// JSON has no representation for).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp)
                                    .unwrap_or(char::REPLACEMENT_CHARACTER),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(
            r#"{"model": {"d": 256, "names": ["a", "b"], "ok": true}}"#,
        )
        .unwrap();
        let model = j.get("model").unwrap();
        assert_eq!(model.get("d").unwrap().as_usize(), Some(256));
        assert_eq!(
            model.get("names").unwrap().as_arr().unwrap()[1].as_str(),
            Some("b")
        );
        assert_eq!(model.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn render_round_trips_through_parse() {
        let j = Json::parse(
            r#"{"a": [1, -2.5, true, null], "s": "x\"y\nz", "o": {}}"#,
        )
        .unwrap();
        let text = j.render();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // Floats round-trip exactly via shortest formatting.
        let x = 0.1f64 + 0.2;
        let n = Json::Num(x);
        let back = Json::parse(&n.render()).unwrap();
        assert_eq!(back.as_f64().unwrap().to_bits(), x.to_bits());
        // Non-finite floats degrade to null instead of emitting
        // unparseable text.
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
            "model": {"vocab": 1024, "d_model": 256, "use_pallas": true},
            "param_order": ["embed", "wq"],
            "param_shapes": {"embed": [1024, 256], "wq": [4, 256, 256]},
            "microbatches": [1, 2, 4],
            "entries": [{"kind": "grad_step", "microbatch": 1, "file": "g.hlo.txt"}]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(
            j.field("param_shapes").unwrap().get("wq").unwrap().as_arr()
                .unwrap()
                .len(),
            3
        );
        assert_eq!(j.field("entries").unwrap().as_arr().unwrap().len(), 1);
    }
}
