//! Small statistics helpers used by the profiler, benchkit and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordinary least squares fit y = slope * x + intercept.
/// Returns (slope, intercept). Needs >= 2 points.
pub fn linear_fit(points: &[(f64, f64)]) -> (f64, f64) {
    assert!(points.len() >= 2, "linear_fit needs >= 2 points");
    let n = points.len() as f64;
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, sy / n);
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    (slope, intercept)
}

/// Coefficient of determination for a fit.
pub fn r_squared(points: &[(f64, f64)], slope: f64, intercept: f64) -> f64 {
    let ym = mean(&points.iter().map(|p| p.1).collect::<Vec<_>>());
    let ss_tot: f64 = points.iter().map(|p| (p.1 - ym) * (p.1 - ym)).sum();
    let ss_res: f64 = points
        .iter()
        .map(|p| {
            let pred = slope * p.0 + intercept;
            (p.1 - pred) * (p.1 - pred)
        })
        .sum();
    if ss_tot < 1e-12 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Mean absolute relative error between predictions and actuals (Fig. 10).
pub fn mean_abs_rel_error(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    assert!(!pred.is_empty());
    mean(
        &pred
            .iter()
            .zip(actual)
            .map(|(p, a)| ((p - a) / a).abs())
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn linear_fit_exact() {
        let pts = [(1.0, 3.0), (2.0, 5.0), (3.0, 7.0)];
        let (slope, intercept) = linear_fit(&pts);
        assert!((slope - 2.0).abs() < 1e-9);
        assert!((intercept - 1.0).abs() < 1e-9);
        assert!((r_squared(&pts, slope, intercept) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_noisy_recovers() {
        let pts: Vec<(f64, f64)> = (1..50)
            .map(|i| {
                let x = i as f64;
                (x, 3.5 * x + 10.0 + if i % 2 == 0 { 0.5 } else { -0.5 })
            })
            .collect();
        let (slope, intercept) = linear_fit(&pts);
        assert!((slope - 3.5).abs() < 0.01);
        assert!((intercept - 10.0).abs() < 0.5);
    }

    #[test]
    fn mare_basic() {
        let pred = [1.1, 2.0];
        let act = [1.0, 2.0];
        assert!((mean_abs_rel_error(&pred, &act) - 0.05).abs() < 1e-9);
    }
}
