//! Minimal `anyhow` substitute for the offline dependency closure (the
//! same role util/prng.rs plays for `rand`): an opaque, message-carrying
//! error type with the `anyhow!` macro and the `Context` extension
//! trait, covering exactly the subset the runtime/trainer code uses.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what permits the blanket
//! `From<E: std::error::Error>` conversion powering `?`.

/// Opaque error: a rendered message plus optional rendered cause chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: std::fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, `anyhow`-style (`context: cause`).
    pub fn wrap<C: std::fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::anyhow!`: format a message into an [`Error`].
/// Mirrors the real macro's arms: a format literal (with optional
/// args), or any `Display` expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

// Make `use crate::util::error::anyhow;` work like the real crate's
// `use anyhow::anyhow;` (macro_export hoists the macro to the root).
pub use crate::anyhow;

/// Drop-in for `anyhow::Context` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: std::fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: std::fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: std::fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "disk gone");
        Err(e)?; // exercises the blanket From
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().contains("disk gone"));
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        assert_eq!(format!("{e:?}"), "bad value 42");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), String> = Err("cause".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: cause");
        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }
}
