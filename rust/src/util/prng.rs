//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so Cephalo carries its own
//! small, well-tested generator: SplitMix64 for seeding / streams and a
//! xoshiro256++-style core for the hot paths. Everything downstream
//! (synthetic corpora, profiler noise, property tests, the AWS
//! availability trace) is seeded explicitly, so every experiment in
//! EXPERIMENTS.md is bit-reproducible.

/// SplitMix64: tiny, excellent for deriving independent streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// The main PRNG handed around the codebase.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. one per worker / experiment).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256++
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range [{lo}, {hi})");
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Uniform i64 in [lo, hi).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal(mean, std) as f32 — the parameter-init workhorse.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fill a slice with Normal(0, std) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
